#!/usr/bin/env bash
# Tier-1 test entrypoint + serving smoke.
#
#   scripts/test.sh              # full pytest suite (tier-1 command)
#   scripts/test.sh smoke        # fast serving smoke: both engine modes
#   scripts/test.sh kernels      # kernel-parity + fused-loop tests and a
#                                # Pallas-routed continuous-serve smoke
#   scripts/test.sh server       # HTTP front-end tests (loopback round
#                                # trip, SSE, 429, deadlines, disconnect)
#   scripts/test.sh sharded      # mesh-parallel decode suite (forced
#                                # 8-device host mesh) + sharded bench
#   scripts/test.sh disagg       # disaggregated prefill/decode pool
#                                # suite (roles, radix-store handoff,
#                                # crash re-route) + mixed-workload
#                                # insulation bench
#   scripts/test.sh cache        # cross-request prefix cache suite +
#                                # a quick bench_cache run
#   scripts/test.sh obs          # observability suite (tracer, span
#                                # trees, telemetry, histograms, logs)
#   scripts/test.sh series       # time-series suite (metrics recorder,
#                                # /debug/timeline, /console, fleet
#                                # fan-in, Prometheus exposition)
#   scripts/test.sh audit        # quality-audit suite (shadow auditor,
#                                # fault injection, SLO watchdog,
#                                # flight recorder, /debug routes)
#   scripts/test.sh gate         # regenerate the quick benches and
#                                # gate them against the committed
#                                # baseline (scripts/bench_gate.py)
#   scripts/test.sh lint         # compileall + import-cycle smoke +
#                                # no-print policy + raise discipline
#                                # in observability hot paths + metrics
#                                # doc drift check (also runs at the
#                                # top of tier-1)
#   scripts/test.sh all          # suite + smoke
#
# Tests run on the single real CPU device; the dry-run subprocesses set
# their own XLA device-count flags (never export device-count flags
# globally here — see tests/conftest.py).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

run_lint() {
    # fail fast on syntax errors and package-level import cycles before
    # paying for any jit compile: byte-compile the whole tree, then
    # import every repro package fresh in one interpreter
    python -m compileall -q src
    python - <<'EOF'
import importlib, pkgutil
import repro
mods = [m.name for m in pkgutil.walk_packages(repro.__path__, "repro.")
        if ".launch." not in m.name       # launchers parse argv/XLA flags
        and not m.name.endswith("__main__")]
for name in sorted(mods):
    importlib.import_module(name)
print(f"lint: imported {len(mods)} repro modules, no cycles")
EOF
    python - <<'EOF'
# library code must log via repro.obs.log, not print: an embedded
# engine should never write to a server's stdout. AST-based (docstring
# examples showing print() are fine); the launch CLIs are the
# allowlisted user-facing surface.
import ast, pathlib, sys
bad = []
for path in sorted(pathlib.Path("src/repro").rglob("*.py")):
    if "launch" in path.parts:
        continue
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "print"):
            bad.append(f"{path}:{node.lineno}")
if bad:
    print("lint: bare print() in library code (use repro.obs.log):")
    print("\n".join(f"  {b}" for b in bad))
    sys.exit(1)
print("lint: no bare print() outside src/repro/launch")
EOF
    python - <<'EOF'
# XLA/JAX process environment is mutated in exactly one place:
# repro.launch (host budgets, fake device counts, platform pins, the
# persistent compile cache). Anywhere else, a write to XLA_FLAGS /
# PJRT_NPROC / JAX_PLATFORMS silently depends on import order and
# defeats the per-engine budget — so the lint walks every assignment,
# os.environ[...] store, setdefault, update, putenv, and pop for those
# keys. Benchmarks compose child env dicts via
# repro.launch.host.budget_env (pure, no process mutation) instead.
import ast, pathlib, sys
KEYS = ("XLA_FLAGS", "PJRT_NPROC", "JAX_PLATFORMS")

def names_env(node):        # os.environ or environ
    return (isinstance(node, ast.Attribute) and node.attr == "environ") \
        or (isinstance(node, ast.Name) and node.id == "environ")

def key_is_xla(node):
    return isinstance(node, ast.Constant) and node.value in KEYS

bad = []
roots = [pathlib.Path("src/repro"), pathlib.Path("benchmarks")]
for root in roots:
    for path in sorted(root.rglob("*.py")):
        if path.parts[:3] == ("src", "repro", "launch"):
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            hit = False
            # os.environ["XLA_FLAGS"] = ... (incl. augmented/annotated)
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                tgts = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                hit = any(isinstance(t, ast.Subscript) and names_env(t.value)
                          and key_is_xla(t.slice) for t in tgts)
            # os.environ.setdefault/update/pop("XLA_FLAGS", ...), putenv
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                f = node.func
                if f.attr in ("setdefault", "pop", "update") \
                        and names_env(f.value):
                    hit = any(key_is_xla(a) for a in node.args) or any(
                        kw.arg in KEYS for kw in node.keywords)
                elif f.attr == "putenv":
                    hit = any(key_is_xla(a) for a in node.args)
            if hit:
                bad.append(f"{path}:{node.lineno}")
if bad:
    print("lint: XLA env mutated outside repro.launch "
          "(route through repro.launch.host):")
    print("\n".join(f"  {b}" for b in bad))
    sys.exit(1)
print("lint: XLA env (XLA_FLAGS/PJRT_NPROC/JAX_PLATFORMS) only "
      "mutated in repro.launch")
EOF
    python - <<'EOF'
# observability hot paths must log-and-drop, never raise: a tracer or
# auditor exception inside the decode thread would kill paying traffic
# to report on it. AST lint: no `raise` statement in the tracer/auditor
# modules outside the explicitly-allowlisted functions (request_tree is
# an offline analysis helper whose ValueError IS its contract;
# __post_init__ is config validation at construction time, before any
# hot path exists).
import ast, pathlib, sys
FILES = ("src/repro/obs/trace.py", "src/repro/obs/audit.py",
         "src/repro/obs/series.py")
ALLOWED = {"request_tree", "__post_init__"}
bad = []
for fname in FILES:
    tree = ast.parse(pathlib.Path(fname).read_text(), filename=fname)
    # map every node to its innermost enclosing function name
    def walk(node, fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn = node.name
        if isinstance(node, ast.Raise) and fn not in ALLOWED:
            bad.append(f"{fname}:{node.lineno} (in {fn or '<module>'})")
        for child in ast.iter_child_nodes(node):
            walk(child, fn)
    walk(tree, None)
if bad:
    print("lint: raise in an observability hot path (log-and-drop "
          "instead; allowlist: request_tree, __post_init__):")
    print("\n".join(f"  {b}" for b in bad))
    sys.exit(1)
print(f"lint: no raise outside {sorted(ALLOWED)} in "
      f"{len(FILES)} obs hot-path modules")
EOF
    # docs/METRICS.md must match a fresh /metrics rendering (every
    # repro_* literal in the server source covered and documented)
    python scripts/gen_metrics_doc.py --check
}

run_suite() {
    run_lint
    python -m pytest -x -q "$@"
}

run_cache() {
    # prefix-cache suite (radix store, cached-prefill identity,
    # routing), then the cache bench on the quick workload
    python -m pytest -x -q tests/test_cache.py
    echo "== bench_cache --quick =="
    python benchmarks/bench_cache.py --quick \
        --out results/BENCH_cache_quick.json
}

run_smoke() {
    # tiny end-to-end serve in both modes; --train-steps kept small so
    # the smoke stays fast (accuracy is not asserted here)
    for mode in continuous batch; do
        echo "== smoke: repro.launch.serve --mode $mode =="
        python -m repro.launch.serve --arch tiny --n 8 --mode "$mode" \
            --train-steps 120 --max-slots 4
    done
}

run_kernels() {
    # kernel-parity sweeps + fused-loop identity tests, then a fused
    # continuous-serve smoke with attention/confidence routed through
    # the Pallas kernels (interpret mode on CPU; real lowering on TPU
    # with REPRO_PALLAS_INTERPRET=0)
    python -m pytest -x -q tests/test_kernels.py tests/test_fused_decode.py
    echo "== smoke: repro.launch.serve --mode continuous --use-kernels =="
    python -m repro.launch.serve --arch tiny --n 4 --mode continuous \
        --train-steps 120 --max-slots 4 --use-kernels
}

run_obs() {
    # observability suite, then the tracer-overhead bench (asserts
    # tracer-on decode throughput within 5% and host_syncs_per_block
    # unchanged; the full run writes results/BENCH_obs.json)
    python -m pytest -x -q tests/test_obs.py
    echo "== bench_obs --quick =="
    python benchmarks/bench_obs.py --quick --out results/BENCH_obs_quick.json
}

run_series() {
    # time-series recorder suite: ring sampling + delta reconstruction,
    # fleet fan-in, /debug/timeline + /console round trips, strict
    # Prometheus-exposition parse of /metrics, writer-vs-reader
    # concurrency hammer
    python -m pytest -x -q tests/test_series.py
}

run_audit() {
    # quality-audit suite: shadow-auditor clean matrix + fault
    # injection (flipped token, poisoned cache chunk), SLO watchdog,
    # flight recorder, /debug/vars + /debug/flight
    python -m pytest -x -q tests/test_audit.py
}

run_gate() {
    # regenerate the quick benches into a scratch dir and gate them
    # against the committed results/ tree (git:HEAD): perf within
    # loose ratios, structural invariants (host_syncs_per_block, the
    # benches' own within_tolerance verdicts) exact
    local fresh="results/gate_fresh"
    mkdir -p "$fresh"
    python benchmarks/bench_obs.py --quick \
        --out "$fresh/BENCH_obs_quick.json"
    python benchmarks/bench_cache.py --quick \
        --out "$fresh/BENCH_cache_quick.json"
    python benchmarks/bench_disagg.py --quick \
        --out "$fresh/BENCH_disagg_quick.json"
    python scripts/bench_gate.py --fresh "$fresh" --baseline git:HEAD \
        --out results/GATE.json
    # the benches above each appended a history record; validate the
    # whole history tree against the record schema
    python scripts/perf_report.py --check
}

run_disagg() {
    # disaggregated prefill/decode pools: role-fenced stealing, the
    # prefill->decode handoff through the shared radix store (token
    # identity vs the co-located path), crash re-route, cancel races,
    # drain ordering; then the mixed-workload bench (steady decode
    # stream + Poisson long-prompt storm) comparing co-located vs
    # pooled fleets in budgeted subprocesses
    python -m pytest -x -q tests/test_disagg.py
    echo "== bench_disagg --quick =="
    python benchmarks/bench_disagg.py --quick \
        --out results/BENCH_disagg_quick.json
}

run_server() {
    # loopback HTTP/SSE tests; also part of the tier-1 suite (the file
    # lives in tests/, so the plain pytest run picks it up too)
    python -m pytest -x -q tests/test_server.py
}

run_sharded() {
    # mesh-parallel gang decode: the pytest files drive subprocesses
    # that force an 8-device host mesh (the flag must never be set in
    # the main pytest process — see tests/conftest.py). test_prewarm is
    # the recompile watchdog (zero post-warm compiles under mixed-
    # method multi-bucket load); test_steal is the work-stealing
    # identity/lifecycle suite. Then the sharded bench exercises
    # budgeted 1/2-engine routing over real sockets.
    python -m pytest -x -q tests/test_sharded_decode.py \
        tests/test_prewarm.py tests/test_steal.py
    echo "== bench_sharded --quick (8 forced host devices) =="
    # the bench composes each child's env via repro.launch.host
    # (budget_env) — don't clobber a developer's ambient XLA_FLAGS here
    python benchmarks/bench_sharded.py --quick \
        --out results/BENCH_sharded_quick.json
}

case "${1:-suite}" in
    smoke)   run_smoke ;;
    kernels) run_kernels ;;
    server)  run_server ;;
    sharded) run_sharded ;;
    disagg)  run_disagg ;;
    cache)   run_cache ;;
    obs)     run_obs ;;
    series)  run_series ;;
    audit)   run_audit ;;
    gate)    run_gate ;;
    lint)    run_lint ;;
    all)     run_suite; run_smoke ;;
    suite)   run_suite ;;
    *)       run_suite "$@" ;;
esac
