"""Benchmark regression gate: compare fresh ``BENCH_*.json`` results
against the committed baseline with per-metric tolerances.

    PYTHONPATH=src python scripts/bench_gate.py \
        --fresh results/gate_fresh [--baseline git:HEAD] \
        [--out results/GATE.json]

``--baseline`` is either a directory of baseline JSON files or
``git:REF`` (the default, ``git:HEAD``), which reads each baseline
from ``REF:results/<name>`` — so a regenerated-but-uncommitted
``results/`` tree never silently self-compares.

Every fresh file is matched to its same-named baseline, both documents
are walked recursively, and each leaf whose key appears in the RULES
table is checked:

    min_ratio r   fresh >= baseline * r      (throughput floors)
    max_ratio r   fresh <= baseline * r      (latency ceilings)
    exact         fresh == baseline          (structural invariants)
    true          fresh is truthy            (self-asserted gates;
                                              baseline value ignored)

Perf tolerances are deliberately loose (CI hosts jitter hard); the
teeth are the exact/true rules — ``host_syncs_per_block`` and the
benches' own ``within_tolerance`` verdicts, which embed the tight 5%
overhead checks measured off/on within one process. Output is
machine-readable JSON ({"pass": bool, "checks": [...]}) plus a
human summary; exit status 0 iff every check passed.
"""
from __future__ import annotations

import argparse
import fnmatch
import glob
import json
import os
import subprocess
import sys

# (metric key, rule, argument). The key matches any JSON object key at
# any depth whose value is a leaf (number/bool); the first matching
# rule wins, later entries never fire for that key.
RULES = [
    ("host_syncs_per_block", "exact", None),
    ("host_syncs_per_block_unchanged", "true", None),
    ("within_tolerance", "true", None),
    ("recompiled_after_warmup", "exact", None),
    # disaggregation gate (BENCH_disagg*.json): the bench's own
    # self-asserted verdicts — pre-warm coverage, prefill->decode
    # handoffs actually happened (and prefill engines never decoded),
    # and the decode pool's storm-window degradation stayed within the
    # co-located fleet's (with the bench's built-in noise slack)
    ("zero_post_warm_compiles", "true", None),
    ("handoffs_ok", "true", None),
    ("decode_pool_insulated", "true", None),
    ("audits_completed", "min_ratio", 1.0),   # never fewer than baseline
    ("audit_errors", "exact", None),
    ("tracer_dropped", "exact", None),
    ("throughput_tok_s", "min_ratio", 0.5),
    ("goodput_tok_s", "min_ratio", 0.5),
    ("ttfb_p50_s", "max_ratio", 2.0),
    ("ttfb_p99_s", "max_ratio", 3.0),
    ("latency_p50_s", "max_ratio", 2.0),
    ("latency_p99_s", "max_ratio", 3.0),
]


def leaves(doc, prefix=""):
    """(dotted.path, key, value) for every scalar leaf."""
    if isinstance(doc, dict):
        for k, v in doc.items():
            yield from leaves(v, f"{prefix}{k}.")
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            yield from leaves(v, f"{prefix}{i}.")
    else:
        path = prefix.rstrip(".")
        yield path, path.rsplit(".", 1)[-1], doc


def rule_for(key):
    for name, rule, arg in RULES:
        if fnmatch.fnmatch(key, name):
            return rule, arg
    return None, None


def check_pair(name, fresh_doc, base_doc):
    base = {p: v for p, _, v in leaves(base_doc)}
    out = []
    for path, key, v in leaves(fresh_doc):
        rule, arg = rule_for(key)
        if rule is None or not isinstance(v, (int, float, bool)):
            continue
        b = base.get(path)
        if rule == "true":
            ok = bool(v)
        elif b is None or not isinstance(b, (int, float, bool)):
            continue                   # new metric: nothing to gate on
        elif rule == "exact":
            ok = v == b
        elif rule == "min_ratio":
            ok = v >= b * arg
        else:                          # max_ratio
            ok = v <= b * arg
        out.append({"file": name, "path": path, "rule": rule,
                    "arg": arg, "baseline": b, "fresh": v, "ok": ok})
    return out


def load_baseline(spec, name):
    if spec.startswith("git:"):
        ref = spec[len("git:"):] or "HEAD"
        try:
            blob = subprocess.run(
                ["git", "show", f"{ref}:results/{name}"],
                capture_output=True, check=True).stdout
        except subprocess.CalledProcessError:
            return None                # not committed at that ref
        return json.loads(blob)
    path = os.path.join(spec, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory of freshly generated BENCH_*.json")
    ap.add_argument("--baseline", default="git:HEAD",
                    help="baseline directory, or git:REF to read the "
                         "committed results/ tree at REF")
    ap.add_argument("--out", default="",
                    help="write the machine-readable verdict here")
    args = ap.parse_args()

    fresh_files = sorted(glob.glob(os.path.join(args.fresh,
                                                "BENCH_*.json")))
    if not fresh_files:
        print(f"bench_gate: no BENCH_*.json under {args.fresh}")
        return 2
    checks, skipped = [], []
    for path in fresh_files:
        name = os.path.basename(path)
        with open(path) as f:
            fresh_doc = json.load(f)
        base_doc = load_baseline(args.baseline, name)
        if base_doc is None:
            skipped.append(name)
            continue
        checks.extend(check_pair(name, fresh_doc, base_doc))
    verdict = {"pass": all(c["ok"] for c in checks) and bool(checks),
               "baseline": args.baseline,
               "files": [os.path.basename(p) for p in fresh_files],
               "skipped_no_baseline": skipped,
               "checks": checks}
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(verdict, f, indent=2)
    n_bad = sum(not c["ok"] for c in checks)
    for c in checks:
        if not c["ok"]:
            print(f"FAIL {c['file']} {c['path']}: fresh={c['fresh']} "
                  f"baseline={c['baseline']} rule={c['rule']} "
                  f"arg={c['arg']}")
    for name in skipped:
        print(f"skip {name}: no baseline at {args.baseline}")
    print(f"bench_gate: {len(checks) - n_bad}/{len(checks)} checks "
          f"passed over {len(fresh_files) - len(skipped)} file(s) "
          f"-> {'PASS' if verdict['pass'] else 'FAIL'}")
    return 0 if verdict["pass"] else 1


if __name__ == "__main__":
    sys.exit(main())
