"""Benchmark regression gate: compare fresh ``BENCH_*.json`` results
against the committed baseline with per-metric tolerances.

    PYTHONPATH=src python scripts/bench_gate.py \
        --fresh results/gate_fresh [--baseline git:HEAD] \
        [--out results/GATE.json]

``--baseline`` is either a directory of baseline JSON files or
``git:REF`` (the default, ``git:HEAD``), which reads each baseline
from ``REF:results/<name>`` — so a regenerated-but-uncommitted
``results/`` tree never silently self-compares.

Every fresh file is matched to its same-named baseline, both documents
are walked recursively, and each leaf whose key appears in the RULES
table is checked:

    min_ratio r   fresh >= baseline * r      (throughput floors)
    max_ratio r   fresh <= baseline * r      (latency ceilings)
    exact         fresh == baseline          (structural invariants)
    true          fresh is truthy            (self-asserted gates;
                                              baseline value ignored)

Perf tolerances are deliberately loose (CI hosts jitter hard); the
teeth are the exact/true rules — ``host_syncs_per_block`` and the
benches' own ``within_tolerance`` verdicts, which embed the tight 5%
overhead checks measured off/on within one process. Output is
machine-readable JSON ({"pass": bool, "checks": [...]}) plus a
human summary; exit status 0 iff every check passed.

Trend rules (``--history``, default ``results/history``): alongside
the pairwise git:HEAD comparison, headline metrics are also checked
against an EWMA over the bench's append-only cross-PR history
(``results/history/<stem>.jsonl``, written by ``benchmarks/common.
append_history``). A single-PR ratio rule cannot see ten PRs each
losing 4%; the EWMA can. Trend rules fire only once a series has
``EWMA_MIN_RECORDS`` prior records, so young benches gate pairwise
only.
"""
from __future__ import annotations

import argparse
import fnmatch
import glob
import json
import os
import subprocess
import sys

# (metric key, rule, argument). The key matches any JSON object key at
# any depth whose value is a leaf (number/bool); the first matching
# rule wins, later entries never fire for that key.
RULES = [
    ("host_syncs_per_block", "exact", None),
    ("host_syncs_per_block_unchanged", "true", None),
    ("within_tolerance", "true", None),
    ("recompiled_after_warmup", "exact", None),
    # disaggregation gate (BENCH_disagg*.json): the bench's own
    # self-asserted verdicts — pre-warm coverage, prefill->decode
    # handoffs actually happened (and prefill engines never decoded),
    # and the decode pool's storm-window degradation stayed within the
    # co-located fleet's (with the bench's built-in noise slack)
    ("zero_post_warm_compiles", "true", None),
    ("handoffs_ok", "true", None),
    ("decode_pool_insulated", "true", None),
    ("audits_completed", "min_ratio", 1.0),   # never fewer than baseline
    ("audit_errors", "exact", None),
    ("tracer_dropped", "exact", None),
    ("throughput_tok_s", "min_ratio", 0.5),
    ("goodput_tok_s", "min_ratio", 0.5),
    ("ttfb_p50_s", "max_ratio", 2.0),
    ("ttfb_p99_s", "max_ratio", 3.0),
    ("latency_p50_s", "max_ratio", 2.0),
    ("latency_p99_s", "max_ratio", 3.0),
]

# EWMA drift rules over the cross-PR history. Tighter than the
# pairwise ratios on purpose: the EWMA is a smoothed consensus of many
# runs, so one noisy CI host moves it little, and slow multi-PR drift
# accumulates into it until a fresh run trips the bound.
TREND_RULES = [
    ("throughput_tok_s", "ewma_min_ratio", 0.6),
    ("goodput_tok_s", "ewma_min_ratio", 0.6),
    ("ttfb_p50_s", "ewma_max_ratio", 1.8),
    ("latency_p50_s", "ewma_max_ratio", 1.8),
    ("throughput_overhead_frac", "ewma_max_abs_delta", 0.10),
]
EWMA_ALPHA = 0.3               # weight of the newest history record
EWMA_MIN_RECORDS = 3           # don't trend-gate a young series


def leaves(doc, prefix=""):
    """(dotted.path, key, value) for every scalar leaf."""
    if isinstance(doc, dict):
        for k, v in doc.items():
            yield from leaves(v, f"{prefix}{k}.")
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            yield from leaves(v, f"{prefix}{i}.")
    else:
        path = prefix.rstrip(".")
        yield path, path.rsplit(".", 1)[-1], doc


def rule_for(key):
    for name, rule, arg in RULES:
        if fnmatch.fnmatch(key, name):
            return rule, arg
    return None, None


def check_pair(name, fresh_doc, base_doc):
    base = {p: v for p, _, v in leaves(base_doc)}
    out = []
    for path, key, v in leaves(fresh_doc):
        rule, arg = rule_for(key)
        if rule is None or not isinstance(v, (int, float, bool)):
            continue
        b = base.get(path)
        if rule == "true":
            ok = bool(v)
        elif b is None or not isinstance(b, (int, float, bool)):
            continue                   # new metric: nothing to gate on
        elif rule == "exact":
            ok = v == b
        elif rule == "min_ratio":
            ok = v >= b * arg
        else:                          # max_ratio
            ok = v <= b * arg
        out.append({"file": name, "path": path, "rule": rule,
                    "arg": arg, "baseline": b, "fresh": v, "ok": ok})
    return out


def trend_rule_for(key):
    for name, rule, arg in TREND_RULES:
        if fnmatch.fnmatch(key, name):
            return rule, arg
    return None, None


def load_history(history_dir, name):
    """Records from ``<history_dir>/<stem>.jsonl`` (``name`` is the
    BENCH file name). Append-only JSONL: a torn final line from a
    crashed run is skipped, earlier history is never at risk."""
    stem = os.path.splitext(name)[0]
    path = os.path.join(history_dir, f"{stem}.jsonl")
    if not os.path.exists(path):
        return []
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                continue               # torn tail line
    return records


def ewma(values, alpha=EWMA_ALPHA):
    acc = values[0]
    for v in values[1:]:
        acc = alpha * v + (1 - alpha) * acc
    return acc


def check_trend(name, fresh_doc, records):
    """EWMA drift checks: each headline leaf of the fresh doc vs the
    exponentially weighted mean of the same dotted path across the
    bench's history. The fresh run appends itself to history *before*
    the gate runs, so a trailing record whose metrics equal the fresh
    doc's is its own echo and is excluded — a run must not smooth the
    baseline it is judged against."""
    fresh_flat = {p: float(v) for p, _, v in leaves(fresh_doc)
                  if isinstance(v, (int, float, bool))}
    if records:
        tail = records[-1].get("metrics") or {}
        if tail and all(fresh_flat.get(k) == v for k, v in tail.items()):
            records = records[:-1]
    out = []
    for path, key, v in leaves(fresh_doc):
        rule, arg = trend_rule_for(key)
        if rule is None or isinstance(v, bool) \
                or not isinstance(v, (int, float)):
            continue
        series = [r["metrics"][path] for r in records
                  if isinstance(r.get("metrics", {}).get(path),
                                (int, float))]
        if len(series) < EWMA_MIN_RECORDS:
            continue
        base = ewma(series)
        if rule == "ewma_min_ratio":
            ok = v >= base * arg
        elif rule == "ewma_max_ratio":
            ok = v <= base * arg
        else:                          # ewma_max_abs_delta
            ok = abs(v - base) <= arg
        out.append({"file": name, "path": path, "rule": rule,
                    "arg": arg, "baseline": round(base, 6), "fresh": v,
                    "n_history": len(series), "ok": ok})
    return out


def load_baseline(spec, name):
    if spec.startswith("git:"):
        ref = spec[len("git:"):] or "HEAD"
        try:
            blob = subprocess.run(
                ["git", "show", f"{ref}:results/{name}"],
                capture_output=True, check=True).stdout
        except subprocess.CalledProcessError:
            return None                # not committed at that ref
        return json.loads(blob)
    path = os.path.join(spec, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory of freshly generated BENCH_*.json")
    ap.add_argument("--baseline", default="git:HEAD",
                    help="baseline directory, or git:REF to read the "
                         "committed results/ tree at REF")
    ap.add_argument("--out", default="",
                    help="write the machine-readable verdict here")
    ap.add_argument("--history", default="results/history",
                    help="cross-PR perf-history dir for EWMA trend "
                         "rules ('' disables trend checks)")
    args = ap.parse_args()

    fresh_files = sorted(glob.glob(os.path.join(args.fresh,
                                                "BENCH_*.json")))
    if not fresh_files:
        print(f"bench_gate: no BENCH_*.json under {args.fresh}")
        return 2
    checks, skipped, trend_checks = [], [], []
    for path in fresh_files:
        name = os.path.basename(path)
        with open(path) as f:
            fresh_doc = json.load(f)
        if args.history:
            trend_checks.extend(check_trend(
                name, fresh_doc, load_history(args.history, name)))
        base_doc = load_baseline(args.baseline, name)
        if base_doc is None:
            skipped.append(name)
            continue
        checks.extend(check_pair(name, fresh_doc, base_doc))
    checks.extend(trend_checks)
    verdict = {"pass": all(c["ok"] for c in checks) and bool(checks),
               "baseline": args.baseline,
               "history": args.history,
               "trend_checks": len(trend_checks),
               "files": [os.path.basename(p) for p in fresh_files],
               "skipped_no_baseline": skipped,
               "checks": checks}
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(verdict, f, indent=2)
    n_bad = sum(not c["ok"] for c in checks)
    for c in checks:
        if not c["ok"]:
            print(f"FAIL {c['file']} {c['path']}: fresh={c['fresh']} "
                  f"baseline={c['baseline']} rule={c['rule']} "
                  f"arg={c['arg']}")
    for name in skipped:
        print(f"skip {name}: no baseline at {args.baseline}")
    print(f"bench_gate: {len(checks) - n_bad}/{len(checks)} checks "
          f"passed ({len(trend_checks)} EWMA trend) over "
          f"{len(fresh_files) - len(skipped)} file(s) "
          f"-> {'PASS' if verdict['pass'] else 'FAIL'}")
    return 0 if verdict["pass"] else 1


if __name__ == "__main__":
    sys.exit(main())
