"""Render the cross-PR perf trajectory to ``docs/PERF.md``.

    PYTHONPATH=src python scripts/perf_report.py \
        [--history results/history] [--out docs/PERF.md] \
        [--html docs/PERF.html] [--check]

Input is the append-only history written by ``benchmarks.common.
append_history`` — one JSONL file per bench series under
``results/history/``, one record per run (commit, timestamp, config
hash, every numeric leaf of the result doc under its bench_gate dotted
path). Output:

* ``docs/PERF.md`` — per-bench tables of the headline metrics' recent
  trajectory with unicode sparklines, newest run last, commits linked
  by short hash so a regression is one ``git show`` away.
* ``--html`` — optional standalone HTML with inline SVG sparklines
  (zero external deps, same discipline as ``GET /console``).
* ``--check`` — validate every history record against the schema
  (required keys, metrics all numeric, parseable lines) and exit
  non-zero on violations without writing anything. ``scripts/test.sh
  gate`` runs this over the fresh records each gate pass.

Headline selection: a curated key list first (throughput, latency,
overhead verdict inputs), then whatever else the series carries, capped
per bench so the report stays readable.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# dotted-leaf suffixes promoted to the top of each bench's table, in
# this order; everything else is alphabetical below the fold
HEADLINE_SUFFIXES = (
    "throughput_tok_s", "goodput_tok_s", "tok_s",
    "ttfb_p50_s", "ttfb_p99_s", "latency_p50_s", "latency_p99_s",
    "host_syncs_per_block", "throughput_overhead_frac",
    "geomean_speedup", "ttfb_speedup_p50", "hit_rate",
)
MAX_METRICS_PER_BENCH = 16
MAX_RUNS_SHOWN = 12
SPARK_CHARS = "▁▂▃▄▅▆▇█"

REQUIRED_KEYS = ("bench", "commit", "ts", "config_hash", "metrics")


def load_series(history_dir):
    """{bench: [records]} for every ``*.jsonl`` under the history dir,
    oldest first (file order — the files are append-only)."""
    series = {}
    for path in sorted(glob.glob(os.path.join(history_dir, "*.jsonl"))):
        bench = os.path.splitext(os.path.basename(path))[0]
        records = []
        with open(path) as f:
            for i, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    records.append((i, json.loads(line)))
                except json.JSONDecodeError:
                    records.append((i, None))    # torn/corrupt line
        series[bench] = records
    return series


def check_schema(series) -> list:
    """Schema violations as ``(file, line, problem)`` rows. A corrupt
    *final* line is tolerated (a crashed run's torn tail is the
    documented failure mode); anywhere else it is a violation."""
    problems = []
    for bench, records in series.items():
        for idx, (lineno, rec) in enumerate(records):
            where = f"{bench}.jsonl:{lineno}"
            if rec is None:
                if idx != len(records) - 1:
                    problems.append((where, "unparseable non-final line"))
                continue
            for key in REQUIRED_KEYS:
                if key not in rec:
                    problems.append((where, f"missing key {key!r}"))
            metrics = rec.get("metrics")
            if not isinstance(metrics, dict) or not metrics:
                problems.append((where, "metrics missing or empty"))
                continue
            for k, v in metrics.items():
                if not isinstance(v, (int, float)) \
                        or isinstance(v, bool):
                    problems.append(
                        (where, f"non-numeric metric {k!r}: {v!r}"))
    return problems


def select_metrics(records):
    """Ordered metric paths for one bench: headline suffixes first,
    then alphabetical, capped."""
    seen = {}
    for _, rec in records:
        if rec:
            for k in rec.get("metrics", {}):
                seen.setdefault(k, True)
    def rank(path):
        leaf = path.rsplit(".", 1)[-1]
        try:
            return (0, HEADLINE_SUFFIXES.index(leaf), path)
        except ValueError:
            return (1, 0, path)
    return sorted(seen, key=rank)[:MAX_METRICS_PER_BENCH]


def values_for(records, path):
    out = []
    for _, rec in records:
        v = (rec or {}).get("metrics", {}).get(path)
        out.append(float(v) if isinstance(v, (int, float))
                   and not isinstance(v, bool) else None)
    return out


def sparkline(vals) -> str:
    nums = [v for v in vals if v is not None]
    if not nums:
        return ""
    lo, hi = min(nums), max(nums)
    span = hi - lo
    out = []
    for v in vals:
        if v is None:
            out.append("·")
        elif span <= 0:
            out.append(SPARK_CHARS[3])
        else:
            i = int((v - lo) / span * (len(SPARK_CHARS) - 1))
            out.append(SPARK_CHARS[i])
    return "".join(out)


def fmt(v) -> str:
    if v is None:
        return "–"
    if v == int(v) and abs(v) < 1e9:
        return str(int(v))
    return f"{v:.4g}"


def render_md(series) -> str:
    lines = ["# Perf trajectory", "",
             "Cross-PR benchmark history, one series per "
             "`results/history/*.jsonl` (appended by every bench run; "
             "see `benchmarks/common.append_history`). Regenerate with "
             "`python scripts/perf_report.py`. Sparklines span the "
             "series min→max; the table shows the most recent "
             f"{MAX_RUNS_SHOWN} runs, newest last.", ""]
    for bench in sorted(series):
        records = [(ln, r) for ln, r in series[bench] if r]
        if not records:
            continue
        shown = records[-MAX_RUNS_SHOWN:]
        lines.append(f"## {bench}")
        lines.append("")
        commits = [r.get("commit") or "?" for _, r in shown]
        hashes = [r.get("config_hash", "")[:6] for _, r in shown]
        lines.append(f"{len(records)} run(s) · commits "
                     f"{commits[0]} → {commits[-1]} · config "
                     + ("stable" if len(set(hashes)) == 1
                        else f"{len(set(hashes))} variants"))
        lines.append("")
        lines.append("| metric | trend | " +
                     " | ".join(c or "?" for c in commits) + " |")
        lines.append("|---|---|" + "---|" * len(shown))
        for path in select_metrics(records):
            vals = values_for(records, path)
            recent = vals[-len(shown):]
            lines.append(f"| `{path}` | {sparkline(vals)} | "
                         + " | ".join(fmt(v) for v in recent) + " |")
        lines.append("")
    return "\n".join(lines) + "\n"


def _svg_spark(vals, w=240, h=36) -> str:
    nums = [(i, v) for i, v in enumerate(vals) if v is not None]
    if not nums:
        return f'<svg width="{w}" height="{h}"></svg>'
    lo = min(v for _, v in nums)
    hi = max(v for _, v in nums)
    span = (hi - lo) or 1.0
    n = max(len(vals) - 1, 1)
    pts = " ".join(
        f"{2 + i * (w - 4) / n:.1f},"
        f"{h - 4 - (v - lo) / span * (h - 8):.1f}" for i, v in nums)
    return (f'<svg width="{w}" height="{h}">'
            f'<polyline fill="none" stroke="#2f81f7" stroke-width="1.5" '
            f'points="{pts}"/></svg>')


def render_html(series) -> str:
    rows = ["<!doctype html><meta charset='utf-8'>"
            "<title>repro perf trajectory</title>"
            "<style>body{font:14px ui-monospace,monospace;margin:2em;"
            "color:#222}table{border-collapse:collapse}"
            "td,th{border:1px solid #ddd;padding:4px 8px;"
            "text-align:right}td:first-child{text-align:left}</style>",
            "<h1>repro perf trajectory</h1>"]
    for bench in sorted(series):
        records = [(ln, r) for ln, r in series[bench] if r]
        if not records:
            continue
        rows.append(f"<h2>{bench}</h2><table>"
                    "<tr><th>metric</th><th>trend</th>"
                    "<th>latest</th></tr>")
        for path in select_metrics(records):
            vals = values_for(records, path)
            last = next((v for v in reversed(vals) if v is not None),
                        None)
            rows.append(f"<tr><td>{path}</td><td>{_svg_spark(vals)}"
                        f"</td><td>{fmt(last)}</td></tr>")
        rows.append("</table>")
    return "\n".join(rows) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--history", default="results/history")
    ap.add_argument("--out", default="docs/PERF.md")
    ap.add_argument("--html", default="",
                    help="also write a standalone HTML report here")
    ap.add_argument("--check", action="store_true",
                    help="validate history-record schema only; write "
                         "nothing, exit 1 on violations")
    args = ap.parse_args()

    series = load_series(args.history)
    if not series:
        print(f"perf_report: no *.jsonl under {args.history}"
              + (" (ok)" if args.check else ""))
        return 0 if args.check else 1

    if args.check:
        problems = check_schema(series)
        for where, what in problems:
            print(f"BAD {where}: {what}")
        n = sum(len([r for _, r in recs if r])
                for recs in series.values())
        print(f"perf_report --check: {len(series)} series, {n} "
              f"record(s), {len(problems)} problem(s)")
        return 1 if problems else 0

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(render_md(series))
    print(f"wrote {args.out}")
    if args.html:
        os.makedirs(os.path.dirname(args.html) or ".", exist_ok=True)
        with open(args.html, "w") as f:
            f.write(render_html(series))
        print(f"wrote {args.html}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
