"""Property tests (hypothesis) for the temporal component: Eq. 9/10."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.schedule import (confidence_and_tokens, dynamic_threshold,
                                 fixed_rate_select, select_tokens)


@given(st.floats(0.5, 1.0), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_threshold_bounds(tau0, alpha, r_mask):
    tau = float(dynamic_threshold(tau0, alpha, jnp.asarray(r_mask)))
    assert tau0 * (1 - alpha) - 1e-6 <= tau <= tau0 + 1e-6


@given(st.floats(0.5, 1.0), st.floats(0.0, 1.0),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_threshold_monotone_in_rmask(tau0, alpha, r1, r2):
    """More masked tokens -> stricter threshold (paper's design intent)."""
    lo, hi = sorted([r1, r2])
    t_lo = float(dynamic_threshold(tau0, alpha, jnp.asarray(lo)))
    t_hi = float(dynamic_threshold(tau0, alpha, jnp.asarray(hi)))
    assert t_lo <= t_hi + 1e-6


@settings(deadline=None, max_examples=50)
@given(st.integers(1, 4), st.integers(1, 16), st.data())
def test_select_always_progresses(B, K, data):
    """Eq. 9: any row with >=1 masked token commits >=1 token."""
    conf = np.array(data.draw(st.lists(
        st.lists(st.floats(0, 1), min_size=K, max_size=K),
        min_size=B, max_size=B)), np.float32)
    masked = np.array(data.draw(st.lists(
        st.lists(st.booleans(), min_size=K, max_size=K),
        min_size=B, max_size=B)))
    tau = data.draw(st.floats(0.1, 1.0))
    commit = np.asarray(select_tokens(jnp.asarray(conf), jnp.asarray(masked),
                                      jnp.asarray(tau)))
    for b in range(B):
        assert not (commit[b] & ~masked[b]).any()      # only masked commit
        if masked[b].any():
            assert commit[b].any()                      # progress guarantee
        else:
            assert not commit[b].any()


def test_select_threshold_semantics():
    conf = jnp.asarray([[0.95, 0.5, 0.92, 0.1]])
    masked = jnp.asarray([[True, True, True, False]])
    commit = np.asarray(select_tokens(conf, masked, jnp.asarray(0.9)))
    assert commit.tolist() == [[True, False, True, False]]


def test_select_fallback_argmax():
    conf = jnp.asarray([[0.3, 0.6, 0.5, 0.99]])
    masked = jnp.asarray([[True, True, True, False]])  # 0.99 not masked
    commit = np.asarray(select_tokens(conf, masked, jnp.asarray(0.9)))
    assert commit.tolist() == [[False, True, False, False]]


@given(st.integers(1, 8))
def test_fixed_rate_commits_exactly_n(n):
    conf = jnp.asarray(np.random.default_rng(0).uniform(size=(2, 16)),
                       jnp.float32)
    masked = jnp.ones((2, 16), bool)
    commit = np.asarray(fixed_rate_select(conf, masked, n))
    assert (commit.sum(1) == min(n, 16)).all()


def test_confidence_is_max_softmax():
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(3, 7, 50)),
                         jnp.float32)
    conf, toks = confidence_and_tokens(logits)
    probs = np.array(jnp.exp(logits - jnp.max(logits, -1, keepdims=True)))
    probs /= probs.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(conf), probs.max(-1), atol=1e-6)
    assert (np.asarray(toks) == probs.argmax(-1)).all()
