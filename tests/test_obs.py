"""Observability layer tests (`repro.obs`): tracer ring buffers and
Chrome-trace export, request span-tree well-formedness across the
request lifecycle (finish, cancel, preempt/resume, deadline), per-block
decode telemetry invariants for every method (fused and host loops,
with zero extra host syncs), ServeMetrics thread-safety under a
decode-thread/scrape-thread hammer, Prometheus histogram exposition,
and structured JSON logging."""
import asyncio
import contextlib
import io
import json
import logging
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.decoder import DecodeConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.obs.log import get_logger, setup_logging
from repro.obs.metrics import Histogram, device_memory_stats
from repro.obs.telemetry import (CONF_BUCKETS, BlockStats,
                                 TelemetryAggregator)
from repro.obs.trace import Tracer, request_tree, span
from repro.serving import ContinuousEngine
from repro.serving.metrics import RequestMetrics, ServeMetrics

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
TOK = ByteTokenizer(CFG.vocab_size)
PROMPT = "Q:12+34=? A:"
TEST_TIMEOUT_S = 300
METHODS = ["vanilla", "dkv", "prefix", "fast", "streaming"]


def _dcfg(method="streaming", gen_len=16, fused=True):
    return DecodeConfig(method=method, gen_len=gen_len, block_size=8,
                        window=4, tau0=0.5, fused=fused)


def _engine(method="streaming", gen_len=16, fused=True, max_slots=4,
            tracer=None):
    return ContinuousEngine(CFG, PARAMS, _dcfg(method, gen_len, fused),
                            max_slots=max_slots, tokenizer=TOK,
                            tracer=tracer)


def _run(coro):
    asyncio.run(asyncio.wait_for(coro, TEST_TIMEOUT_S))


# ------------------------------------------------------------ tracer core


def test_tracer_complete_events_and_clock():
    tr = Tracer()
    with tr.span("work", pid=0, tag="x"):
        time.sleep(0.002)
    evs = [e for e in tr.events() if e.get("ph") == "X"]
    assert len(evs) == 1
    ev = evs[0]
    assert ev["name"] == "work"
    assert ev["args"] == {"tag": "x"}
    assert ev["dur"] >= 1500                 # >= 1.5ms in microseconds
    assert ev["ts"] >= 0                     # monotonic since birth


def test_tracer_null_span_helper():
    with span(None, "ignored"):              # tracer off: no-op context
        pass
    tr = Tracer()
    with span(tr, "kept"):
        pass
    assert any(e.get("name") == "kept" for e in tr.events())


def test_tracer_ring_capacity_drops_oldest():
    tr = Tracer(capacity_per_thread=8)
    for i in range(20):
        tr.instant(f"ev{i}")
    evs = [e for e in tr.events() if e.get("ph") == "i"]
    assert len(evs) == 8
    assert evs[-1]["name"] == "ev19"         # newest kept
    assert tr.dropped == 12                  # oldest evicted


def test_trace_ids_unique():
    tr = Tracer()
    ids = {tr.new_trace_id() for _ in range(100)}
    assert len(ids) == 100


def test_request_tree_nesting_and_errors():
    tr = Tracer()
    tid = tr.new_trace_id()
    t = time.perf_counter_ns()
    tr.async_begin(tid, "request", t_ns=t)
    tr.async_begin(tid, "queue", t_ns=t + 10)
    tr.async_end(tid, "queue", t_ns=t + 20)
    tr.async_begin(tid, "decode", t_ns=t + 20)   # ties: e before b
    tr.async_end(tid, "decode", t_ns=t + 50)
    tr.async_end(tid, "request", t_ns=t + 60)
    tree = request_tree(tr.request_events(tid))
    assert [(name, depth) for name, depth, _, _ in tree] == \
        [("request", 0), ("queue", 1), ("decode", 1)]
    assert all(dur is not None for _, _, _, dur in tree)
    with pytest.raises(ValueError):          # unclosed span
        request_tree([{"ph": "b", "name": "a", "ts": 1.0}])
    with pytest.raises(ValueError):          # end without begin
        request_tree([{"ph": "e", "name": "a", "ts": 1.0}])


def test_chrome_trace_export_schema(tmp_path):
    tr = Tracer()
    pid = tr.process("engine-0")
    tr.name_thread("decode", pid=pid)
    with tr.span("block", pid=pid):
        pass
    tid = tr.new_trace_id()
    t = time.perf_counter_ns()
    tr.async_span(tid, "request", t, t + 1000, pid=pid)
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    phases = {"M", "X", "b", "e", "i"}
    for e in evs:
        assert e["ph"] in phases
        assert isinstance(e["name"], str)
        assert isinstance(e["pid"], int)
        if e["ph"] != "M":
            assert isinstance(e["ts"], float)
        if e["ph"] == "X":
            assert e["dur"] > 0
        if e["ph"] in ("b", "e"):
            assert e["cat"] == "request" and e["id"] == tid
    # metadata first: process/thread names precede all timed events
    kinds = [e["ph"] for e in evs]
    assert kinds[: kinds.count("M")] == ["M"] * kinds.count("M")
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert {"frontend", "engine-0", "decode"} <= names


def test_trace_flusher_periodic_and_final(tmp_path):
    from repro.obs.trace import TraceFlusher

    tr = Tracer()
    path = str(tmp_path / "trace.json")
    fl = TraceFlusher(tr, path, interval_s=0.05).start()
    with tr.span("early"):
        pass
    deadline = time.time() + 5.0
    while fl.flushes == 0 and time.time() < deadline:
        time.sleep(0.02)
    assert fl.flushes >= 1
    early = json.loads(open(path).read())["traceEvents"]
    assert any(e.get("name") == "early" for e in early)
    with tr.span("late"):
        pass
    fl.stop()  # final_flush=True picks up spans after the last tick
    assert not fl._thread.is_alive()
    late = json.loads(open(path).read())["traceEvents"]
    assert any(e.get("name") == "late" for e in late)


def test_trace_flusher_stop_without_final_flush(tmp_path):
    from repro.obs.trace import TraceFlusher

    tr = Tracer()
    path = str(tmp_path / "trace.json")
    fl = TraceFlusher(tr, path, interval_s=60.0).start()
    with tr.span("never-flushed"):
        pass
    fl.stop(final_flush=False)
    assert not fl._thread.is_alive()
    import os
    assert not os.path.exists(path)  # no tick fired, no final write


# ------------------------------------------------- span trees (lifecycle)


def _finish_tree(tracer, trace_id):
    """The request's rebuilt span tree (raises if malformed)."""
    return request_tree(tracer.request_events(trace_id))


def test_span_tree_normal_lifecycle():
    tr = Tracer()
    eng = _engine()
    eng.set_tracer(tr, "engine-0")
    tid = tr.new_trace_id()
    eng.submit(PROMPT, max_tokens=13, trace_id=tid)
    eng.run_to_completion()
    tree = _finish_tree(tr, tid)
    names = [name for name, _, _, _ in tree]
    assert names[0] == "request"
    assert "queue" in names and "decode" in names
    blocks = [n for n in names if n.startswith("block ")]
    assert blocks == ["block 0", "block 1"]  # 16 gen tokens / 8
    depth = dict((n, d) for n, d, _, _ in tree)
    assert depth["queue"] == 1 and depth["decode"] == 1
    assert depth["block 0"] == 2             # nested under decode


def test_span_tree_cancel_while_waiting():
    tr = Tracer()
    eng = _engine(max_slots=1)
    eng.set_tracer(tr, "engine-0")
    t1 = tr.new_trace_id()
    t2 = tr.new_trace_id()
    eng.submit(PROMPT, max_tokens=13, trace_id=t1)
    u2 = eng.submit(PROMPT, max_tokens=13, trace_id=t2)
    eng.step()                               # admits only the first
    comp = eng.cancel(u2)                    # still waiting
    assert comp is not None and comp.cancelled
    eng.run_to_completion()
    tree = _finish_tree(tr, t2)              # well-formed despite cancel
    names = [n for n, _, _, _ in tree]
    assert names[0] == "request" and "decode" not in names
    _finish_tree(tr, t1)                     # survivor unaffected


def test_span_tree_cancel_while_active():
    tr = Tracer()
    eng = _engine(gen_len=32)
    eng.set_tracer(tr, "engine-0")
    tid = tr.new_trace_id()
    uid = eng.submit(PROMPT, max_tokens=32, trace_id=tid)
    eng.step()                               # first block decodes
    assert eng.cancel(uid) is None           # active: finishes next tick
    eng.run_to_completion()
    tree = _finish_tree(tr, tid)
    names = [n for n, _, _, _ in tree]
    assert "decode" in names                 # opened AND closed


def test_span_tree_preempt_resume():
    tr = Tracer()
    eng = _engine(gen_len=32)
    eng.set_tracer(tr, "engine-0")
    tid = tr.new_trace_id()
    uid = eng.submit(PROMPT, max_tokens=32, trace_id=tid)
    eng.step()
    eng.preempt(uid)                         # park at block boundary
    eng.run_to_completion()                  # resumes and finishes
    tree = _finish_tree(tr, tid)
    decodes = [n for n, _, _, _ in tree if n == "decode"]
    assert len(decodes) == 2                 # one per residency
    evs = tr.request_events(tid)
    assert evs[0]["name"] == "request"
    assert evs[-1]["name"] == "request"      # outermost closes last


def test_span_tree_deadline_via_engine_loop():
    from repro.server import EngineLoop, ServerRequest
    tr = Tracer()
    eng = _engine(gen_len=32)
    loop = EngineLoop(eng, idle_poll_s=0.005, tracer=tr, index=0)
    loop.start()
    done = threading.Event()
    out = {}

    def deliver(event):
        kind, payload = event
        if kind == "done":
            out["comp"] = payload
            done.set()

    ticket = loop.submit(ServerRequest(prompt=PROMPT, max_tokens=32,
                                       timeout_s=0.05), deliver)
    assert ticket.trace_id
    assert done.wait(TEST_TIMEOUT_S)
    loop.close(drain=True)
    assert out["comp"].cancelled
    assert ticket.cancel_reason == "deadline"
    _finish_tree(tr, ticket.trace_id)        # tree balanced after expiry


# ------------------------------------------------- per-block telemetry


@pytest.mark.parametrize("method", METHODS)
def test_block_stats_consistency(method):
    """sum(committed_per_step) + straggler_fill == live_rows * K for
    every decoded block, and the confidence histogram counts exactly
    the step-committed tokens."""
    eng = _engine(method)
    eng.submit(PROMPT, max_tokens=16)
    eng.run_to_completion()
    summ = eng.telemetry.summary()
    assert summ, "telemetry must populate"
    K = eng.dcfg.block_size
    total_tokens = 0
    for key, row in summ.items():
        assert key.startswith(f"{method}/")
        assert row["blocks"] == 1
        committed = sum(row["committed_per_step"]) + row["straggler_fill"]
        assert committed == 1 * K            # one live row per block
        assert sum(row["conf_hist"]) == sum(row["committed_per_step"])
        assert len(row["conf_hist"]) == CONF_BUCKETS
        assert 0 < row["steps_mean"] <= row["steps_cap_mean"]
        total_tokens += committed
    assert total_tokens == 16
    tot = eng.telemetry.totals()
    assert tot["blocks"] == 2
    assert 0.0 <= tot["steps_saved_frac"] < 1.0


def test_telemetry_zero_extra_host_syncs():
    """Acceptance: telemetry rides the fused loop's single per-block
    sync — host_syncs_per_block stays exactly 1."""
    eng = _engine()
    eng.submit(PROMPT, max_tokens=16)
    eng.run_to_completion()
    snap = eng.metrics.snapshot()
    assert snap["host_syncs_per_block"] == 1.0
    assert eng.telemetry.blocks == 2         # and telemetry still filled


def test_fused_host_telemetry_parity():
    """The fused loop's in-carry tallies agree with the host loop's
    directly-measured ones on identical work."""
    rows = {}
    for fused in (True, False):
        eng = _engine(fused=fused)
        eng.submit(PROMPT, max_tokens=16)
        eng.run_to_completion()
        rows[fused] = eng.telemetry.summary()
    assert rows[True].keys() == rows[False].keys()
    for key in rows[True]:
        f, h = rows[True][key], rows[False][key]
        assert f["committed_per_step"] == h["committed_per_step"], key
        assert f["straggler_fill"] == h["straggler_fill"], key
        l1 = sum(abs(a - b) for a, b in zip(f["conf_hist"],
                                            h["conf_hist"]))
        assert l1 <= 4, (key, f["conf_hist"], h["conf_hist"])


def test_telemetry_aggregator_accumulates():
    agg = TelemetryAggregator()
    bs = BlockStats(method="streaming", block_idx=0, batch=2, live_rows=2,
                    steps=3, steps_cap=8, committed_per_step=[10, 4, 2],
                    straggler_fill=0, conf_hist=[0] * 9 + [16], window=4,
                    early_exits=2, wall_s=0.5)
    agg.add(bs)
    agg.add(bs)
    assert bs.tokens_committed == 16 and bs.nfe == 6
    row = agg.summary()["streaming/0"]
    assert row["blocks"] == 2
    assert row["committed_per_step"] == [20, 8, 4]
    tot = agg.totals()
    assert tot["tokens"] == 32
    assert tot["steps_saved_frac"] == pytest.approx(1 - 6 / 16)


# ------------------------------------------------- ServeMetrics safety


def test_serve_metrics_thread_safety_hammer():
    """Regression: the decode thread mutates while the asyncio thread
    scrapes — snapshots must never crash or tear (requests list length
    vs aggregate counters computed from it)."""
    m = ServeMetrics(max_slots=4)
    N = 3000
    stop = threading.Event()
    errors = []

    def writer():
        for i in range(N):
            m.add_request(RequestMetrics(
                uid=i, queue_s=0.001, ttfb_s=0.01, latency_s=0.1,
                n_tokens=8, nfe=16, n_blocks=1, host_syncs=1))
            m.sample_tick(2, 0.001)
        stop.set()

    def reader():
        while not stop.is_set():
            try:
                snap = m.snapshot()
                # internally consistent: derived values match the copy
                assert snap["requests"] >= 0
                assert snap["tokens"] == snap["requests"] * 8
                _ = m.throughput, m.mean_occupancy, m.total_blocks
            except Exception as e:           # pragma: no cover
                errors.append(e)
                return

    threads = [threading.Thread(target=writer)] + \
        [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert not errors
    snap = m.snapshot()
    assert snap["requests"] == N
    assert m.hist_ttfb.count == N


# ------------------------------------------------- histograms / metrics


def test_histogram_buckets_sum_count():
    h = Histogram("x_seconds", "test", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    counts, s, n = h.snapshot()
    assert counts == [1, 1, 1, 1]            # one per bucket + +Inf
    assert n == 4 and s == pytest.approx(55.55)
    lines = h.prometheus()
    assert 'x_seconds_bucket{le="0.1"} 1' in lines
    assert 'x_seconds_bucket{le="1.0"} 2' in lines      # cumulative
    assert 'x_seconds_bucket{le="+Inf"} 4' in lines
    assert any(line.startswith("x_seconds_count 4") for line in lines)
    labeled = h.prometheus('engine="1"')
    assert 'x_seconds_bucket{engine="1",le="0.1"} 1' in labeled


def test_histogram_merge_requires_same_bounds():
    a = Histogram("x", "t", buckets=(1.0, 2.0))
    b = Histogram("x", "t", buckets=(1.0, 2.0))
    a.observe(0.5)
    b.observe(1.5)
    a.merge(b)
    counts, _, n = a.snapshot()
    assert counts == [1, 1, 0] and n == 2
    with pytest.raises(ValueError):
        a.merge(Histogram("x", "t", buckets=(5.0,)))


def test_device_memory_stats_cpu_safe():
    assert device_memory_stats() == {}       # CPU backend: empty, no raise


# ------------------------------------------------- structured logging


def test_json_logging_fields():
    buf = io.StringIO()
    setup_logging(level="debug", json_mode=True, stream=buf)
    log = get_logger("repro.test.obs")
    log.info("block decoded", extra={"uid": 7, "engine": 0,
                                     "gang": [7, 8], "trace_id": "t-1"})
    line = buf.getvalue().strip()
    doc = json.loads(line)
    assert doc["msg"] == "block decoded"
    assert doc["level"] == "INFO"
    assert doc["logger"] == "repro.test.obs"
    assert doc["uid"] == 7 and doc["engine"] == 0
    assert doc["gang"] == [7, 8] and doc["trace_id"] == "t-1"
    # reconfigure to text: handler replaced, not stacked
    buf2 = io.StringIO()
    setup_logging(level="info", json_mode=False, stream=buf2)
    assert len(logging.getLogger("repro").handlers) == 1
    log.info("plain", extra={"uid": 9})
    assert "plain" in buf2.getvalue() and "uid=9" in buf2.getvalue()
    setup_logging(level="warning", stream=io.StringIO())  # quiet again


def test_library_loggers_under_repro_namespace():
    from repro.server import http, loop, router
    for mod in (http, loop, router):
        assert mod.log.name.startswith("repro.")


# ------------------------------------------------- HTTP integration


@contextlib.asynccontextmanager
async def _traced_server(**kw):
    from repro.server import EngineLoop
    from repro.server.http import HttpFrontend
    tr = Tracer()
    eng = _engine(tracer=None, **kw)
    loop = EngineLoop(eng, max_pending=16, idle_poll_s=0.005,
                      tracer=tr, index=0)
    frontend = await HttpFrontend(loop, port=0, tracer=tr).start()
    try:
        yield frontend, eng, tr
    finally:
        await frontend.shutdown(drain=True, timeout_s=30)


def test_http_trace_header_and_block():
    from repro.server import client as C

    async def main():
        async with _traced_server() as (fe, eng, tr):
            status, headers, doc = await C.complete(
                fe.host, fe.port,
                {"prompt": PROMPT, "max_tokens": 13, "trace": True})
            assert status == 200
            tid = headers["x-repro-trace-id"]
            assert tid and doc["trace_id"] == tid
            evs = doc["trace"]["events"]
            assert evs and all(e["id"] == tid for e in evs)
            names = {e["name"] for e in evs}
            assert {"http", "request", "queue", "decode"} <= names
            # opt-out: no trace block, header still present
            status, headers2, doc2 = await C.complete(
                fe.host, fe.port, {"prompt": PROMPT, "max_tokens": 13})
            assert "trace" not in doc2
            assert headers2["x-repro-trace-id"] == doc2["trace_id"]
        # after drain: full tree incl. the http span is well-formed
        tree = request_tree(tr.request_events(tid))
        names = [n for n, _, _, _ in tree]
        assert names[0] == "http"
        assert names[1] == "request"
    _run(main())


def test_http_untraced_server_has_no_trace_fields():
    from repro.server import EngineLoop
    from repro.server import client as C
    from repro.server.http import HttpFrontend

    async def main():
        eng = _engine()
        loop = EngineLoop(eng, max_pending=16, idle_poll_s=0.005)
        fe = await HttpFrontend(loop, port=0).start()
        try:
            status, headers, doc = await C.complete(
                fe.host, fe.port,
                {"prompt": PROMPT, "max_tokens": 13, "trace": True})
            assert status == 200
            assert "x-repro-trace-id" not in headers
            assert "trace_id" not in doc and "trace" not in doc
        finally:
            await fe.shutdown(drain=True, timeout_s=30)
    _run(main())


def test_server_request_validates_trace_flag():
    from repro.server.types import BadRequest, ServerRequest
    assert ServerRequest.from_json(
        {"prompt": "x", "trace": True}).trace is True
    assert ServerRequest.from_json({"prompt": "x"}).trace is False
    with pytest.raises(BadRequest):
        ServerRequest.from_json({"prompt": "x", "trace": 1})


def test_metrics_exposition_histograms_and_telemetry():
    from repro.server import EngineLoop
    from repro.server.http import HttpFrontend
    eng = _engine()
    eng.submit(PROMPT, max_tokens=16)
    eng.run_to_completion()
    text = HttpFrontend(EngineLoop(eng))._metrics_text()
    for family in ("repro_ttfb_seconds", "repro_queue_wait_seconds",
                   "repro_block_wall_seconds", "repro_nfe_per_token"):
        assert f"{family}_bucket" in text
        assert f"{family}_count" in text
    assert "repro_decode_blocks_total 2" in text
    assert "repro_decode_steps_total" in text
    assert "repro_decode_confidence_total" in text
    assert 'bucket="0.9-1.0"' in text
    # exposition parses: every non-comment line is "name{labels} value"
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name_part, _, value = line.rpartition(" ")
        float(value)
        assert name_part
