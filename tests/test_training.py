"""Training substrate: optimizer math, loss behaviour, checkpointing,
data pipeline determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.synthetic import ArithmeticDataset, exact_match, make_sample
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.training import checkpoint
from repro.training.loss import chunked_ce, diffusion_loss
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      lr_schedule)
from repro.training.train import TrainConfig, train


def test_adamw_matches_reference_scalar():
    """One param, two steps, vs hand-computed AdamW."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=0.0, warmup_steps=0, total_steps=10**9,
                      min_lr_frac=1.0)
    p = {"w": jnp.asarray([2.0])}
    st_ = adamw_init(cfg, p)
    g = {"w": jnp.asarray([0.5])}
    p1, st1, _ = adamw_update(cfg, g, st_, p)
    m1, v1 = 0.1 * 0.5, 0.01 * 0.25
    upd = (m1 / (1 - 0.9)) / (np.sqrt(v1 / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), 2.0 - 0.1 * upd,
                               rtol=1e-5)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, min_lr_frac=1.0)
    p = {"w": jnp.zeros((4,))}
    st_ = adamw_init(cfg, p)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(cfg, g, st_, p)
    assert float(m["grad_norm"]) > 1e6 - 1  # reported pre-clip


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0 and lrs[1] == 0.5
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2] and abs(lrs[4] - 0.1) < 1e-6


def test_chunked_ce_matches_direct():
    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 37  # not a multiple of the chunk -> exercises padding
    hidden = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (B, S)))
    nll, correct = chunked_ce(cfg, params, hidden, tokens, w, chunk=16)
    logits = hidden @ params["lm_head"]
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), -1)
    tl = jnp.take_along_axis(logits.astype(jnp.float32),
                             tokens[..., None], -1)[..., 0]
    want = ((lse - tl) * w).sum()
    np.testing.assert_allclose(float(nll), float(want), rtol=1e-4)


def test_diffusion_loss_masks_only_loss_region():
    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0, 200)
    lm = jnp.zeros((4, 24), bool).at[:, 12:].set(True)
    loss, m = diffusion_loss(cfg, params, toks, lm, jax.random.PRNGKey(2))
    assert bool(jnp.isfinite(loss)) and int(m["n_masked"]) >= 4


def test_loss_decreases_fast():
    cfg = get_config("tiny")
    params, hist = train(cfg, TrainConfig(steps=40, batch_size=16,
                                          seq_len=28, log_every=39),
                         verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_roundtrip():
    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        checkpoint.save(path, params, {"note": "x"})
        zeros = jax.tree.map(jnp.zeros_like, params)
        back = checkpoint.restore(path, zeros)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert checkpoint.load_metadata(path)["note"] == "x"


# ------------------------------------------------------------- data

def test_tokenizer_roundtrip():
    tok = ByteTokenizer(320)
    s = "Q:12+34=? A:46"
    assert tok.decode(tok.encode(s)) == s
    assert tok.decode(tok.encode(s, add_eos=True)) == s


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 2**31 - 1))
def test_samples_are_correct_arithmetic(seed):
    rng = np.random.default_rng(seed)
    s = make_sample(rng, 99)
    expr = s.prompt[2:s.prompt.index("=")]
    op = "+" if "+" in expr else "-"
    a, b = expr.split(op)
    val = int(a) + int(b) if op == "+" else int(a) - int(b)
    assert str(val) == s.answer


def test_dataset_deterministic():
    tok = ByteTokenizer(320)
    ds1 = ArithmeticDataset(tok, seq_len=28, seed=5)
    ds2 = ArithmeticDataset(tok, seq_len=28, seed=5)
    b1, b2 = ds1.batch(3, 8), ds2.batch(3, 8)
    assert (b1.tokens == b2.tokens).all()
    assert (b1.loss_mask == b2.loss_mask).all()
    b3 = ds1.batch(4, 8)
    assert not (b1.tokens == b3.tokens).all()


def test_eval_exact_match_metric():
    tok = ByteTokenizer(320)
    ds = ArithmeticDataset(tok, seq_len=28)
    samples = ds.eval_set(4)
    perfect = np.stack([
        np.pad(tok.encode(s.answer, add_eos=True), (0, 16))[:16]
        for s in samples])
    assert exact_match(tok, perfect, samples) == 1.0
    wrong = np.full((4, 16), ord("z"), np.int32)
    assert exact_match(tok, wrong, samples) == 0.0
