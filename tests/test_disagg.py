"""Disaggregated prefill/decode pools (EngineRouter roles + the
prefill→decode handoff through the shared radix store).

The contract under test: a request primed on a prefill-pool engine and
adopted by a decode-pool engine produces the SAME tokens as the
single-engine path — the prefill pool publishes every chunk-aligned
prompt chunk into the ONE shared store, the adopter's normal admission
prefill assembles the prompt KV from it, and cached-vs-cold prefill is
bit-identical by construction (repro.cache), so token identity is
exact, not approximate (dkv per its documented structural policy). On
top of identity: fully-cached requests bypass the prefill pool, a
prefill-engine crash re-routes its queue instead of failing it (no
orphaned span trees, no leaked radix pins), cancels racing the handoff
conclude exactly once, stealing never crosses pool roles, and the
busy-time/load accounting splits prefill from decode.
"""
import threading
import types

import jax
import numpy as np
import pytest

from repro.cache import PrefixKVCache
from repro.core.decoder import DecodeConfig
from repro.models import get_config, init_params
from repro.obs import Tracer
from repro.obs.trace import request_tree
from repro.server import EngineLoop, EngineRouter, HttpFrontend
from repro.server.router import PREFILL_PENDING_WEIGHT
from repro.server.types import ServerRequest
from repro.serving import ContinuousEngine

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
MAX_TOKENS = 16
BLOCK = 8
CHUNK = 8                       # prefix-cache chunk (tokens)
# 16 chars = two full cache chunks, one shape bucket
PROMPTS = [f"Q:{i}{(i + 3) % 10}+{(i + 5) % 10}{i}=? Answer"
           for i in range(4)]
METHODS = ["vanilla", "dkv", "prefix", "fast", "streaming"]


def make_engine(method="streaming", store=None, prefill_only=False,
                max_slots=2):
    dcfg = DecodeConfig(method=method, gen_len=MAX_TOKENS,
                        block_size=BLOCK, window=4, tau0=0.5,
                        prefix_cache=store is not None,
                        cache_chunk=CHUNK)
    return ContinuousEngine(CFG, PARAMS, dcfg, max_slots=max_slots,
                            prefix_cache=store,
                            prefill_only=prefill_only)


REF = {}


def ref_comps(method):
    """Every prompt decoded co-located on ONE engine: prompt ->
    Completion (the disaggregated fleet must reproduce its tokens)."""
    if method not in REF:
        store = PrefixKVCache(chunk_tokens=CHUNK) \
            if method != "vanilla" else None
        eng = make_engine(method, store)
        uids = {eng.submit(p, max_tokens=MAX_TOKENS): p for p in PROMPTS}
        comps = eng.run_to_completion()
        assert len(comps) == len(PROMPTS)
        REF[method] = {uids[c.uid]: c for c in comps}
    return REF[method]


class Fleet:
    """1 prefill-only loop + ``n_decode`` decode loops under one
    router, all sharing ONE radix store (vanilla has no store: the
    handoff still works, the adopter just re-prefills from scratch)."""

    def __init__(self, method="streaming", n_decode=1, tracer=None,
                 steal=True, max_slots=2):
        self.store = (PrefixKVCache(chunk_tokens=CHUNK, shared=True)
                      if method != "vanilla" else None)
        self.engines = [make_engine(method, self.store,
                                    prefill_only=True,
                                    max_slots=max_slots)]
        self.engines += [make_engine(method, self.store,
                                     max_slots=max_slots)
                         for _ in range(n_decode)]
        self.loops = [EngineLoop(e, max_pending=64, idle_poll_s=0.005,
                                 tracer=tracer, index=i,
                                 role="prefill" if i == 0 else "decode")
                      for i, e in enumerate(self.engines)]
        self.router = EngineRouter(self.loops, steal=steal)

    def __enter__(self):
        for lp in self.loops:
            lp.start()
        return self

    def __exit__(self, *exc):
        self.router.close(drain=False, timeout_s=60)

    def submit(self, prompt, via=None):
        """Submit through the router, or straight to one loop (``via``)
        to force the prefill path regardless of routing policy."""
        done = threading.Event()
        results = []

        def deliver(event, results=results, done=done):
            results.append(event)
            if event[0] == "done":
                done.set()

        req = ServerRequest(prompt=prompt, max_tokens=MAX_TOKENS)
        if via is None:
            t = self.router.submit(req, deliver)
        else:
            t = via.submit(req, deliver)
            t.loop = via
        return prompt, t, done, results


def _assert_matches(comp, ref, method):
    """Token identity vs the co-located reference; dkv is asserted per
    its documented structural (non-batch-invariant) policy."""
    if method == "dkv":
        assert comp.n_tokens == ref.n_tokens
        assert comp.n_blocks == ref.n_blocks
        toks = np.asarray(comp.tokens)
        assert toks.size == 0 or (0 <= toks.min()
                                  and toks.max() < CFG.vocab_size)
    else:
        assert comp.text == ref.text, "handoff changed tokens"


def _no_leaked_pins(store):
    return store is None or all(n.refs == 0 for n in store.tree.nodes)


# --------------------------------------------------- token identity

@pytest.mark.parametrize("method", METHODS)
def test_handoff_tokens_identical(method):
    ref = ref_comps(method)
    with Fleet(method) as fl:
        recs = [fl.submit(p, via=fl.loops[0]) for p in PROMPTS]
        for p, t, done, results in recs:
            assert done.wait(timeout=240), f"never finished: {p}"
        # every row went prefill pool -> decode pool exactly once...
        assert fl.engines[0].metrics.handoffs_out == len(PROMPTS)
        assert fl.engines[1].metrics.handoffs_in == len(PROMPTS)
        # ...and the prefill engine never decoded a block
        assert fl.engines[0].metrics.decode_busy_s == 0.0
        assert fl.engines[0].scheduler.decode_wall_s == 0.0
        for p, t, done, results in recs:
            assert results[-1][0] == "done"
            comp = results[-1][1]
            assert not comp.cancelled
            assert comp.handed_off
            _assert_matches(comp, ref[p], method)
        assert _no_leaked_pins(fl.store)


def test_router_routes_cold_to_prefill_warm_to_decode():
    """A cache-miss prompt routes to the prefill pool; once its chunks
    are in the shared store, the same prompt bypasses straight to the
    decode pool (handoff counters stay put) and still reuses the KV."""
    with Fleet("streaming") as fl:
        req = ServerRequest(prompt=PROMPTS[0], max_tokens=MAX_TOKENS)
        assert fl.router._needs_prefill(req)
        p, t, done, results = fl.submit(PROMPTS[0])
        assert done.wait(timeout=240)
        assert t.loop is fl.loops[1]          # migrated to the adopter
        assert results[-1][1].handed_off
        out_before = fl.engines[0].metrics.handoffs_out
        assert out_before == 1
        # warm: the prefill pass published both aligned chunks
        assert not fl.router._needs_prefill(req)
        p, t, done, results = fl.submit(PROMPTS[0])
        assert done.wait(timeout=240)
        comp = results[-1][1]
        assert not comp.handed_off
        assert fl.engines[0].metrics.handoffs_out == out_before
        assert comp.cache_hit_tokens > 0      # ...but the KV was reused
        assert comp.text == ref_comps("streaming")[PROMPTS[0]].text
        assert _no_leaked_pins(fl.store)


# --------------------------------------------------- churn

def test_prefill_crash_reroutes_without_orphans():
    """A prefill engine whose step explodes mid-stream sheds its queue:
    already-primed rows are dispatched (store-backed, safe), the rest
    re-route via the steal machinery to healthy loops, every request
    still completes, span trees stay well-formed, and the shared store
    ends with zero pinned chunks."""
    tracer = Tracer()
    with Fleet("streaming", n_decode=2, tracer=tracer) as fl:
        real_step = fl.engines[0].step
        calls = []

        def flaky_step():
            if calls:
                raise RuntimeError("injected prefill failure")
            calls.append(1)
            return real_step()

        fl.engines[0].step = flaky_step
        recs = [fl.submit(p, via=fl.loops[0]) for p in PROMPTS]
        for p, t, done, results in recs:
            assert done.wait(timeout=240), f"never concluded: {p}"
        comps = [r[3][-1][1] for r in recs]
        # the first step primed a gang (handed off); the crash re-routed
        # the rest to the decode pool, which primed for itself — so
        # everything completes, nothing is error-cancelled
        assert all(not c.cancelled for c in comps), \
            [c.cancelled for c in comps]
        ref = ref_comps("streaming")
        for (p, t, done, results), comp in zip(recs, comps):
            assert comp.text == ref[p].text
        assert fl.engines[0].metrics.handoffs_out >= 1
        assert _no_leaked_pins(fl.store)
        for p, t, done, results in recs:
            events = tracer.request_events(t.trace_id)
            if events:
                request_tree(events)          # raises if malformed


def test_cancel_during_handoff_concludes_exactly_once():
    """Cancels fired while rows migrate prefill->decode land on exactly
    one side: either the prefill scheduler's handoff_ready sweep or the
    forwarded cancel on the adopter — never both, never neither."""
    tracer = Tracer()
    with Fleet("streaming", tracer=tracer) as fl:
        recs = [fl.submit(p, via=fl.loops[0]) for p in PROMPTS]
        for p, t, done, results in recs[::2]:
            fl.router.cancel(t, "test-cancel")
        for p, t, done, results in recs:
            assert done.wait(timeout=240), f"never concluded: {p}"
        for p, t, done, results in recs:
            dones = [e for e in results if e[0] == "done"]
            assert len(dones) == 1, f"{p!r} concluded {len(dones)} times"
        for p, t, done, results in recs[1::2]:
            comp = results[-1][1]
            assert not comp.cancelled
            assert comp.text == ref_comps("streaming")[p].text
        assert _no_leaked_pins(fl.store)
        traced = 0
        for p, t, done, results in recs:
            events = tracer.request_events(t.trace_id) if t.trace_id \
                else []
            if events:
                request_tree(events)
                traced += 1
        assert traced >= 1


def test_drain_close_completes_inflight_handoffs():
    """close(drain=True) on the fleet: prefill loops drain first (their
    tails are handoffs the decode pool must outlive to adopt)."""
    with Fleet("streaming") as fl:
        recs = [fl.submit(p, via=fl.loops[0]) for p in PROMPTS[:2]]
        assert fl.router.close(drain=True, timeout_s=120)
        for p, t, done, results in recs:
            assert done.wait(timeout=1), f"drain dropped: {p}"
            assert not results[-1][1].cancelled


# --------------------------------------------------- routing policy

def _stub_loop(role, live=0, waiting=0, paused=0, pending=0, free=2,
               running=True, index=0):
    sched = types.SimpleNamespace(
        live_rows=live, waiting=[None] * waiting,
        paused=[None] * paused, max_slots=2, slots_used=2 - free)
    return types.SimpleNamespace(
        role=role, running=running, index=index, inflight=0,
        _pending=[None] * pending, engine=types.SimpleNamespace(
            scheduler=sched, prefix_cache=None))


def test_pick_victim_never_crosses_roles():
    prefill = _stub_loop("prefill", pending=8, free=0, index=0)
    decode_a = _stub_loop("decode", waiting=2, free=0, index=1)
    decode_b = _stub_loop("decode", index=2)
    r = EngineRouter([prefill, decode_a, decode_b], steal=True)
    victim, backlog = r.pick_victim(decode_b)
    assert victim is decode_a            # not the loaded prefill loop
    assert backlog == 2
    # and a prefill thief only sees prefill victims
    thief = _stub_loop("prefill", index=3)
    r2 = EngineRouter([prefill, decode_a, thief], steal=True)
    victim, backlog = r2.pick_victim(thief)
    assert victim is prefill
    assert backlog == 8


def test_victim_ranking_weights_queued_below_parked():
    """A deep-but-cheap queue (prefill-pending rows) must not outbid a
    sibling whose parked rows represent live decode work."""
    deep_queue = _stub_loop("decode", pending=8, free=0, index=0)
    parked = _stub_loop("decode", paused=3, free=0, index=1)
    thief = _stub_loop("decode", index=2)
    r = EngineRouter([deep_queue, parked, thief], steal=True)
    victim, backlog = r.pick_victim(thief)
    assert victim is parked              # 3*1.0 beats 8*WEIGHT
    assert backlog == 3                  # raw count, for the steal size
    assert 8 * PREFILL_PENDING_WEIGHT < 3


def test_loop_load_weights_prefill_pending_rows():
    lp = _stub_loop("decode", live=2, waiting=3, pending=1, paused=1)
    sole = _stub_loop("decode", index=1)
    r = EngineRouter([lp, sole], steal=False)
    assert r._loop_load(lp) == pytest.approx(
        2 + 1 + PREFILL_PENDING_WEIGHT * 4)
    assert r._loop_load(sole) == 0.0
    # submit ordering prefers genuinely-idle over deeply-queued
    assert r._by_load([lp, sole]) == [sole, lp]


# --------------------------------------------------- observability

def test_metrics_split_prefill_vs_decode():
    with Fleet("streaming") as fl:
        recs = [fl.submit(p, via=fl.loops[0]) for p in PROMPTS[:2]]
        for p, t, done, results in recs:
            assert done.wait(timeout=240)
        pre, dec = fl.engines[0].metrics, fl.engines[1].metrics
        assert pre.prefill_busy_s > 0 and pre.decode_busy_s == 0.0
        assert dec.decode_busy_s > 0
        assert pre.handoffs_out == dec.handoffs_in == 2
        assert dec.handoff_wait_s > 0
        snap = pre.snapshot()
        for key in ("prefill_busy_s", "decode_busy_s", "handoffs_out",
                    "handoffs_in", "handoff_wait_s"):
            assert key in snap
        dv = fl.loops[0].debug_vars()
        assert dv["role"] == "prefill" and dv["handoffs_out"] == 2
        assert fl.loops[1].debug_vars()["role"] == "decode"
        text = HttpFrontend(fl.router)._metrics_text()
        assert "repro_prefill_busy_seconds_total" in text
        assert "repro_decode_busy_seconds_total" in text
        assert "repro_handoffs_total 2" in text
        assert 'repro_pool_engines{role="prefill"} 1' in text
        assert 'repro_pool_engines{role="decode"} 1' in text
        assert 'repro_engine_handoffs_in_total{engine="1"} 2' in text
        assert 'repro_engine_handoffs_out_total{engine="0"} 2' in text
        assert "repro_handoff_wait_seconds" in text
