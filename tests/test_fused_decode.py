"""Fused device-resident denoise loop vs the legacy host loop.

The fused path compiles the whole per-block denoise loop (refresh +
``lax.while_loop`` steps + straggler finalize + EOS early exit) into one
jitted function that the host calls once per block. These tests pin the
contract that makes it a pure refactor: token identity with the per-step
host loop for all five methods, under both kernel routings, with exact
NFE / per-block step / flop-proxy counter agreement — plus the
no-per-block-recompilation bound the serving layer relies on."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.decoder import METHODS, DecodeConfig, DiffusionDecoder
from repro.models import get_config, init_params

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
PROMPT = np.random.default_rng(0).integers(0, 200, (2, 10)).astype(np.int32)


def _pair(method, **kw):
    """(host-loop result, fused-loop result) on identical inputs."""
    kw.setdefault("gen_len", 16)
    kw.setdefault("block_size", 8)
    kw.setdefault("window", 4)
    d = DecodeConfig(method=method, fused=False, **kw)
    host = DiffusionDecoder(CFG, PARAMS, d).generate(PROMPT.copy())
    df = dataclasses.replace(d, fused=True)
    fused = DiffusionDecoder(CFG, PARAMS, df).generate(PROMPT.copy())
    return host, fused


@pytest.mark.parametrize("use_kernels", [False, True],
                         ids=["ref", "pallas"])
@pytest.mark.parametrize("method", METHODS)
def test_fused_matches_host_loop(method, use_kernels):
    """Bit-identical tokens and identical schedule/flop accounting
    between the two loop implementations, with attention/confidence on
    either the reference or the Pallas route.

    dkv is the one exception to bitwise comparison — the same
    XLA:CPU threaded-reduction run-to-run noise that already forces its
    continuous/batch equivalence to be structural (see
    test_serving.py::test_dkv_equivalence_structural) flips occasional
    argmaxes between any two runs, including two host-loop runs. Its
    schedule and counters are still exact, and token agreement must
    stay far above anything a loop-logic bug would leave intact."""
    host, fused = _pair(method, use_kernels=use_kernels, tau0=0.5)
    if method == "dkv":
        assert (host.tokens == fused.tokens).mean() > 0.5
        assert (fused.tokens != CFG.mask_token_id).all()
    else:
        assert (host.tokens == fused.tokens).all()
    assert host.nfe == fused.nfe
    assert host.steps_per_block == fused.steps_per_block
    assert host.query_tokens_processed == fused.query_tokens_processed
    assert host.kv_tokens_attended == fused.kv_tokens_attended
    assert host.early_exits == fused.early_exits


def test_fused_matches_host_loop_frozen_suffix():
    host, fused = _pair("streaming", gen_len=32, window=8,
                        frozen_suffix=True, tau0=0.5)
    assert (host.tokens == fused.tokens).all()
    assert host.nfe == fused.nfe
    assert host.kv_tokens_attended == fused.kv_tokens_attended


def test_fused_matches_host_loop_early_exit():
    """With a fake EOS the model actually emits, both loops must agree
    on which rows exit, when, and what the truncated outputs are."""
    d0 = DecodeConfig(method="streaming", gen_len=32, block_size=8,
                      window=8, early_exit=False)
    r0 = DiffusionDecoder(CFG, PARAMS, d0).generate(PROMPT.copy())
    vals, counts = np.unique(r0.tokens, return_counts=True)
    cfg2 = dataclasses.replace(CFG, eos_token_id=int(vals[counts.argmax()]))
    d = DecodeConfig(method="streaming", gen_len=32, block_size=8, window=8,
                     fused=False)
    host = DiffusionDecoder(cfg2, PARAMS, d).generate(PROMPT.copy())
    fused = DiffusionDecoder(
        cfg2, PARAMS, dataclasses.replace(d, fused=True)).generate(
        PROMPT.copy())
    assert (host.tokens == fused.tokens).all()
    assert host.early_exits == fused.early_exits > 0
    assert host.steps_per_block == fused.steps_per_block


def test_fused_one_host_sync_per_block():
    """The whole point: the host loop syncs every denoise step (and, on
    the fixed-schedule methods, copies full (B, K, V) logits each time);
    the fused loop syncs once per block and never copies block logits."""
    host, fused = _pair("prefix")
    n_blocks = len(fused.steps_per_block)
    assert fused.host_syncs == n_blocks
    assert fused.logit_syncs == 0
    assert host.host_syncs == host.nfe          # one per step
    assert host.logit_syncs == host.nfe         # (B, K, V) every step
    # parallel methods move even the host loop onto the fused head path:
    # per-step syncs shrink to (conf, toks), never block logits
    host_s, fused_s = _pair("streaming")
    assert host_s.logit_syncs == fused_s.logit_syncs == 0
    assert fused_s.host_syncs == len(fused_s.steps_per_block)


def test_fused_no_per_block_recompilation():
    """The jit cache is bounded by shape buckets: a second generation at
    the same shapes must not add compiled variants (the serving
    scheduler's no-recompile-after-warmup property)."""
    d = DecodeConfig(method="streaming", gen_len=16, block_size=8, window=4,
                     fused=True)
    dec = DiffusionDecoder(CFG, PARAMS, d)
    dec.generate(PROMPT.copy())
    size_after_warmup = dec.jit_cache_size()
    # fused loop: one compiled variant per block index, none per request
    assert size_after_warmup <= d.gen_len // d.block_size + 1
    other = np.random.default_rng(9).integers(0, 200, (2, 10)).astype(
        np.int32)
    dec.generate(other)
    assert dec.jit_cache_size() == size_after_warmup


def test_straggler_finalize_preserves_done_rows():
    """Regression (both loops): when the steps cap forces a straggler
    commit, rows that early-exited in a PRIOR block must keep their
    masked tail instead of having it overwritten with the last step's
    argmax — the EOS truncation in finalize was the only thing hiding
    the overwrite."""
    for fused in (False, True):
        d = DecodeConfig(method="streaming", gen_len=16, block_size=8,
                         window=4, steps_per_block=1, tau0=0.99,
                         fused=fused)
        dec = DiffusionDecoder(CFG, PARAMS, d)
        st = dec.prefill(PROMPT.copy())
        st.done[0] = True               # pretend row 0 exited in block -1
        dec.decode_block(st)
        blk = st.x[:, st.prompt_len:st.prompt_len + 8]
        # the single step's selection still commits its fallback token
        # for every row (legacy semantics), but the cap-time straggler
        # fill must skip the done row: its tail stays masked while the
        # live row's block is fully argmax-filled
        assert (blk[0] == CFG.mask_token_id).any(), fused
        assert (blk[1] != CFG.mask_token_id).all(), fused


def test_decode_state_resume_across_loop_switch():
    """DecodeState is loop-agnostic: blocks decoded by the host loop
    then resumed under the fused loop (or vice versa) reproduce a pure
    single-loop run exactly — the scheduler may flip ``fused`` between
    ticks without perturbing generations."""
    d = DecodeConfig(method="streaming", gen_len=32, block_size=8, window=8,
                     fused=True)
    ref = DiffusionDecoder(CFG, PARAMS, d).generate(PROMPT.copy())
    dec_f = DiffusionDecoder(CFG, PARAMS, d)
    dec_h = DiffusionDecoder(CFG, PARAMS,
                             dataclasses.replace(d, fused=False))
    st = dec_h.prefill(PROMPT.copy())
    dec_h.decode_block(st)              # block 0: host loop
    dec_f.decode_block(st)              # block 1: fused loop
    dec_h.decode_block(st)              # block 2: host loop
    dec_f.decode_block(st)              # block 3: fused loop
    out = dec_f.finalize(st)
    assert (out.tokens == ref.tokens).all()
    assert out.nfe == ref.nfe
