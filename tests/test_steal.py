"""Block-boundary work stealing (EngineRouter / EngineLoop / engine).

The contract under test: a stolen request produces the SAME tokens it
would have produced unstolen. Streaming decode is batch-invariant (the
same discipline ``test_sharded_decode.py`` leans on), stolen waiting
requests are re-prefilled from scratch by the thief, and stolen paused
rows resume through the exact preempt/resume path — so token identity
is exact, not approximate. On top of identity: cancellation races with
the steal handoff (every ticket concludes exactly once), and the span
discipline (victim closes "request"/"queue" with ``stolen=True``, the
thief reopens both) keeps per-request trace trees well-formed.
"""
import threading

import jax
import numpy as np
import pytest

from repro.core.decoder import DecodeConfig
from repro.models import get_config, init_params
from repro.obs import Tracer
from repro.obs.trace import request_tree
from repro.server import EngineLoop, EngineRouter
from repro.server.types import ServerRequest
from repro.serving import ContinuousEngine

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
MAX_TOKENS = 16
# one shape bucket: equal-length prompts gang-batch cleanly
PROMPTS = [f"Q:{i}{(i + 3) % 10}+{(i + 5) % 10}{i}=? A:" for i in range(8)]


def make_engine(max_slots=2):
    dcfg = DecodeConfig(method="streaming", gen_len=MAX_TOKENS,
                        block_size=8, window=16)
    return ContinuousEngine(CFG, PARAMS, dcfg, max_slots=max_slots)


def reference_texts():
    """Every prompt decoded on one engine, no stealing: prompt -> text."""
    eng = make_engine(max_slots=2)
    uids = {eng.submit(p, max_tokens=MAX_TOKENS): p for p in PROMPTS}
    comps = eng.run_to_completion()
    assert len(comps) == len(PROMPTS)
    return {uids[c.uid]: c.text for c in comps}


REF = None


def _ref():
    global REF
    if REF is None:
        REF = reference_texts()
    return REF


class Fleet:
    """Two EngineLoops under one router, everything submitted to loop 0
    so loop 1 has nothing to do but steal."""

    def __init__(self, steal=True, tracer=None):
        self.engines = [make_engine(max_slots=2) for _ in range(2)]
        self.loops = [EngineLoop(e, max_pending=64, idle_poll_s=0.005,
                                 tracer=tracer, index=i)
                      for i, e in enumerate(self.engines)]
        self.router = EngineRouter(self.loops, steal=steal)

    def __enter__(self):
        for lp in self.loops:
            lp.start()
        return self

    def __exit__(self, *exc):
        self.router.close(drain=False, timeout_s=60)

    def submit_all(self, prompts):
        """Submit everything to the victim (loop 0) and return
        per-ticket (ticket, done_event, results_list) records."""
        out = []
        for p in prompts:
            done = threading.Event()
            results = []

            def deliver(event, results=results, done=done):
                results.append(event)
                if event[0] == "done":
                    done.set()

            t = self.loops[0].submit(
                ServerRequest(prompt=p, max_tokens=MAX_TOKENS), deliver)
            t.loop = self.loops[0]
            out.append((p, t, done, results))
        return out


def test_stolen_tokens_bit_identical():
    ref = _ref()
    with Fleet(steal=True) as fl:
        recs = fl.submit_all(PROMPTS)
        for p, t, done, results in recs:
            assert done.wait(timeout=180), f"request never finished: {p}"
        # the idle sibling must actually have taken work...
        assert fl.engines[1].metrics.steals_in >= 1
        total = sum(e.metrics.steals_in for e in fl.engines)
        assert total == sum(e.metrics.steals_out for e in fl.engines)
        # ...and every request — stolen or not — matches the unstolen run
        for p, t, done, results in recs:
            comp = results[-1][1]
            assert results[-1][0] == "done"
            assert not comp.cancelled
            assert comp.text == ref[p], f"steal changed tokens for {p!r}"


def test_steal_under_cancel_concludes_every_ticket_once():
    tracer = Tracer()
    with Fleet(steal=True, tracer=tracer) as fl:
        recs = fl.submit_all(PROMPTS)
        # cancel every other ticket immediately: some are still pending
        # on the victim, some already stolen (the cancel must forward to
        # the ticket's current owner), some decoding
        for p, t, done, results in recs[::2]:
            fl.router.cancel(t, "test-cancel")
        for p, t, done, results in recs:
            assert done.wait(timeout=180), f"request never concluded: {p}"
        for p, t, done, results in recs:
            dones = [e for e in results if e[0] == "done"]
            assert len(dones) == 1, f"{p!r} concluded {len(dones)} times"
        # span trees stay well-formed across the steal/cancel races:
        # request_tree raises on unbalanced or unclosed nesting. A
        # ticket cancelled before reaching any engine opened no spans —
        # zero events is correct for it, malformed nesting never is.
        traced = 0
        for p, t, done, results in recs:
            events = tracer.request_events(t.trace_id) if t.trace_id \
                else []
            if events:
                request_tree(events)
                traced += 1
        assert traced >= 1


def test_paused_row_steal_resumes_identically():
    """Deterministic engine-level lifecycle: decode one block, preempt,
    steal the parked row, adopt it on a second engine, finish there —
    and get exactly the tokens an unbroken single-engine run yields."""
    ref = _ref()
    victim = make_engine(max_slots=1)
    thief = make_engine(max_slots=1)
    target = PROMPTS[0]

    uid = victim.submit(target, max_tokens=MAX_TOKENS)
    assert victim.step() == []            # prefill + block 0 of 2
    victim.preempt(uid)
    # Admission resumes paused rows first, so inside a full tick a
    # compacting-method row parks and immediately un-parks — the parked
    # state is observable only at the block boundary itself. Run the
    # scheduler's own compaction step (the first half of that boundary)
    # to freeze the instant a loop-level steal command would see.
    victim.scheduler._compact()
    assert any(r.uid == uid for r, _, _ in victim.scheduler.paused)

    stolen = victim.steal_paused()
    assert stolen is not None
    req, state = stolen
    assert req.uid == uid and state.cache is None   # host-portable
    assert victim.metrics.steals_out == 1
    assert not victim.scheduler.paused

    new_uid = thief.adopt_paused(req, state)
    assert thief.metrics.steals_in == 1
    comps = {c.uid: c for c in thief.run_to_completion()}
    assert comps[new_uid].text == ref[target]
    assert victim.run_to_completion() == []          # nothing left behind


def test_dkv_paused_rows_are_never_stolen():
    """dkv parked rows pin a device cache (and the method is not
    batch-invariant) — steal_paused must refuse them."""
    dcfg = DecodeConfig(method="dkv", gen_len=MAX_TOKENS, block_size=8)
    eng = ContinuousEngine(CFG, PARAMS, dcfg, max_slots=1)
    uid = eng.submit(PROMPTS[0], max_tokens=MAX_TOKENS)
    eng.step()
    eng.preempt(uid)
    eng.scheduler._compact()              # freeze the parked instant
    assert eng.scheduler.paused
    assert eng.steal_paused() is None
    assert eng.metrics.steals_out == 0
    eng.run_to_completion()
