"""Subprocess worker for tests/test_sharded_decode.py.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set
by the parent — see tests/conftest.py for why the flag must never be
set in-process) and compares, *within one process*, single-device
decode (``executor=None``) against ``DecodeExecutor``-placed decode on
real (data, model) host meshes. Prints one JSON document on stdout.

In-process comparison matters: run-to-run XLA:CPU noise (threaded
matmul reduction order) is the documented reason dkv can't be compared
exactly across processes; inside one process both paths see the same
runtime, so any divergence is placement-induced.
"""
import json
import sys

import numpy as np


def main():
    quick = "--quick" in sys.argv
    import jax

    from repro.core.decoder import METHODS, DecodeConfig, DiffusionDecoder
    from repro.launch.mesh import make_host_mesh
    from repro.models import get_config, init_params
    from repro.serving import ContinuousEngine, DecodeExecutor
    from repro.data.tokenizer import ByteTokenizer

    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 200, (4, 10)).astype(np.int32)
    out = {"n_devices": len(jax.devices()), "runs": []}

    def dcfg(method):
        return DecodeConfig(method=method, gen_len=16, block_size=8,
                            window=8)

    # satellite matrix: data = 2/4, model = 1/2, all five methods
    meshes = [(2, 1)] if quick else [(2, 1), (4, 1), (2, 2)]
    methods = ["streaming", "fast"] if quick else list(METHODS)
    for method in methods:
        d = dcfg(method)
        ref = DiffusionDecoder(cfg, params, d).generate(prompts.copy())
        for dm, mm in meshes:
            ex = DecodeExecutor(cfg, params, make_host_mesh(dm, mm))
            r = DiffusionDecoder(cfg, None, d,
                                 executor=ex).generate(prompts.copy())
            out["runs"].append({
                "method": method, "data": dm, "model": mm,
                "exact": bool((ref.tokens == r.tokens).all()),
                "agree": float((ref.tokens == r.tokens).mean()),
                "valid": bool(((r.tokens >= 0)
                               & (r.tokens < cfg.vocab_size)).all()),
                "nfe": int(r.nfe), "ref_nfe": int(ref.nfe),
            })

    # divisibility fallback: batch 3 doesn't divide data=2 — the
    # executor must replicate (never silently pad) and stay exact
    d = dcfg("streaming")
    ref3 = DiffusionDecoder(cfg, params, d).generate(prompts[:3].copy())
    ex2 = DecodeExecutor(cfg, params, make_host_mesh(2, 1))
    r3 = DiffusionDecoder(cfg, None, d,
                          executor=ex2).generate(prompts[:3].copy())
    sh = ex2.batch_sharding(2, 3)
    out["fallback"] = {
        "exact": bool((ref3.tokens == r3.tokens).all()),
        "replicated": bool(sh.spec[0] is None),
        "sharded_even": bool(ex2.batch_sharding(2, 4).spec[0] is not None),
    }

    # sharded continuous engine end-to-end: gang rounding (odd request
    # count on data=2) + placement-bound pool + per-row identity
    tok = ByteTokenizer(cfg.vocab_size)
    eng = ContinuousEngine(cfg, params, d, max_slots=8, tokenizer=tok,
                           executor=ex2)
    n_req = 3
    uids = [eng.submit(prompts[i], max_tokens=16) for i in range(n_req)]
    comps = {c.uid: c for c in eng.run_to_completion()}
    out["engine"] = {
        "batch_multiple": eng.scheduler.batch_multiple,
        "pad_3": eng.scheduler._pad_batch(3),
        "served": len(comps),
        "exact": bool(all(
            (comps[uids[i]].tokens == ref3.tokens[i][:16]).all()
            for i in range(n_req))),
        "pool_placement": list(eng.pool.placement),
    }
    # prefix-cache under a data=2 mesh: cached (warm store) vs cold
    # prefill must be bit-identical with executor placement in the
    # loop — the KV slices round-trip through host staging and the
    # sharded gang buffers (store placement-bound to the mesh)
    from repro.cache import PrefixKVCache
    dpc = DecodeConfig(method="streaming", gen_len=16, block_size=8,
                       window=8, prefix_cache=True, cache_chunk=5)
    store = PrefixKVCache(chunk_tokens=5, placement=ex2.placement)
    cold = DiffusionDecoder(cfg, None, dpc, executor=ex2,
                            prompt_cache=store).generate(prompts.copy())
    warm = DiffusionDecoder(cfg, None, dpc, executor=ex2,
                            prompt_cache=store).generate(prompts.copy())
    eng_pc = ContinuousEngine(cfg, params, dpc, max_slots=8, tokenizer=tok,
                              executor=ex2, prefix_cache=store)
    uids_pc = [eng_pc.submit(prompts[i], max_tokens=16) for i in range(4)]
    comps_pc = {c.uid: c for c in eng_pc.run_to_completion()}
    out["prefix_cache"] = {
        "exact": bool((cold.tokens == warm.tokens).all()),
        "hit_tokens": store.stats()["lookup_hit_tokens"],
        "store_placement": list(store.placement),
        "engine_exact": bool(all(
            (comps_pc[uids_pc[i]].tokens == cold.tokens[i][:16]).all()
            for i in range(4))),
        "engine_hits": [comps_pc[uids_pc[i]].cache_hit_tokens
                        for i in range(4)],
    }
    json.dump(out, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
