"""Per-arch smoke tests: reduced variant of each assigned family runs
one forward AND one train step on CPU; output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.models import apply_model, get_config, init_cache, init_params
from repro.models.heads import plan_heads
from repro.training.loss import diffusion_loss
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)
SMOKE = [a + "-smoke" for a in ASSIGNED] + ["llada-8b-smoke", "tiny",
                                            "tiny-moe"]


@pytest.mark.parametrize("name", SMOKE)
def test_forward_smoke(name):
    cfg = get_config(name)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size - 4)
    kwargs = {}
    if cfg.frontend_embed_dim:
        kwargs["prefix_embeds"] = jax.random.normal(
            KEY, (2, cfg.frontend_prefix_len, cfg.frontend_embed_dim))
    out = apply_model(cfg, params, tokens=toks, **kwargs)
    S = 32 + (cfg.frontend_prefix_len if cfg.frontend_embed_dim else 0)
    assert out.logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.isfinite(out.logits).all())


@pytest.mark.parametrize("name", SMOKE)
def test_train_step_smoke(name):
    cfg = get_config(name)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size - 4)
    mask = jnp.ones((2, 24), bool)

    def loss_fn(p):
        return diffusion_loss(cfg, p, toks, mask, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    ocfg = AdamWConfig()
    st = adamw_init(ocfg, params)
    p2, st2, m = adamw_update(ocfg, grads, st, params)
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0 and np.isfinite(delta)


@pytest.mark.parametrize("name", ["tiny", "recurrentgemma-9b-smoke",
                                  "xlstm-350m-smoke", "gemma2-27b-smoke"])
def test_cached_step_consistency(name):
    """Block-refresh + step must equal a single full encode for the
    query-region logits (the cache path is exact given identical
    visibility)."""
    cfg = get_config(name)
    params = init_params(cfg, KEY)
    B, P, Q = 2, 12, 6
    toks = jax.random.randint(KEY, (B, P + Q), 0, cfg.vocab_size - 4)
    full = apply_model(cfg, params, tokens=toks)
    # refresh: encode full with cache, then re-run the query region via
    # step mode against the cached prefix — identical visibility
    cache = init_cache(cfg, B, P + Q)
    enc = apply_model(cfg, params, tokens=toks, mode="encode", cache=cache,
                      cache_upto=P)
    qpos = jnp.broadcast_to(jnp.arange(P, P + Q)[None], (B, Q))
    step = apply_model(cfg, params, tokens=toks[:, P:], positions=qpos,
                       mode="step", cache=enc.cache,
                       kv_valid=jnp.full((B,), P, jnp.int32))
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        # pure-attention archs: step == encode exactly (same visibility)
        np.testing.assert_allclose(np.asarray(step.logits),
                                   np.asarray(full.logits[:, P:]),
                                   atol=2e-3, rtol=2e-3)
    else:
        # recurrent mixers: step re-scans the suffix from the prefix
        # state; prefix-state scan differs from full-seq scan only in
        # what the PREFIX saw (nothing) — causal => identical
        np.testing.assert_allclose(np.asarray(step.logits),
                                   np.asarray(full.logits[:, P:]),
                                   atol=2e-3, rtol=2e-3)


def test_head_padding_semantics():
    """Zero-padded q heads and duplicated kv heads preserve outputs."""
    base = get_config("tiny")
    toks = jax.random.randint(KEY, (2, 16), 0, base.vocab_size - 4)
    p1 = init_params(base, KEY)
    out1 = apply_model(base, p1, tokens=toks)
    import dataclasses
    padded = dataclasses.replace(base, tp=16)  # forces 8q/4kv -> 16/16
    plan = plan_heads(padded.n_heads, padded.n_kv_heads, padded.tp)
    assert plan.pad_q % 16 == 0 and plan.pad_kv % 16 == 0
    p2 = init_params(padded, KEY)
    out2 = apply_model(padded, p2, tokens=toks)
    assert bool(jnp.isfinite(out2.logits).all())
    assert out2.logits.shape == out1.logits.shape


@pytest.mark.parametrize("nq,nkv,tp", [
    (24, 8, 16), (56, 8, 16), (24, 24, 16), (16, 1, 16), (64, 8, 16),
    (32, 16, 16), (16, 16, 16), (4, 4, 16), (28, 4, 16), (64, 8, 8),
])
def test_plan_heads_divisibility(nq, nkv, tp):
    plan = plan_heads(nq, nkv, tp)
    assert plan.pad_q % tp == 0
    assert plan.pad_kv % tp == 0 or tp % plan.pad_kv == 0
    assert plan.pad_q % plan.pad_kv == 0
    assert plan.pad_q >= nq and plan.pad_kv >= nkv
    # group mapping consistent: q j -> kv j // group covers all kv
    assert plan.group * plan.pad_kv == plan.pad_q


def test_long_serve_layout_switch():
    cfg = get_config("qwen3-32b-smoke")
    lay = cfg.effective_layout(serve_long=True)
    assert all(s.mixer == "attn_local" for s in lay)
    lay2 = cfg.effective_layout(serve_long=False)
    assert all(s.mixer == "attn" for s in lay2)
