"""System-behaviour tests for the diffusion decoder (the paper's core)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decoder import METHODS, DecodeConfig, DiffusionDecoder
from repro.models import get_config, init_params

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
PROMPT = np.random.default_rng(0).integers(0, 200, (2, 10)).astype(np.int32)


def _gen(method, **kw):
    kw.setdefault("gen_len", 32)
    kw.setdefault("block_size", 8)
    kw.setdefault("window", 8)
    d = DecodeConfig(method=method, **kw)
    return DiffusionDecoder(CFG, PARAMS, d).generate(PROMPT.copy())


@pytest.mark.parametrize("method", METHODS)
def test_all_methods_produce_tokens(method):
    r = _gen(method)
    assert r.tokens.shape == (2, 32)
    assert (r.tokens >= 0).all() and (r.tokens < CFG.vocab_size).all()
    assert r.nfe > 0


def test_vanilla_is_deterministic():
    a, b = _gen("vanilla"), _gen("vanilla")
    assert (a.tokens == b.tokens).all()
    assert a.nfe == b.nfe


def test_streaming_full_window_matches_fast():
    """With w covering the whole suffix and alpha=0 (static threshold),
    streaming degenerates exactly to Fast-dLLM."""
    s = _gen("streaming", window=10_000, alpha=0.0, early_exit=False)
    f = _gen("fast", early_exit=False)
    assert (s.tokens == f.tokens).all()
    assert s.nfe == f.nfe


def test_streaming_prunes_query_tokens():
    s = _gen("streaming", gen_len=64, window=8, early_exit=False)
    f = _gen("fast", gen_len=64, early_exit=False)
    assert s.query_tokens_processed < f.query_tokens_processed


def test_parallel_methods_use_fewer_steps():
    v = _gen("vanilla")
    s = _gen("streaming", tau0=0.5)
    assert s.nfe <= v.nfe


def test_fixed_schedule_step_counts():
    r = _gen("prefix", early_exit=False)
    # one-per-step baseline: every block takes exactly block_size steps
    assert all(s == 8 for s in r.steps_per_block)


def test_early_exit_skips_blocks():
    """Force EOS by making the model... use a prompt of EOS tokens so the
    trained-free random model still sometimes commits EOS; instead test
    the mechanism directly: patch eos_token_id to the argmax'd token."""
    r_no = _gen("streaming", early_exit=False, gen_len=64)
    # pick the token the model actually generates most and pretend it is
    # EOS — early exit must then cut blocks for those rows
    vals, counts = np.unique(r_no.tokens, return_counts=True)
    fake_eos = int(vals[counts.argmax()])
    cfg2 = dataclasses.replace(CFG, eos_token_id=fake_eos)
    d = DecodeConfig(method="streaming", gen_len=64, block_size=8, window=8)
    r = DiffusionDecoder(cfg2, PARAMS, d).generate(PROMPT.copy())
    assert r.early_exits > 0
    assert len(r.steps_per_block) <= len(r_no.steps_per_block)


def test_trailing_position_toggle_changes_query():
    with_t = _gen("streaming", gen_len=64, trailing_position=True,
                  early_exit=False)
    without = _gen("streaming", gen_len=64, trailing_position=False,
                   early_exit=False)
    assert with_t.query_tokens_processed > without.query_tokens_processed


def test_dynamic_threshold_commits_not_fewer_tokens_per_step():
    """alpha > 0 relaxes tau as the block empties -> step count per block
    can only shrink or stay equal vs alpha=0 at same tau0."""
    a0 = _gen("streaming", alpha=0.0, tau0=0.8, early_exit=False)
    a6 = _gen("streaming", alpha=0.6, tau0=0.8, early_exit=False)
    assert sum(a6.steps_per_block) <= sum(a0.steps_per_block)


def test_tokens_match_training_domain():
    # committed tokens must never be the mask token
    for m in METHODS:
        r = _gen(m)
        assert (r.tokens != CFG.mask_token_id).all()


@pytest.mark.parametrize("name", ["xlstm-350m-smoke",
                                  "recurrentgemma-9b-smoke",
                                  "gemma2-27b-smoke", "olmoe-1b-7b-smoke",
                                  "musicgen-medium-smoke"])
def test_streaming_decode_every_family(name):
    """The paper's decoder must run on every assigned arch family
    (block-causal mode for SSM/hybrid — DESIGN.md §6)."""
    cfg = get_config(name, block_size=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size - 4, (2, 10)).astype(np.int32)
    d = DecodeConfig(method="streaming", gen_len=16, block_size=8, window=4,
                     early_exit=False)
    r = DiffusionDecoder(cfg, params, d).generate(prompts)
    assert r.tokens.shape == (2, 16)
    assert (r.tokens != cfg.mask_token_id).all()


def test_frozen_suffix_decodes():
    """HC1: frozen-suffix steps query only the block; generation still
    valid and processes fewer query tokens than plain streaming."""
    s = _gen("streaming", gen_len=64, window=8, early_exit=False)
    f = _gen("streaming", gen_len=64, window=8, early_exit=False,
             frozen_suffix=True)
    assert f.tokens.shape == s.tokens.shape
    assert (f.tokens != CFG.mask_token_id).all()
    assert f.query_tokens_processed < s.query_tokens_processed


def test_engine_serves_queue():
    from repro.core.engine import ServingEngine
    d = DecodeConfig(method="streaming", gen_len=16, block_size=8, window=8)
    eng = ServingEngine(CFG, PARAMS, d, max_batch=4)
    for i in range(6):
        eng.submit(f"Q:{i}{i}+11=? A:", max_tokens=16)
    done = eng.run_to_completion()
    assert len(done) == 6
    assert eng.stats["batches"] >= 2  # 6 requests / max_batch 4
    assert all(isinstance(c.text, str) for c in done)
