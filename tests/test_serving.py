"""Continuous-batching serving subsystem tests: resumable decode_block
equivalence, scheduler backfill on early exit, prefix-KV pool
reuse/eviction, streaming order, preemption, admission control, and
token-identity between the continuous and synchronous engines."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.decoder import METHODS, DecodeConfig, DiffusionDecoder
from repro.core.engine import ServingEngine
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.serving import (BlockScheduler, ContinuousEngine, PrefixKVPool,
                           StreamRouter, round_up_blocks)

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
TOK = ByteTokenizer(CFG.vocab_size)
RNG = np.random.default_rng(0)
PROMPTS = RNG.integers(0, 200, (4, 10)).astype(np.int32)


def _dcfg(method="streaming", **kw):
    kw.setdefault("gen_len", 16)
    kw.setdefault("block_size", 8)
    kw.setdefault("window", 8)
    return DecodeConfig(method=method, **kw)


def _fake_eos_cfg(method="streaming", gen_len=32):
    """A config whose eos_token_id is the token the untrained model
    emits most — guarantees early exits (same trick as test_decoder)."""
    d = _dcfg(method, gen_len=gen_len, early_exit=False)
    r = DiffusionDecoder(CFG, PARAMS, d).generate(PROMPTS.copy())
    vals, counts = np.unique(r.tokens, return_counts=True)
    return dataclasses.replace(CFG, eos_token_id=int(vals[counts.argmax()]))


# ------------------------------------------------------------ decoder API


@pytest.mark.parametrize("method", [m for m in METHODS if m != "dkv"])
def test_decode_block_interleaved_matches_generate(method):
    """Two independent DecodeStates advanced alternately through
    decode_block reproduce generate() exactly — the resumability
    contract the scheduler relies on. (dkv is covered by the
    deterministic-backend subprocess test below: it amplifies
    run-to-run ulp noise from threaded CPU matmuls into argmax flips,
    so in-process exact comparison is not sound for it.)"""
    d = _dcfg(method)
    dec = DiffusionDecoder(CFG, PARAMS, d)
    ref_a = dec.generate(PROMPTS[:2].copy())
    ref_b = dec.generate(PROMPTS[2:].copy())
    sa = dec.prefill(PROMPTS[:2].copy())
    sb = dec.prefill(PROMPTS[2:].copy())
    while not (sa.finished and sb.finished):
        dec.decode_block(sa)
        dec.decode_block(sb)
    ra, rb = dec.finalize(sa), dec.finalize(sb)
    assert (ra.tokens == ref_a.tokens).all()
    assert (rb.tokens == ref_b.tokens).all()
    assert ra.nfe == ref_a.nfe and rb.nfe == ref_b.nfe


@pytest.mark.parametrize("method", [m for m in METHODS if m != "dkv"])
def test_batch_invariance(method):
    """Per-row outputs are bit-identical across batch reshaping for
    every method the scheduler compacts (dkv is excluded by design —
    its step-level KV freezing drifts at ulp level, which is why
    BlockScheduler pins dkv gangs to their admitted batch)."""
    d = _dcfg(method)
    dec = DiffusionDecoder(CFG, PARAMS, d)
    assert dec.batch_invariant
    full = dec.generate(PROMPTS.copy())
    for b in range(PROMPTS.shape[0]):
        one = DiffusionDecoder(CFG, PARAMS, d).generate(
            PROMPTS[b:b + 1].copy())
        assert (one.tokens[0] == full.tokens[b]).all()


def test_take_rows_resumes_mid_generation():
    d = _dcfg("streaming", gen_len=32)
    dec = DiffusionDecoder(CFG, PARAMS, d)
    ref = dec.generate(PROMPTS.copy())
    st = dec.prefill(PROMPTS.copy())
    dec.decode_block(st)                       # block 0 done at B=4
    sub = dec.take_rows(st, [1, 3])            # compact to B=2
    while not sub.finished:
        dec.decode_block(sub)
    out = dec.finalize(sub)
    assert (out.tokens == ref.tokens[[1, 3]]).all()


# ------------------------------------------------------------ KV pool


def test_pool_reuse_and_eviction():
    pool = PrefixKVPool(CFG, max_free=2)
    a = pool.acquire(2, 24)
    b = pool.acquire(2, 24)
    assert pool.misses == 2 and pool.hits == 0
    pool.release(2, 24, a)
    pool.release(2, 24, b)
    got = pool.acquire(2, 24)
    assert pool.hits == 1 and got is b          # most recently released
    pool.release(2, 24, got)                    # free: [a, b]
    pool.release(4, 24, pool.acquire(4, 24))    # evicts a (oldest)
    pool.release(2, 48, pool.acquire(2, 48))    # evicts b
    assert pool.evictions == 2
    assert pool.free_buffers == 2
    assert pool.acquire(8, 24) is not None      # miss allocates fresh
    assert pool.stats()["misses"] == 5


def test_pool_reused_across_requests():
    """Sequential same-bucket requests reuse one KV buffer instead of
    allocating per request."""
    eng = ContinuousEngine(CFG, PARAMS, _dcfg(), max_slots=2)
    prompt = PROMPTS[0]
    eng.submit(prompt, max_tokens=16)
    eng.run_to_completion()
    misses0 = eng.pool.misses
    eng.submit(prompt, max_tokens=16)
    eng.run_to_completion()
    assert eng.pool.misses == misses0          # no new allocation
    assert eng.pool.hits >= 1


# ------------------------------------------------------------ scheduler


def test_backfill_on_early_exit():
    """With every slot taken, a waiting request is admitted as soon as
    early exits shrink a gang — before the gang finishes its full
    generation."""
    cfg_eos = _fake_eos_cfg(gen_len=32)
    d = _dcfg("streaming", gen_len=32)
    sched = BlockScheduler(cfg_eos, PARAMS, d, max_slots=2, tokenizer=TOK)
    for b in range(3):
        sched.submit(PROMPTS[b], 32, 32)
    saw_concurrent_gangs = False
    saw_shrink = False
    guard = 0
    while not sched.idle and guard < 100:
        guard += 1
        sizes = [g.batch for g in sched.gangs]
        sched.tick()
        new_sizes = [g.batch for g in sched.gangs]
        if len(new_sizes) >= 2:
            saw_concurrent_gangs = True
        if sizes and new_sizes and min(new_sizes) < max(sizes):
            saw_shrink = True
    assert guard < 100
    # the fake-EOS model exits early almost immediately: slots must have
    # been recycled into a second concurrent gang (the third request
    # decodes while the first gang is still live) or via gang shrink
    assert saw_concurrent_gangs or saw_shrink


def test_early_exit_frees_compute():
    """Continuous mode spends fewer NFEs than synchronous batch on an
    early-exit-heavy workload: finished rows leave the batch at block
    boundaries instead of being decoded to the last block."""
    cfg_eos = _fake_eos_cfg(gen_len=32)
    d = _dcfg("streaming", gen_len=32)
    sync = ServingEngine(cfg_eos, PARAMS, d, max_batch=4, mode="batch")
    cont = ServingEngine(cfg_eos, PARAMS, d, max_batch=4, mode="continuous")
    for b in range(4):
        sync.submit(TOK.decode(PROMPTS[b])[:10].ljust(10, "x"),
                    max_tokens=32)
    # token prompts must match exactly: drive continuous with the same
    # encoded prompts through its scheduler
    for b in range(4):
        cont._continuous.scheduler.submit(
            sync.tok.encode(TOK.decode(PROMPTS[b])[:10].ljust(10, "x")),
            32, 32)
    sync_done = sync.run_to_completion()
    cont_done = cont._continuous.run_to_completion()
    assert len(sync_done) == len(cont_done) == 4
    sync_nfe = sync_done[0].nfe                 # batch NFE, all rows
    cont_nfe = max(c.nfe for c in cont_done)
    assert cont_nfe <= sync_nfe


@pytest.mark.parametrize("method", [m for m in METHODS if m != "dkv"])
def test_continuous_matches_batch_tokens(method):
    """Acceptance: continuous mode is token-identical to the
    synchronous path on a ragged workload (mixed gen_len buckets,
    backfill + compaction active)."""
    d = _dcfg(method)
    prompts = [TOK.decode(p) for p in
               RNG.integers(32, 126, (6, 9)).astype(np.int32)]
    budgets = [16, 8, 16, 8, 16, 8]
    sync = ServingEngine(CFG, PARAMS, d, max_batch=2, mode="batch")
    cont = ServingEngine(CFG, PARAMS, d, max_batch=2, mode="continuous")
    us = [sync.submit(p, mt) for p, mt in zip(prompts, budgets)]
    uc = [cont.submit(p, mt) for p, mt in zip(prompts, budgets)]
    ds_ = {c.uid: c for c in sync.run_to_completion()}
    dc = {c.uid: c for c in cont.run_to_completion()}
    for a, b in zip(us, uc):
        assert (ds_[a].tokens == dc[b].tokens).all(), method


def test_dkv_equivalence_structural():
    """dkv resumability and continuous/batch equivalence. dkv's
    step-level KV freezing amplifies run-to-run XLA:CPU noise
    (work-stealing threaded matmul reductions — persists even under
    --xla_cpu_multi_thread_eigen=false) into occasional argmax flips,
    so exact token identity is not assertable for it on this backend.
    Structure is: with early_exit off the dkv schedule is fixed
    (1 prefill + 8 steps/block), so NFE and per-block step counts must
    match exactly, and token agreement must stay far above what any
    scheduling logic bug (wrong cache carry / block resume) would
    leave intact."""
    d = _dcfg("dkv", early_exit=False)
    dec = DiffusionDecoder(CFG, PARAMS, d)
    ref = dec.generate(PROMPTS[:2].copy())
    st = dec.prefill(PROMPTS[:2].copy())
    while not st.finished:
        dec.decode_block(st)
    out = dec.finalize(st)
    assert out.nfe == ref.nfe == 1 + 2 * 8
    assert out.steps_per_block == ref.steps_per_block
    assert (out.tokens != CFG.mask_token_id).all()
    assert (out.tokens == ref.tokens).mean() > 0.5

    prompts = [TOK.decode(p) for p in
               RNG.integers(32, 126, (3, 9)).astype(np.int32)]
    sync = ServingEngine(CFG, PARAMS, d, max_batch=4, mode="batch")
    cont = ServingEngine(CFG, PARAMS, d, max_batch=4, mode="continuous")
    us = [sync.submit(p, 16) for p in prompts]
    uc = [cont.submit(p, 16) for p in prompts]
    ds_ = {c.uid: c for c in sync.run_to_completion()}
    dc = {c.uid: c for c in cont.run_to_completion()}
    assert len(ds_) == len(dc) == 3
    a = np.stack([ds_[u].tokens for u in us])
    b = np.stack([dc[u].tokens for u in uc])
    assert (a == b).mean() > 0.5


def test_pad_pow2_admits_groups_larger_than_pow2_capacity():
    """Regression: with pad_pow2, a group whose padded size exceeds
    max_slots must be split down the pow2 ladder, not livelock the
    queue (5 requests at max_slots=6 -> gangs of 4 + 1, all served)."""
    eng = ContinuousEngine(CFG, PARAMS, _dcfg(), max_slots=6,
                           pad_pow2=True)
    uids = [eng.submit(PROMPTS[b % 4], max_tokens=16) for b in range(5)]
    done = eng.run_to_completion()
    assert sorted(c.uid for c in done) == sorted(uids)


def test_admission_control():
    sched = BlockScheduler(CFG, PARAMS, _dcfg(), max_slots=2,
                           max_waiting=2, tokenizer=TOK)
    sched.submit(PROMPTS[0], 16, 16)
    sched.submit(PROMPTS[1], 16, 16)
    with pytest.raises(RuntimeError, match="admission rejected"):
        sched.submit(PROMPTS[2], 16, 16)


def test_preemption_resumes_exactly():
    d = _dcfg("streaming", gen_len=32)
    ref = DiffusionDecoder(CFG, PARAMS, d).generate(PROMPTS[:1].copy())
    eng = ContinuousEngine(CFG, PARAMS, d, max_slots=4)
    uid = eng.submit(PROMPTS[0], max_tokens=32)
    eng.step()                                  # block 0 decoded
    eng.preempt(uid)
    eng.step()                                  # vacated + re-admitted
    assert eng.scheduler.paused or eng.scheduler.gangs
    done = eng.run_to_completion()
    assert len(done) == 1
    assert (done[0].tokens == ref.tokens[0]).all()


# ------------------------------------------------------------ cancellation


def test_cancel_mid_gang_frees_slot_and_preserves_survivors():
    """cancel(uid) on an active row releases the slot at the next
    block boundary (before that tick's decode), yields a partial
    cancelled Completion, and leaves every surviving row bit-identical
    to an uncancelled run (the batch-invariance contract)."""
    d = _dcfg("streaming", gen_len=32, early_exit=False)
    ref = DiffusionDecoder(CFG, PARAMS, d).generate(PROMPTS.copy())
    eng = ContinuousEngine(CFG, PARAMS, d, max_slots=4)
    uids = [eng.submit(PROMPTS[b], max_tokens=32) for b in range(4)]
    eng.step()                                  # block 0 at B=4
    assert eng.scheduler.slots_used == 4
    assert eng.cancel(uids[1]) is None          # active -> deferred
    comps = eng.step()                          # cancel applies first
    cancelled = [c for c in comps if c.cancelled]
    assert [c.uid for c in cancelled] == [uids[1]]
    assert cancelled[0].n_blocks == 1           # paid for exactly 1 block
    assert len(cancelled[0].tokens) == 8        # the committed block only
    assert eng.scheduler.slots_used == 3        # slot freed for good
    comps += eng.run_to_completion()
    done = {c.uid: c for c in comps}
    for b in (0, 2, 3):                         # survivors untouched
        assert (done[uids[b]].tokens == ref.tokens[b]).all()
    assert (cancelled[0].tokens == ref.tokens[1][:8]).all()
    assert eng.metrics.cancelled == 1


def test_cancel_before_admit_drains_waiting_queue():
    """Cancelling a request still in the waiting queue removes it
    immediately (no slot ever consumed) and returns its empty
    Completion synchronously."""
    d = _dcfg("streaming", gen_len=16, early_exit=False)
    eng = ContinuousEngine(CFG, PARAMS, d, max_slots=2)
    uids = [eng.submit(PROMPTS[b], max_tokens=16) for b in range(3)]
    eng.step()                                  # 2 admitted, 1 waiting
    assert len(eng.scheduler.waiting) == 1
    comp = eng.cancel(uids[2])
    assert comp is not None and comp.cancelled and comp.n_tokens == 0
    assert not eng.scheduler.waiting
    rest = eng.run_to_completion()
    assert sorted(c.uid for c in rest) == sorted(uids[:2])
    assert not any(c.cancelled for c in rest)


def test_cancel_unknown_or_finished_uid_is_noop():
    eng = ContinuousEngine(CFG, PARAMS, _dcfg(), max_slots=2)
    uid = eng.submit(PROMPTS[0], max_tokens=16)
    assert eng.cancel(999) is None
    assert not eng.scheduler._cancel            # no stale flag parked
    done = eng.run_to_completion()
    assert len(done) == 1 and not done[0].cancelled
    assert eng.cancel(uid) is None              # finished: ignored
    assert not eng.scheduler._cancel


def test_completion_trims_to_requested_max_tokens():
    """gen_len rounds max_tokens up to a block multiple; the surplus
    must never leave the engine — neither in Completion.tokens/text nor
    in the streamed chunk text."""
    d = _dcfg("streaming", gen_len=16, early_exit=False)
    eng = ContinuousEngine(CFG, PARAMS, d, max_slots=2)
    uid = eng.submit(PROMPTS[0], max_tokens=11)   # rounds up to 16
    got = []
    eng.on_chunk(uid, got.append)
    comp = eng.run_to_completion()[0]
    assert comp.max_tokens == 11
    assert len(comp.tokens) == 11 and comp.n_tokens <= 11
    assert comp.text == TOK.decode(comp.tokens)
    # chunk text: block 0 carries 8 tokens' text, block 1 only 3
    assert "".join(c.text for c in got) == comp.text


# ------------------------------------------------------------ streaming


def test_stream_chunks_ordered_and_complete():
    d = _dcfg("streaming", gen_len=16, early_exit=False)
    eng = ContinuousEngine(CFG, PARAMS, d, max_slots=4)
    uids = [eng.submit(PROMPTS[b], max_tokens=16) for b in range(3)]
    seen = {}
    for chunk in eng.stream():
        seen.setdefault(chunk.uid, []).append(chunk)
    assert set(seen) == set(uids)
    for uid in uids:
        blocks = [c.block_idx for c in seen[uid]]
        assert blocks == list(range(len(blocks)))      # in order, gapless
        assert [c.finished for c in seen[uid]].count(True) == 1
        assert seen[uid][-1].finished
        joined = "".join(c.text for c in seen[uid][:-1])
        assert isinstance(joined, str)


def test_stream_callbacks_fire_per_block():
    d = _dcfg("streaming", gen_len=16, early_exit=False)
    eng = ContinuousEngine(CFG, PARAMS, d, max_slots=2)
    uid = eng.submit(PROMPTS[0], max_tokens=16)
    got = []
    eng.on_chunk(uid, got.append)
    eng.run_to_completion()
    assert [c.block_idx for c in got] == [0, 1]
    assert got[-1].finished


def test_stream_router_unsubscribes_finished():
    router = StreamRouter()
    router.subscribe(7, lambda c: None)
    from repro.serving.types import BlockChunk
    router.publish([BlockChunk(7, 0, np.zeros(2, np.int32), "", True, False)])
    assert 7 not in router._subs


def test_stream_router_hygiene():
    """Regression: a raising subscriber must not abort delivery to
    later subscribers or later chunks (it is logged and dropped), and
    emptied subscriber lists — per-uid and wildcard — are GC'd."""
    from repro.serving.types import BlockChunk

    def chunk(uid, finished=False):
        return BlockChunk(uid, 0, np.zeros(1, np.int32), "", finished,
                          False)

    router = StreamRouter()
    good, wild = [], []

    def bad(c):
        raise RuntimeError("boom")

    router.subscribe(1, bad)
    router.subscribe(1, good.append)
    router.subscribe(None, wild.append)
    router.publish([chunk(1), chunk(1)])
    assert len(good) == 2 and len(wild) == 2    # bad didn't block anyone
    assert bad not in router._subs.get(1, [])   # bad was dropped
    # wildcard entry is GC'd once its last subscriber leaves
    router.unsubscribe(None, wild.append)
    assert None not in router._subs
    # a raising wildcard-only subscriber leaves no empty list behind
    router.subscribe(None, bad)
    router.publish([chunk(2)])
    assert None not in router._subs


# ------------------------------------------------------------ metrics


def test_metrics_snapshot():
    eng = ContinuousEngine(CFG, PARAMS, _dcfg(), max_slots=2)
    for b in range(3):
        eng.submit(PROMPTS[b], max_tokens=16)
    done = eng.run_to_completion()
    snap = eng.metrics.snapshot()
    assert snap["requests"] == 3 == len(done)
    assert snap["throughput_tok_s"] >= 0
    assert 0 < snap["mean_occupancy"] <= 1
    for c in done:
        assert c.ttfb_s <= c.latency_s
        assert c.queue_s <= c.ttfb_s
    assert snap["ttfb_p50_s"] <= snap["latency_p50_s"]
    assert round_up_blocks(13, 8) == 16


def test_legacy_engine_api_continuous_default():
    eng = ServingEngine(CFG, PARAMS, _dcfg(), max_batch=4)
    assert eng.mode == "continuous"
    for i in range(3):
        eng.submit(f"Q:{i}{i}+11=? A:", max_tokens=16)
    done = eng.run_to_completion()
    assert len(done) == 3
    assert all(isinstance(c.text, str) for c in done)
    assert eng.throughput > 0


# ------------------------------------------------------ placement layer


def test_executor_1x1_identity_and_pool_binding():
    """A DecodeExecutor on a trivial 1x1 mesh is the identity
    placement: bit-identical tokens, data_extent 1. Pools are bound to
    one executor — a host pool handed to an executor-backed scheduler
    must be refused (cross-mesh buffer reuse hazard)."""
    from repro.launch.mesh import make_host_mesh
    from repro.serving import DecodeExecutor

    d = _dcfg("streaming")
    ref = DiffusionDecoder(CFG, PARAMS, d).generate(PROMPTS.copy())
    ex = DecodeExecutor(CFG, PARAMS, make_host_mesh(1, 1))
    got = DiffusionDecoder(CFG, None, d, executor=ex).generate(
        PROMPTS.copy())
    assert (ref.tokens == got.tokens).all()
    assert ex.data_extent == 1
    with pytest.raises(ValueError):
        ContinuousEngine(CFG, PARAMS, d, pool=PrefixKVPool(CFG),
                         executor=ex)
    # placement-keyed pool: host and executor pools bucket differently
    host_pool, ex_pool = PrefixKVPool(CFG), PrefixKVPool(CFG, executor=ex)
    assert host_pool._key(2, 24) != ex_pool._key(2, 24)


def test_pooled_prefix_reuse_across_gangs_no_aliasing():
    """Regression (reuse-after-free hazard): a sub-state extracted by
    take_rows must not alias KV of the gang it left — the gang's buffer
    goes back to the pool, is handed to a *new* gang, and gets
    rewritten (or donated on accelerators, where aliased memory is
    *dead*). dkv is the method whose cache carries across blocks, and
    its in-process token comparison is unsound (ulp noise, see
    test_dkv_equivalence_structural), so the contract is asserted on
    the cache bytes themselves: the parked KV must be bit-stable while
    a second gang churns the pooled buffer."""
    d = _dcfg("dkv", gen_len=32)         # dkv: cache carries across blocks
    dec = DiffusionDecoder(CFG, PARAMS, d)
    pool = PrefixKVPool(CFG)

    st = dec.prefill(PROMPTS.copy(), cache=pool.acquire(4, 42))
    dec.decode_block(st)
    sub = dec.take_rows(st, [1])          # park row 1 mid-generation
    snap = [np.array(leaf) for leaf in jax.tree.leaves(sub.cache)]
    # the first gang's buffer returns to the pool and a second gang
    # reuses (and on accelerators would donate) it before the parked
    # row resumes
    pool.release(4, 42, st.cache)
    st2 = dec.prefill(PROMPTS.copy(), cache=pool.acquire(4, 42))
    assert pool.hits >= 1                 # really the same buffer
    while not st2.finished:
        dec.decode_block(st2)
    for before, after in zip(snap, jax.tree.leaves(sub.cache)):
        assert (before == np.array(after)).all(), \
            "parked take_rows KV aliased the pooled buffer"
    while not sub.finished:               # parked row still completes
        dec.decode_block(sub)
    assert dec.finalize(sub).tokens.shape == (1, 32)


def test_gang_sizes_round_to_batch_multiple():
    """Data-shard-aware bucketing: gang batches round up to the data
    extent so sharded placement never falls back silently; pad lanes
    are real (replicate row 0) but carry no request."""
    sched = BlockScheduler(CFG, PARAMS, _dcfg(), max_slots=8,
                           batch_multiple=4)
    assert sched._pad_batch(1) == 4 and sched._pad_batch(5) == 8
    for b in range(3):
        sched.submit(PROMPTS[b], 16, 16)
    sched.tick()
    assert len(sched.gangs) == 1
    gang = sched.gangs[0]
    assert gang.batch == 4
    assert sum(r is not None for r in gang.requests) == 3
    # a multiple that doesn't divide max_slots must not livelock
    sched2 = BlockScheduler(CFG, PARAMS, _dcfg(), max_slots=8,
                            batch_multiple=3)
    n, padded = sched2._gang_target(8, 8, sched2._decoder(16))
    assert n > 0 and padded <= 8 and padded % 3 == 0


# ------------------------------------------------------ cross-gang merge


def test_cross_gang_merge_of_stragglers():
    """Two same-bucket gangs left ragged (here: one row of each
    cancelled) fuse into ONE gang at the next block boundary — half the
    block calls — and the surviving rows stay bit-identical."""
    d = _dcfg("streaming", gen_len=24, early_exit=False)
    ref = DiffusionDecoder(CFG, PARAMS, d).generate(PROMPTS.copy())
    eng = ContinuousEngine(CFG, PARAMS, d, max_slots=4, max_gang=2,
                           tokenizer=TOK)
    uids = [eng.submit(PROMPTS[i], max_tokens=24) for i in range(4)]
    eng.step()                            # two gangs of 2 decode block 0
    assert len(eng.scheduler.gangs) == 2
    eng.cancel(uids[1])
    eng.cancel(uids[3])
    eng.step()          # cancels vacate -> stragglers merge -> block 1
    assert eng.scheduler.merges == 1
    assert len(eng.scheduler.gangs) == 1
    assert eng.scheduler.gangs[0].batch == 2
    comps = {c.uid: c for c in eng.run_to_completion()}
    assert (comps[uids[0]].tokens == ref.tokens[0]).all()
    assert (comps[uids[2]].tokens == ref.tokens[2]).all()
    assert eng.metrics.snapshot()["gang_merges"] == 1


def test_merge_respects_max_gang_and_skips_dkv():
    """Gangs whose combined open rows exceed max_gang stay separate;
    dkv gangs (non-batch-invariant) are never merged."""
    d = _dcfg("streaming", gen_len=24, early_exit=False)
    eng = ContinuousEngine(CFG, PARAMS, d, max_slots=4, max_gang=2,
                           tokenizer=TOK)
    for i in range(4):
        eng.submit(PROMPTS[i], max_tokens=24)
    eng.step()
    eng.step()                            # 2+2 > max_gang: no merge
    assert eng.scheduler.merges == 0 and len(eng.scheduler.gangs) == 2
    dv = _dcfg("dkv", gen_len=24)
    eng2 = ContinuousEngine(CFG, PARAMS, dv, max_slots=4, max_gang=1,
                            tokenizer=TOK)
    for i in range(2):
        eng2.submit(PROMPTS[i], max_tokens=24)
    eng2.step()                           # two 1-row dkv gangs
    assert len(eng2.scheduler.gangs) == 2
    eng2.scheduler.max_gang = 2           # merge would now fit...
    eng2.step()
    assert eng2.scheduler.merges == 0     # ...but dkv is never merged
    eng2.run_to_completion()
