"""Subprocess body for the recompile-watchdog test (test_prewarm.py).

Runs under the exact process discipline ``launch/serve.py`` uses: host
budget env applied by the PARENT (before this interpreter existed),
persistent compile cache enabled, every engine pre-warmed for every
shape bucket the workload will hit — then a mixed-method, multi-bucket,
merge-and-preempt-heavy load. The contract under test: the measurement
window contains ZERO compiles (``post_warm_compiles == 0`` per engine).

Prints one JSON report as the last stdout line.
"""
import json
import sys

import numpy as np

from repro.launch import host as host_budgeting

CACHE_DIR = sys.argv[1]
PC_ON = host_budgeting.enable_compile_cache(CACHE_DIR)

import jax  # noqa: E402  (cache config must precede first compile)

from repro.core.decoder import DecodeConfig  # noqa: E402
from repro.launch.mesh import make_submeshes  # noqa: E402
from repro.models import get_config, init_params  # noqa: E402
from repro.serving import ContinuousEngine, DecodeExecutor  # noqa: E402

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
# two shape buckets; prompt_len is the EXACT tokenized length (shape
# buckets don't round — a one-byte miss is a fresh prefill variant,
# which is precisely what this watchdog exists to catch)
SHORT = [f"Q:{i}7+{i}1=? A:" for i in range(8)]
LONG = [f"Q:{i}70+{i}10=??? A:" for i in range(8)]
BUCKETS = [(len(SHORT[0]), 16), (len(LONG[0]), 8)]


def drive(eng):
    """Mixed-bucket load exercising every post-admission code path that
    could compile: queueing beyond max_slots, straggler merges, and a
    preempt/park/resume cycle."""
    uids, comps = [], []
    for i in range(3):                      # staggered: forces ragged
        uids.append(eng.submit(SHORT[i], max_tokens=16))
        uids.append(eng.submit(LONG[i], max_tokens=8))
    comps += eng.step()                     # gangs form, stragglers next
    for i in range(3, 8):
        uids.append(eng.submit(SHORT[i], max_tokens=16))
    comps += eng.step()
    eng.preempt(uids[-1])                   # park + resume path
    comps += eng.run_to_completion()
    return uids, comps


def main():
    budget = host_budgeting.compute_host_budget(2)
    meshes = make_submeshes(2, 1, 1)
    methods = ("streaming", "fast")         # mixed-method fleet
    engines = [
        ContinuousEngine(
            CFG, PARAMS,
            DecodeConfig(method=m, gen_len=16, block_size=8, window=16),
            max_slots=4, executor=DecodeExecutor(CFG, PARAMS, mesh),
            host_budget=budget)
        for m, mesh in zip(methods, meshes)]
    warm = [e.prewarm(BUCKETS) for e in engines]

    per_engine = []
    for m, eng in zip(methods, engines):
        uids, comps = drive(eng)
        assert len(comps) == len(uids), (m, len(comps), len(uids))
        watch = eng.scheduler.compile_watch
        per_engine.append({
            "method": m,
            "requests": len(comps),
            "prewarm_variants": warm[len(per_engine)]["variants"],
            "compile_misses": watch.misses,
            "post_warm_compiles": watch.post_warm,
            "host_threads": eng.metrics.host_threads,
        })

    print(json.dumps({
        "n_devices": len(jax.devices()),
        "persistent_cache": PC_ON,
        "pjrt_nproc": budget.intra_op,
        "per_engine": per_engine,
    }))


if __name__ == "__main__":
    main()
