"""Mesh-parallel (sharded) decode tests for the DecodeExecutor
placement layer.

Runs ``tests/_sharded_child.py`` once in a subprocess with 8 forced
host devices (conftest keeps the main process single-device) and
asserts over its JSON report:

* token identity between single-device and data-sharded decode for the
  four batch-invariant methods (the scheduler/executor contract);
* dkv and model-parallel meshes get structural equivalence — dkv's
  step-level KV freezing and model-axis reduction splits both sit in
  documented ulp territory (EXPERIMENTS.md), so exactness is asserted
  only where the math is order-identical, agreement everywhere;
* the divisibility fallback: a batch that doesn't divide the data axis
  is replicated, never silently padded, and stays exact;
* a sharded ContinuousEngine end to end: data-shard-aware gang
  rounding, placement-bound pool, per-row token identity.
"""
import json
import os
import subprocess
import sys

import pytest

_REPORT = {}


def _report():
    if not _REPORT:
        env = dict(
            os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu",
            XLA_FLAGS="--xla_force_host_platform_device_count=8")
        r = subprocess.run(
            [sys.executable, os.path.join("tests", "_sharded_child.py")],
            capture_output=True, text=True, timeout=560, env=env, cwd=".")
        assert r.returncode == 0, r.stdout + r.stderr
        _REPORT.update(json.loads(r.stdout.strip().splitlines()[-1]))
    return _REPORT


def test_child_ran_on_forced_host_mesh():
    rep = _report()
    assert rep["n_devices"] == 8
    # full matrix: data = 2/4 and model = 1/2 for all five methods
    combos = {(r["method"], r["data"], r["model"]) for r in rep["runs"]}
    for m in ("vanilla", "dkv", "prefix", "fast", "streaming"):
        for mesh in ((2, 1), (4, 1), (2, 2)):
            assert (m,) + mesh in combos


def test_data_sharded_token_identity():
    """data=2/4, model=1: per-row math is untouched (batch split only),
    so the batch-invariant methods must be bit-identical and every
    method must spend the same NFE budget."""
    for r in _report()["runs"]:
        if r["model"] != 1:
            continue
        assert r["nfe"] == r["ref_nfe"], r
        if r["method"] != "dkv":
            assert r["exact"], r


def test_dkv_and_model_parallel_structural():
    """dkv (documented XLA:CPU ulp noise under batch/layout change) and
    model-sharded meshes (reduction-order change when contractions
    split over the model axis) are asserted structurally: valid tokens,
    same NFE schedule shape, and near-total agreement — a placement
    *bug* (wrong rows, stale KV, garbled gather) craters agreement to
    chance (~1/vocab), which is what this guards."""
    for r in _report()["runs"]:
        assert r["valid"], r
        assert r["agree"] >= 0.95, r


def test_divisibility_fallback_replicates_exactly():
    fb = _report()["fallback"]
    assert fb["replicated"], "batch 3 on data=2 must fall back"
    assert fb["sharded_even"], "batch 4 on data=2 must shard"
    assert fb["exact"], "replicated fallback must stay bit-identical"


def test_prefix_cache_exact_on_data_sharded_mesh():
    """Cached vs cold prefill is bit-identical under DecodeExecutor
    placement (data=2): chunk KV slices round-trip host staging and
    the sharded gang buffers without drift, the store is placement-
    bound, and the sharded ContinuousEngine path reuses chunks the
    direct decoders inserted (prompt KV is method/gen-len agnostic)."""
    pc = _report()["prefix_cache"]
    assert pc["exact"], "warm prefill must equal cold on the mesh"
    assert pc["hit_tokens"] > 0, "second run must hit the store"
    assert pc["store_placement"] != ["host"]
    assert pc["engine_exact"]
    assert all(h > 0 for h in pc["engine_hits"]), \
        "engine rows must reuse the chunks the direct runs inserted"


def test_sharded_engine_end_to_end():
    eng = _report()["engine"]
    assert eng["batch_multiple"] == 2
    assert eng["pad_3"] == 4, "gang sizes round up to the data extent"
    assert eng["served"] == 3
    assert eng["exact"], "sharded engine rows must match single-device"
    assert eng["pool_placement"] != ["host"], \
        "pool must be placement-bound to the executor's mesh"
