"""Quality-audit layer tests (`repro.obs.audit`).

Fault injection is the core of this suite: a flipped committed token
and a poisoned cached prefix chunk must each be *caught* by the shadow
auditor, attributed to the right divergence source and block index,
and produce a well-formed flight-recorder dump — including when the
audited request lived through preempt/resume and a cross-engine steal.
The clean matrix is the complement: every method x {fused, host} x
{cached, cold} serving configuration audits clean (dkv per its
documented structural contract), so the auditor can run always-on
without crying wolf.
"""
import asyncio
import contextlib
import json
import os
import threading
import time
import types

import jax
import numpy as np
import pytest

from repro.cache import HOST_PLACEMENT, PrefixKVCache
from repro.core.decoder import DecodeConfig
from repro.models import get_config, init_params
from repro.obs import Tracer
from repro.obs.audit import (AuditConfig, FlightRecorder, ShadowAuditor,
                             SLOWatchdog)
from repro.server import EngineLoop, HttpFrontend
from repro.server import client as C
from repro.serving import ContinuousEngine

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
MAX_TOKENS = 16
BLOCK = 8
CHUNK = 8                       # prefix-cache chunk (tokens)
# 16 chars = two full cache chunks, one shape bucket
PROMPTS = [f"Q:{i}{(i + 3) % 10}+{(i + 5) % 10}{i}=? Answer" for i in range(6)]
TEST_TIMEOUT_S = 240


def make_engine(method="streaming", fused=True, cached=False,
                max_slots=2):
    dcfg = DecodeConfig(method=method, gen_len=MAX_TOKENS,
                        block_size=BLOCK, window=4, tau0=0.5,
                        fused=fused, prefix_cache=cached,
                        cache_chunk=CHUNK)
    store = PrefixKVCache(chunk_tokens=CHUNK,
                          placement=HOST_PLACEMENT) if cached else None
    return ContinuousEngine(CFG, PARAMS, dcfg, max_slots=max_slots,
                            prefix_cache=store)


def attach(eng, tmp_path=None, tracer=None, oracle="auto", rate=1.0,
           **cfg):
    flight = None
    if tmp_path is not None:
        flight = FlightRecorder(str(tmp_path), tracer=tracer)
    auditor = ShadowAuditor(
        eng, AuditConfig(sample_rate=rate, oracle=oracle, **cfg),
        tracer=tracer, flight=flight)
    eng.attach_auditor(auditor)
    return auditor, flight


def serve_and_audit(eng, prompts, tracer=None):
    """Run ``prompts`` to completion, then drain every audit."""
    for p in prompts:
        eng.submit(p, max_tokens=MAX_TOKENS,
                   trace_id=tracer.new_trace_id()
                   if tracer is not None else "")
    comps = eng.run_to_completion()
    eng.drain_audits()
    return comps


# --------------------------------------------------- clean matrix

MATRIX = [(m, fused, cached)
          for m in ("vanilla", "dkv", "prefix", "fast", "streaming")
          for fused in (True, False)
          for cached in ((False, True) if m != "vanilla" else (False,))]


@pytest.mark.parametrize("method,fused,cached", MATRIX)
def test_clean_run_zero_divergences(method, fused, cached):
    """Every serving configuration audits clean against its oracle
    lane(s); dkv may only report its documented structural class."""
    eng = make_engine(method, fused=fused, cached=cached)
    auditor, _ = attach(eng)
    comps = serve_and_audit(eng, PROMPTS[:2])
    assert len(comps) == 2 and not any(c.cancelled for c in comps)
    assert auditor.sampled == 2
    assert auditor.completed == 2
    assert auditor.errors == 0 and auditor.dropped == 0
    div = dict(auditor.divergences)
    structural = div.pop("dkv-structural")
    assert sum(div.values()) == 0, f"real divergences on clean run: {div}"
    if method != "dkv":
        assert structural == 0
    # lane coverage: host always; cold only when the cache is live
    lanes = {r.lane for r in auditor.results}
    assert lanes == ({"host", "cold"} if cached else {"host"})
    # calibration rides every audited token; clean runs agree everywhere
    # except dkv's structural divergence tail
    assert sum(auditor.conf_total) > 0
    if method != "dkv":
        assert auditor.conf_agree == auditor.conf_total


# --------------------------------------------------- fault injection

def test_injected_token_flip_caught_and_attributed(tmp_path):
    """A flipped committed token is detected, classified fused-vs-host,
    attributed to the right block + span, and dumps a flight dir."""
    tracer = Tracer()
    eng = make_engine()
    eng.set_tracer(tracer, "engine-0")
    auditor, flight = attach(eng, tmp_path, tracer=tracer)
    flipped = {}

    def flip(tokens, lane):
        pos = len(tokens) // 2
        tokens[pos] = (tokens[pos] + 1) % CFG.vocab_size
        flipped["pos"] = pos
        return tokens

    auditor.inject = flip
    comps = serve_and_audit(eng, PROMPTS[:1], tracer=tracer)
    assert auditor.completed == 1
    assert auditor.divergences == {"fused-vs-host": 1,
                                   "cached-vs-cold": 0,
                                   "stolen-vs-resident": 0,
                                   "dkv-structural": 0}
    res = [r for r in auditor.results if not r.matched]
    assert len(res) == 1
    r = res[0]
    assert r.position == flipped["pos"]
    assert r.block == flipped["pos"] // BLOCK
    assert r.uid == comps[0].uid
    assert r.got != r.expected and r.got >= 0 and r.expected >= 0
    # span attribution resolves to the live block span, not evicted
    assert r.span == f"block {r.block}"
    # regret counts early-exited requests only
    assert auditor.regret == (1 if comps[0].early_exited else 0)
    # disagreeing tokens land in the calibration counters
    assert sum(auditor.conf_agree) < sum(auditor.conf_total)
    # the tracer carries the divergence instant
    assert any(e.get("name") == "audit_divergence"
               and e["args"]["source"] == "fused-vs-host"
               and e["args"]["block"] == r.block
               for e in tracer.events())
    _assert_flight_dump(flight, tmp_path, "audit-fused-vs-host")


def test_poisoned_cache_chunk_caught_by_cold_lane(tmp_path):
    """Corrupting a cached prefix chunk's KV changes served tokens; the
    host lane shares the store (reproduces the poison, matches) while
    the cache-bypass cold lane diverges -> cached-vs-cold."""
    tracer = Tracer()
    eng = make_engine(cached=True)
    eng.set_tracer(tracer, "engine-0")
    auditor, flight = attach(eng, tmp_path, tracer=tracer)
    prompt = PROMPTS[0]

    # request 1 populates the cache and audits clean on both lanes
    serve_and_audit(eng, [prompt], tracer=tracer)
    assert auditor.divergences_total() == 0
    store = eng.prefix_cache
    tok = np.asarray(eng.tok.encode(prompt), np.int32)
    chain = store.tree.walk(tok)
    assert chain, "prompt left no cached chunks"
    # poison the first chunk's KV in place (large perturbation so the
    # attention outputs actually move)
    chain[0].payload = jax.tree_util.tree_map(
        lambda a: a + 7.0
        if np.issubdtype(np.asarray(a).dtype, np.floating) else a,
        chain[0].payload)

    # request 2 prefills over the poisoned chunk
    comps = serve_and_audit(eng, [prompt], tracer=tracer)
    assert comps[0].cache_hit_tokens > 0, "expected a cache hit"
    assert auditor.divergences["cached-vs-cold"] == 1
    assert auditor.divergences["fused-vs-host"] == 0
    bad = [r for r in auditor.results if not r.matched]
    assert len(bad) == 1 and bad[0].lane == "cold"
    assert bad[0].source == "cached-vs-cold"
    assert bad[0].block == bad[0].position // BLOCK >= 0
    _assert_flight_dump(flight, tmp_path, "audit-cached-vs-cold")


def test_divergence_on_stolen_request_classified(tmp_path):
    """A request that was preempted, stolen, and finished on the thief
    still audits end-to-end on the thief; an injected flip there is
    classified stolen-vs-resident and the flight dump stays
    well-formed."""
    victim = make_engine(max_slots=1)
    thief = make_engine(max_slots=1)
    tracer = Tracer()
    thief.set_tracer(tracer, "thief")
    auditor, flight = attach(thief, tmp_path, tracer=tracer)

    uid = victim.submit(PROMPTS[0], max_tokens=MAX_TOKENS)
    victim.step()                         # prefill + block 0
    victim.preempt(uid)
    victim.scheduler._compact()
    req, state = victim.steal_paused()
    assert req.uid == uid
    thief.adopt_paused(req, state)
    comps = thief.run_to_completion()
    assert len(comps) == 1 and comps[0].stolen

    # clean audit of the stolen completion first
    thief.drain_audits()
    assert auditor.completed == 1
    assert auditor.divergences_total() == 0

    # then the same completion with a flip: stolen-vs-resident
    auditor.inject = lambda t, lane: (t.__setitem__(0, (t[0] + 1)
                                                    % CFG.vocab_size)
                                      or t)
    auditor.on_completion(comps[0])
    thief.drain_audits()
    assert auditor.divergences["stolen-vs-resident"] == 1
    bad = [r for r in auditor.results if not r.matched]
    assert bad[-1].block == 0 and bad[-1].position == 0
    _assert_flight_dump(flight, tmp_path, "audit-stolen-vs-resident")
    assert victim.run_to_completion() == []


def _assert_flight_dump(flight, tmp_path, reason):
    """One dump dir exists for ``reason`` and all three artifacts are
    present and parseable; trace.json is Chrome-trace shaped."""
    assert flight.dumps >= 1
    dirs = [d for d in os.listdir(tmp_path) if reason in d]
    assert dirs, f"no flight dump for {reason}: {os.listdir(tmp_path)}"
    path = os.path.join(tmp_path, sorted(dirs)[0])
    trace = json.load(open(os.path.join(path, "trace.json")))
    assert isinstance(trace["traceEvents"], list)
    metrics = json.load(open(os.path.join(path, "metrics.json")))
    assert metrics["meta"]["reason"] == reason
    state = json.load(open(os.path.join(path, "state.json")))
    assert state["meta"]["seq"] == metrics["meta"]["seq"]


# --------------------------------------------------- lane discipline

def test_audit_lane_yields_to_paying_traffic():
    """tick() refuses to decode while real traffic waits or occupies
    every slot — the audit lane only runs in the gaps."""
    eng = make_engine(max_slots=1)
    auditor, _ = attach(eng)
    comps = serve_and_audit(eng, PROMPTS[:1])
    assert auditor.completed == 1

    # queue another audit job, then make the engine busy again
    auditor.on_completion(comps[0])
    assert auditor.pending
    eng.submit(PROMPTS[1], max_tokens=MAX_TOKENS)
    assert eng.scheduler.waiting or eng.scheduler.slots_used >= 1
    assert eng.audit_tick() is False      # paying traffic owns the engine
    eng.run_to_completion()
    eng.drain_audits()
    assert not auditor.pending and auditor.errors == 0


def test_backlog_bound_drops_not_blocks():
    eng = make_engine()
    auditor, _ = attach(eng, max_backlog=1)
    serve_and_audit(eng, PROMPTS[:4])
    # 4 sampled, 1 queued at a time; intake past the bound drops
    assert auditor.sampled == 4
    assert auditor.dropped >= 1
    assert auditor.completed == auditor.sampled - auditor.dropped
    assert auditor.errors == 0


def test_audit_decoders_bypass_serving_compile_ledger():
    """Audit-lane decoders are not registered with the scheduler: their
    compiles must not count as serving (post-warm) compiles."""
    eng = make_engine()
    auditor, _ = attach(eng)
    serve_and_audit(eng, PROMPTS[:1])
    assert auditor.completed == 1
    watch = eng.scheduler.compile_watch
    assert watch.counters()["post_warm"] == 0
    assert all(k[0] in ("host", "cold")
               for k in auditor._lane_decoders)


# --------------------------------------------------- SLO + flight

def _fake_comp(ttfb=0.01, latency=0.1, n=16):
    return types.SimpleNamespace(cancelled=False, ttfb_s=ttfb,
                                 latency_s=latency, n_tokens=n)


def test_slo_watchdog_breach_latches_and_dumps(tmp_path):
    flight = FlightRecorder(str(tmp_path), tracer=Tracer())
    wd = SLOWatchdog(ttfb_p50_s=0.05, min_requests=2, flight=flight)
    for _ in range(3):
        wd.observe(_fake_comp(ttfb=0.01))
    assert wd.breaches["ttfb_p50_s"] == 0       # in SLO
    for _ in range(6):
        wd.observe(_fake_comp(ttfb=0.5))        # p50 now over target
    cur = wd.current()
    assert cur["breached"]["ttfb_p50_s"] == 1
    assert wd.breaches["ttfb_p50_s"] == 1       # one onset, latched
    dirs = os.listdir(tmp_path)
    assert any("slo-ttfb_p50_s" in d for d in dirs)
    # a breach that stays breached never re-dumps
    wd.observe(_fake_comp(ttfb=0.5))
    assert wd.breaches["ttfb_p50_s"] == 1


def test_slo_goodput_floor():
    wd = SLOWatchdog(goodput_tok_s=1e12, min_requests=2)
    for _ in range(4):
        wd.observe(_fake_comp())
    assert wd.current()["breached"]["goodput_tok_s"] == 1
    wd2 = SLOWatchdog(goodput_tok_s=1e-9, min_requests=2)
    for _ in range(4):
        wd2.observe(_fake_comp())
        time.sleep(0.002)                 # nonzero window span
    assert wd2.current()["breached"]["goodput_tok_s"] == 0


def test_flight_recorder_debounce_and_force(tmp_path):
    flight = FlightRecorder(str(tmp_path), min_interval_s=60.0)
    assert flight.dump("first") is not None
    assert flight.dump("second") is None          # debounced
    assert flight.suppressed == 1
    forced = flight.dump("manual", force=True)
    assert forced is not None and "manual" in forced
    assert flight.dumps == 2
    # never raises, even with a broken state provider
    flight.state_provider = lambda: 1 / 0
    assert flight.dump("broken", force=True) is not None


# --------------------------------------------------- server routes

@contextlib.asynccontextmanager
async def _server(audit=True, flight_dir=None):
    tracer = Tracer()
    eng = make_engine(max_slots=2)
    eng.set_tracer(tracer, "engine-0")
    flight = FlightRecorder(flight_dir, tracer=tracer) \
        if flight_dir else None
    auditor = None
    if audit:
        auditor = ShadowAuditor(eng, AuditConfig(sample_rate=1.0),
                                tracer=tracer, flight=flight)
        eng.attach_auditor(auditor)
    wd = SLOWatchdog(ttfb_p50_s=30.0, min_requests=1)
    loop = EngineLoop(eng, max_pending=16, idle_poll_s=0.005,
                      tracer=tracer)
    loop.watchdog = wd
    loop.flight = flight
    if flight is not None and flight.state_provider is None:
        from repro.server.http import _flight_state
        flight.state_provider = lambda: _flight_state([loop], wd)
    front = await HttpFrontend(loop, port=0, tracer=tracer,
                               flight=flight, watchdog=wd).start()
    try:
        yield front, eng, auditor
    finally:
        await front.shutdown(drain=False, timeout_s=30)


def _run(coro):
    asyncio.run(asyncio.wait_for(coro, TEST_TIMEOUT_S))


async def _wait_audits(eng, auditor, timeout_s=60.0):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout_s:
        if auditor.sampled and not auditor.pending:
            return
        await asyncio.sleep(0.01)
    raise AssertionError("audits never drained")


def test_debug_vars_and_flight_routes(tmp_path):
    async def scenario():
        async with _server(flight_dir=str(tmp_path)) as (front, eng,
                                                         auditor):
            host, port = front.host, front.port
            status, _, doc = await C.complete(
                host, port, {"prompt": PROMPTS[0],
                             "max_tokens": MAX_TOKENS})
            assert status == 200
            await _wait_audits(eng, auditor)

            status, _, body = await C.request(host, port, "GET",
                                              "/debug/vars")
            assert status == 200
            doc = json.loads(body)
            eng0 = doc["engines"][0]
            assert eng0["scheduler"]["slots_used"] == 0
            assert eng0["audit"]["sampled"] == auditor.sampled
            assert "compile" in eng0["scheduler"]
            assert doc["slo"]["targets"] == {"ttfb_p50_s": 30.0}

            status, _, body = await C.request(host, port, "GET",
                                              "/debug/flight")
            assert status == 200
            fl = json.loads(body)
            assert fl["dumps"] == 1
            assert os.path.isdir(fl["path"])
            for name in ("trace.json", "metrics.json", "state.json"):
                assert os.path.exists(os.path.join(fl["path"], name))
            # the manual dump's metrics carry live engine + audit state
            m = json.load(open(os.path.join(fl["path"], "metrics.json")))
            assert m["engines"][0]["audit"]["sampled"] >= 1

            status, _, body = await C.request(host, port, "GET",
                                              "/metrics")
            text = body.decode()
            for family in ("repro_audit_sampled_total",
                           "repro_audit_divergences_total",
                           "repro_audit_conf_agree_total",
                           "repro_slo_target", "repro_slo_breaches_total",
                           "repro_flight_dumps_total",
                           "repro_trace_drops_total"):
                assert family in text, f"missing {family} in /metrics"
            assert 'repro_audit_divergences_total{source="dkv-structural"}' \
                in text

    _run(scenario())


def test_debug_flight_without_recorder_503():
    async def scenario():
        async with _server(audit=False, flight_dir=None) as (front, _, _):
            status, _, body = await C.request(front.host, front.port,
                                              "GET", "/debug/flight")
            assert status == 503
            assert b"flight" in body

    _run(scenario())


def test_loop_audits_in_gaps_and_mirrors_metrics(tmp_path):
    """Under the EngineLoop, audits advance automatically between
    scheduler ticks and the counters are mirrored into ServeMetrics."""
    async def scenario():
        async with _server() as (front, eng, auditor):
            for p in PROMPTS[:3]:
                status, _, _ = await C.complete(
                    front.host, front.port,
                    {"prompt": p, "max_tokens": MAX_TOKENS})
                assert status == 200
            await _wait_audits(eng, auditor)
            assert auditor.completed == auditor.sampled == 3
            assert auditor.divergences_total() == 0
            snap = eng.metrics.snapshot()
            assert snap["audits_completed"] == 3
            assert snap["audit_divergences"] == 0
            assert snap["host_syncs_per_block"] == 1.0

    _run(scenario())
