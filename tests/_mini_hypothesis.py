"""Deterministic stand-in for `hypothesis` when it isn't installed.

The test image doesn't ship hypothesis and the repo must not install
packages, so importing this module registers a minimal shim under
``sys.modules['hypothesis']`` implementing exactly the subset this
suite uses: ``@given`` / ``@settings`` and the strategies ``floats``,
``integers``, ``booleans``, ``lists``, ``just``, ``one_of`` and
``data``. Examples are drawn from a per-test fixed-seed RNG (stable
across runs — no hash salting), endpoints are sampled with elevated
probability, and a failing example is attached to the assertion error.
There is no shrinking. When the real hypothesis is available, conftest
never imports this file.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 30


class Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng):
        return self._draw_fn(rng)


def floats(min_value=0.0, max_value=1.0):
    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return float(min_value)
        if r < 0.10:
            return float(max_value)
        return float(min_value + (max_value - min_value) * rng.random())
    return Strategy(draw)


def integers(min_value, max_value):
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans():
    return Strategy(lambda rng: bool(rng.integers(0, 2)))


def just(value):
    return Strategy(lambda rng: value)


def one_of(*strategies):
    return Strategy(
        lambda rng: strategies[int(rng.integers(0, len(strategies)))].draw(rng))


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]
    return Strategy(draw)


class _Data:
    """Interactive draw object handed to tests that take ``st.data()``."""

    def __init__(self, rng):
        self._rng = rng
        self.drawn = []

    def draw(self, strategy, label=None):
        v = strategy.draw(self._rng)
        self.drawn.append(v)
        return v


def data():
    return Strategy(lambda rng: _Data(rng))


def given(*gargs, **gkwargs):
    if gkwargs:
        raise NotImplementedError("mini-hypothesis supports positional "
                                  "strategies only")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_mini_hyp_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode()) & 0xFFFFFFFF
            rng = np.random.default_rng(seed)
            for i in range(n):
                example = [s.draw(rng) for s in gargs]
                try:
                    fn(*args, *example, **kwargs)
                except AssertionError as e:
                    shown = [v.drawn if isinstance(v, _Data) else v
                             for v in example]
                    raise AssertionError(
                        f"mini-hypothesis falsifying example #{i}: "
                        f"{shown!r}\n{e}") from e
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # pytest must not see the strategy parameters as fixtures: hide
        # the wrapped signature (examples are supplied by the wrapper).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return decorate


class settings:
    """Accepts and mostly ignores hypothesis settings; ``max_examples``
    is honoured. Usable both as ``@settings(...)`` and via the
    register/load profile classmethods conftest calls."""

    _profiles = {}

    def __init__(self, deadline=None, max_examples=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._mini_hyp_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        prof = cls._profiles.get(name, {})
        if prof.get("max_examples"):
            global DEFAULT_MAX_EXAMPLES
            DEFAULT_MAX_EXAMPLES = prof["max_examples"]


def _register():
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__version__ = "0.0-mini-shim"
    st = types.ModuleType("hypothesis.strategies")
    for name in ("floats", "integers", "booleans", "just", "one_of",
                 "lists", "data"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_register()
