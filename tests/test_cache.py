"""Cross-request prefix KV cache tests (repro.cache).

Covers the ISSUE 5 contract: cached-prefill vs cold-prefill token
identity for all five methods (the assembled chunk bytes ARE the
original pass's bytes), partial-hit tail prefill, refcount-pinned
chunks surviving eviction pressure, scheduler integration (compaction,
preemption/resume re-priming, hit-aware admission), Completion/metrics
hit surfacing, and cache-affinity routing across engines. The sharded
(forced host mesh) variant lives in tests/_sharded_child.py."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.cache import PrefixKVCache, RadixTree, slice_nbytes
from repro.core.decoder import METHODS, DecodeConfig, DiffusionDecoder
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.serving import ContinuousEngine

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
TOK = ByteTokenizer(CFG.vocab_size)
RNG = np.random.default_rng(7)
CHUNK = 8
PROMPTS = RNG.integers(0, 200, (4, 20)).astype(np.int32)   # 2 chunks + 4


def _dcfg(method="streaming", **kw):
    kw.setdefault("gen_len", 16)
    kw.setdefault("block_size", 8)
    kw.setdefault("window", 8)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("cache_chunk", CHUNK)
    return DecodeConfig(method=method, **kw)


def _decoder(d, store):
    return DiffusionDecoder(CFG, PARAMS, d, prompt_cache=store)


def _fake_kv(nbytes=64):
    return {"scan": (np.zeros(nbytes // 4, np.float32),), "tail": ()}


# ------------------------------------------------------------ radix tree


def test_radix_match_is_chunk_aligned_longest_prefix():
    store = PrefixKVCache(chunk_tokens=4, max_bytes=1 << 20)
    toks = np.arange(13, dtype=np.int32)          # 3 chunks + remainder
    store.insert(toks, 0, [_fake_kv() for _ in range(3)])
    assert store.nodes == 3
    assert store.match_len(toks) == 12            # remainder never cached
    # diverging after 2 chunks -> 2-chunk hit
    other = toks.copy()
    other[9] = 99
    assert store.match_len(other) == 8
    # shared chain: inserting the divergent prompt adds ONE node
    store.insert(other, 2, [_fake_kv()])
    assert store.nodes == 4
    chain = store.match(other)
    assert len(chain) == 3 and chain[1] is store.match(toks)[1]
    # hash chain: equal chunk content under different parents differs
    ids = {n.node_id for n in store.tree.nodes}
    assert len(ids) == store.nodes


def test_pinned_chunks_survive_eviction_pressure():
    kv = _fake_kv(256)
    store = PrefixKVCache(chunk_tokens=2,
                          max_bytes=4 * slice_nbytes(kv))
    hot = np.asarray([1, 2, 3, 4], np.int32)
    store.insert(hot, 0, [_fake_kv(256), _fake_kv(256)])
    pinned = store.match(hot)                     # refs -> 1 each
    assert len(pinned) == 2
    for i in range(8):                            # blow the byte budget
        store.insert(np.asarray([50 + i, 60 + i], np.int32), 0,
                     [_fake_kv(256)])
    assert store.evictions > 0
    assert store.bytes <= store.max_bytes
    # the pinned chain survived intact; cold chains were LRU victims
    assert store.match_len(hot) == 4
    store.unpin(pinned)
    # once unpinned, pressure may reclaim it
    for i in range(8):
        store.insert(np.asarray([80 + i, 90 + i], np.int32), 0,
                     [_fake_kv(256)])
    assert store.bytes <= store.max_bytes


def test_eviction_is_leaf_only_lru():
    tree = RadixTree(2)
    toks = np.asarray([1, 2, 3, 4, 5, 6], np.int32)
    a = tree.extend(None, toks[:2], None, 8)
    b = tree.extend(a, toks[2:4], None, 8)
    tree.extend(b, toks[4:6], None, 8)
    leaves = tree.evictable_leaves()
    assert [n.depth for n in leaves] == [3], \
        "interior nodes must never be eviction candidates"


# ------------------------------------------------------ prefill identity


@pytest.mark.parametrize("method", list(METHODS))
def test_cached_prefill_token_identity(method):
    """A warm store must reproduce the cold run bit-for-bit: assembled
    chunks carry the original pass's bytes and computed tails see
    identical inputs. dkv — whose *decode loop* amplifies XLA:CPU
    run-to-run ulp noise (see test_serving) — gets the identity
    asserted at the prefill boundary (prompt-region KV bytes) plus
    structural decode equality; every other method end-to-end."""
    d = _dcfg(method)
    store = PrefixKVCache(chunk_tokens=CHUNK)
    cold_dec = _decoder(d, store)
    cold_state = cold_dec.prefill(PROMPTS.copy())
    warm_dec = _decoder(d, store)
    warm_state = warm_dec.prefill(PROMPTS.copy())
    if method != "vanilla":
        assert (warm_state.prefix_hit_tokens == 16).all()
        # the cached-prefill bit-identity contract, directly: prompt
        # KV bytes equal between cold and warm prefill
        for a, b in zip(jax.tree.leaves(cold_state.cache),
                        jax.tree.leaves(warm_state.cache)):
            ax = np.asarray(a)[..., :20, :, :] if a.ndim == 4 \
                else np.asarray(a)[..., :, :20, :, :]
            bx = np.asarray(b)[..., :20, :, :] if b.ndim == 4 \
                else np.asarray(b)[..., :, :20, :, :]
            assert (ax == bx).all()
    for st, dec in ((cold_state, cold_dec), (warm_state, warm_dec)):
        while not st.finished:
            dec.decode_block(st)
    cold = cold_dec.finalize(cold_state)
    warm = warm_dec.finalize(warm_state)
    # warm prefill skips the hit chunks' passes — fewer NFE is the
    # point; the decode schedule itself must be identical
    assert warm.nfe <= cold.nfe
    assert cold.steps_per_block == warm.steps_per_block
    if method == "dkv":
        assert (cold.tokens == warm.tokens).mean() > 0.5
    else:
        assert (cold.tokens == warm.tokens).all()
    if method == "vanilla":
        assert store.nodes == 0                   # cache is a no-op
    else:
        assert store.stats()["lookup_hit_tokens"] >= 4 * 16


def test_partial_hit_computes_only_the_novel_tail():
    d = _dcfg()
    store = PrefixKVCache(chunk_tokens=CHUNK)
    _decoder(d, store).generate(PROMPTS[:1].copy())   # warm chunks 0-1
    diverged = PROMPTS[:1].copy()
    diverged[0, CHUNK:] = RNG.integers(0, 200, 12)    # novel after chunk 0
    cold = _decoder(d, PrefixKVCache(chunk_tokens=CHUNK)).generate(
        diverged.copy())
    dec = _decoder(d, store)
    st = dec.prefill(diverged.copy())
    assert st.prefix_hit_tokens[0] == CHUNK           # exactly one chunk
    while not st.finished:
        dec.decode_block(st)
    assert (dec.finalize(st).tokens == cold.tokens).all()


def test_fused_and_host_loops_agree_under_prefix_cache():
    """The cached tail refresh exists in both execution paths; the host
    loop stays the validation oracle for the fused one."""
    d = _dcfg()
    fused = _decoder(d, PrefixKVCache(chunk_tokens=CHUNK)).generate(
        PROMPTS.copy())
    host = _decoder(dataclasses.replace(d, fused=False),
                    PrefixKVCache(chunk_tokens=CHUNK)).generate(
        PROMPTS.copy())
    assert (fused.tokens == host.tokens).all()
    assert fused.steps_per_block == host.steps_per_block


def test_prefix_cache_requires_attention_only_layout():
    from repro.models.config import LayerSpec, MLSTM
    bad = dataclasses.replace(CFG, pattern=(LayerSpec(MLSTM),), reps=0,
                              tail=())
    with pytest.raises(AssertionError):
        DiffusionDecoder(bad, PARAMS, _dcfg())


# ------------------------------------------------------ engine integration


def _engine(d=None, store=None, max_slots=4):
    return ContinuousEngine(CFG, PARAMS, d or _dcfg(), max_slots=max_slots,
                            tokenizer=TOK, prefix_cache=store)


def test_engine_warm_requests_match_cold_and_report_hits():
    d = _dcfg()
    eng = _engine(d)
    uids = [eng.submit(PROMPTS[i % 2], max_tokens=16) for i in range(6)]
    comps = {c.uid: c for c in eng.run_to_completion()}
    ref = _decoder(d, PrefixKVCache(chunk_tokens=CHUNK)).generate(
        PROMPTS[:2].copy())
    for i in range(6):
        assert (comps[uids[i]].tokens == ref.tokens[i % 2][:16]).all()
    hits = [comps[uids[i]].cache_hit_tokens for i in range(6)]
    assert any(h >= 2 * CHUNK for h in hits), hits
    snap = eng.metrics.snapshot()
    assert snap["prefix_cache_hits"] >= 1
    assert snap["prefix_cache_hit_tokens"] >= 2 * CHUNK
    assert snap["prefix_cache_bytes"] > 0
    assert snap["prefix_cache_evictions"] == 0


def test_admission_groups_by_hit_depth():
    """Warm and cold requests of the same shape bucket must not share a
    gang (a cold row would drag the gang's common hit to zero)."""
    eng = _engine()
    eng.submit(PROMPTS[0], max_tokens=16)
    eng.run_to_completion()                        # warm template 0
    eng.submit(PROMPTS[0], max_tokens=16)          # warm (2-chunk hit)
    eng.submit(PROMPTS[1], max_tokens=16)          # cold, same bucket
    sched = eng.scheduler
    keys = {sched._group_key(r) for r in sched.waiting}
    assert len(keys) == 2, "hit depth must split the admission group"
    comps = eng.run_to_completion()
    hits = sorted(c.cache_hit_tokens for c in comps)
    assert hits == [0, 16]


def test_compaction_preserves_prompt_kv():
    """Early-exited rows shrink the gang; survivors' prompt KV must
    travel with the compacted state (the tail refresh never recomputes
    it). Forced via a fake-EOS config exactly like test_serving."""
    d0 = _dcfg(early_exit=False, gen_len=32)
    r = DiffusionDecoder(CFG, PARAMS, d0).generate(PROMPTS.copy())
    vals, counts = np.unique(r.tokens, return_counts=True)
    cfg = dataclasses.replace(CFG, eos_token_id=int(vals[counts.argmax()]))
    d = _dcfg(gen_len=32)
    refs = [DiffusionDecoder(cfg, PARAMS, d,
                             prompt_cache=PrefixKVCache(chunk_tokens=CHUNK))
            .generate(PROMPTS[i:i + 1].copy()) for i in range(4)]
    eng = ContinuousEngine(cfg, PARAMS, d, max_slots=4, tokenizer=TOK)
    uids = [eng.submit(PROMPTS[i], max_tokens=32) for i in range(4)]
    comps = {c.uid: c for c in eng.run_to_completion()}
    for i in range(4):
        assert (comps[uids[i]].tokens == refs[i].tokens[0][:32]).all()


def test_preempt_resume_reprimes_prompt_kv():
    d = _dcfg(gen_len=32)
    ref = _decoder(d, PrefixKVCache(chunk_tokens=CHUNK)).generate(
        PROMPTS[:2].copy())
    eng = _engine(d, max_slots=4)
    ua = eng.submit(PROMPTS[0], max_tokens=32)
    ub = eng.submit(PROMPTS[1], max_tokens=32)
    eng.step()
    eng.preempt(ub)
    # next tick extracts ub at the block boundary: its parked state
    # drops the KV buffer, and the same tick's backfill re-admits it
    # with a pooled buffer + a prompt re-prime from the store
    comps = {c.uid: c for c in eng.run_to_completion()}
    assert (comps[ua].tokens == ref.tokens[0][:32]).all()
    assert (comps[ub].tokens == ref.tokens[1][:32]).all()
    st = eng.prefix_cache.stats()
    # initial gang prefill: 2 cold lookups; the resume re-prime is a
    # third lookup that hits its own chunks (16 of 20 prompt tokens)
    assert st["lookups"] >= 3
    assert st["lookup_hit_tokens"] == 16, \
        "the resumed row must re-prime its dropped prompt KV from the store"


def test_scheduler_rejects_mismatched_store():
    from repro.serving import BlockScheduler
    store = PrefixKVCache(chunk_tokens=CHUNK, placement=("elsewhere",))
    with pytest.raises(ValueError):
        BlockScheduler(CFG, PARAMS, _dcfg(), prefix_cache=store)
    with pytest.raises(ValueError):
        BlockScheduler(CFG, PARAMS, _dcfg(),
                       prefix_cache=PrefixKVCache(chunk_tokens=CHUNK + 1))


# ------------------------------------------------------ routing / metrics


def test_cache_affinity_routes_to_warm_engine():
    """The router must prefer the engine whose store holds the longest
    matching prefix, and fall back to least-loaded when all are cold."""
    from repro.server import EngineLoop, EngineRouter, ServerRequest
    prompt = "".join(chr(c) for c in RNG.integers(48, 123, 24))
    engines = [_engine() for _ in range(2)]
    engines[1].submit(prompt, max_tokens=16)
    engines[1].run_to_completion()                 # warm engine 1 only
    assert engines[1].expected_prefix_hit(prompt) >= 2 * CHUNK
    assert engines[0].expected_prefix_hit(prompt) == 0
    router = EngineRouter([EngineLoop(e) for e in engines])
    req = ServerRequest.from_json({"prompt": prompt, "max_tokens": 16})
    ticket = router.submit(req, lambda e: None)
    assert ticket.loop is router.loops[1], "warm engine must win"
    # cold prompt: affinity is moot, least-loaded (index ties) wins
    cold = ServerRequest.from_json({"prompt": "Z" * 24, "max_tokens": 16})
    t2 = router.submit(cold, lambda e: None)
    assert t2.loop is router.loops[0]


def test_metrics_endpoint_exposes_cache_series():
    from repro.server import EngineLoop, HttpFrontend
    eng = _engine()
    eng.submit(PROMPTS[0], max_tokens=16)
    eng.run_to_completion()
    eng.submit(PROMPTS[0], max_tokens=16)
    eng.run_to_completion()
    text = HttpFrontend(EngineLoop(eng))._metrics_text()
    assert "repro_prefix_cache_hits_total 1" in text
    assert "repro_prefix_cache_hit_tokens_total 16" in text
    assert "repro_prefix_cache_evictions_total 0" in text
    assert "repro_prefix_cache_bytes" in text
    assert "repro_prefix_cache_chunks" in text
