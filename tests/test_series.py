"""Time-series recorder tests (`repro.obs.series`).

Three layers:

* **unit** — the delta-encoded ring against a fake engine whose
  counters the test advances by hand: rate reconstruction, ring
  bounding + drop accounting, the never-raise sampling contract, the
  shared JSONL sink's refcounted lifecycle, and the fleet fan-in
  invariant (raw per-bucket deltas sum across engines *before* rates
  derive — an average of per-engine fractions is wrong whenever the
  engines' sample cadences differ, and the test constructs exactly
  that case).
* **concurrency** — a writer thread force-sampling flat out while the
  reader repeatedly derives windowed series and last-rates snapshots:
  the lock-free deque contract (GIL-atomic appends of immutable
  tuples) must never tear a sample or raise.
* **integration** — a real engine behind the HTTP frontend: the
  recorder sampled on the decode-thread cadence, `/debug/timeline`
  (including parameter clamping), `/console`, `/debug/vars`'s compact
  snapshot, `--metrics-log` JSONL persistence through graceful drain,
  a strict Prometheus-exposition parse of `_metrics_text()` from a
  loaded multi-engine frontend, and per-pool busy fractions on a live
  prefill/decode fleet (the ROADMAP open-item-1 sizing signal).
"""
import asyncio
import contextlib
import json
import re
import threading
import time
import types

import jax
import pytest

from repro.cache import PrefixKVCache
from repro.core.decoder import DecodeConfig
from repro.models import get_config, init_params
from repro.obs.series import (COUNTERS, GAUGES, JsonlSink,
                              MetricsRecorder, fleet_series,
                              timeline_doc)
from repro.server import EngineLoop, EngineRouter, HttpFrontend
from repro.server import client as C
from repro.server.types import ServerRequest
from repro.serving import ContinuousEngine

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
MAX_TOKENS = 16
BLOCK = 8
CHUNK = 8
PROMPTS = [f"Q:{i}{(i + 3) % 10}+{(i + 5) % 10}{i}=? Answer"
           for i in range(4)]
TEST_TIMEOUT_S = 240


def _run(coro):
    asyncio.run(asyncio.wait_for(coro, TEST_TIMEOUT_S))


# --------------------------------------------------------- fakes

_METRIC_ATTRS = (
    "total_nfe", "cancelled", "admission_rejects", "deadline_misses",
    "steals_in", "steals_out", "handoffs_in", "handoffs_out",
    "prefix_cache_hit_tokens", "prefill_busy_s", "decode_busy_s",
    "busy_time_s", "wall_time_s", "compile_misses", "compile_seconds",
    "queue_depth", "prefix_cache_bytes", "audit_backlog",
)


class FakeMetrics:
    def __init__(self):
        for name in _METRIC_ATTRS:
            setattr(self, name, 0.0)


class FakeEngine:
    """Counters the test advances by hand, shaped like the slice of
    ContinuousEngine the recorder reads."""

    def __init__(self):
        self.metrics = FakeMetrics()
        self.stats = {"tokens": 0.0, "good_tokens": 0.0,
                      "requests": 0.0}
        self.scheduler = types.SimpleNamespace(live_rows=0)

    def tick(self, tokens=0.0, requests=0.0, busy=0.0, wall=0.0,
             prefill=0.0, decode=0.0, nfe=0.0, steals=0.0):
        self.stats["tokens"] += tokens
        self.stats["good_tokens"] += tokens
        self.stats["requests"] += requests
        m = self.metrics
        m.busy_time_s += busy
        m.wall_time_s += wall
        m.prefill_busy_s += prefill
        m.decode_busy_s += decode
        m.total_nfe += nfe
        m.steals_in += steals


class _BrokenMetrics:
    def __getattr__(self, name):
        raise RuntimeError(f"metrics read of {name} exploded")


# --------------------------------------------------------- unit

def test_delta_rates_and_windowed_series():
    eng = FakeEngine()
    t0 = time.monotonic()
    rec = MetricsRecorder(eng, interval_s=0.01)
    rec._last_t = t0                       # pin the grid for the test
    # three 1 s samples: 100, 50, 0 tokens; half-busy throughout
    for i, toks in enumerate((100, 50, 0)):
        eng.tick(tokens=toks, requests=1, busy=0.5, wall=1.0,
                 decode=0.5)
        assert rec.sample(now=t0 + (i + 1) * 1.0)
    assert rec.samples == 3 and rec.errors == 0

    last = rec.last_rates()
    assert last["tok_s"] == 0.0            # newest sample had 0 tokens
    assert last["rps"] == 1.0
    assert last["busy_frac"] == pytest.approx(0.5)

    # query half a step after the last sample (a live query's clock is
    # always strictly ahead of every sample it reads)
    doc = rec.series(window_s=4.0, step_s=1.0, now=t0 + 3.5)
    assert doc["buckets"] == 4 and doc["filled"] == 3
    tok_s = doc["rates"]["tok_s"]
    assert tok_s[0] is None                # empty bucket shows a gap
    assert tok_s[1:] == [100.0, 50.0, 0.0]
    assert doc["rates"]["decode_busy_frac"][1:] == [0.5, 0.5, 0.5]
    # per-bucket deltas are self-contained: dropping the head sample
    # must not change the remaining buckets
    rec.ring.popleft()
    doc2 = rec.series(window_s=4.0, step_s=1.0, now=t0 + 3.5)
    assert doc2["rates"]["tok_s"][2:] == [50.0, 0.0]


def test_ring_bounded_and_drops_counted():
    eng = FakeEngine()
    rec = MetricsRecorder(eng, interval_s=0.001, max_bytes=1)
    assert rec.ring.maxlen == 16           # floor
    t0 = time.monotonic()
    rec._last_t = t0
    for i in range(40):
        eng.tick(tokens=1, wall=0.01)
        assert rec.sample(now=t0 + (i + 1) * 0.01)
    assert len(rec.ring) == 16
    assert rec.samples == 40
    assert rec.dropped == 24
    assert rec.stats()["ring_cap"] == 16


def test_sampler_never_raises():
    eng = FakeEngine()
    rec = MetricsRecorder(eng, interval_s=0.001)
    good = eng.metrics
    eng.metrics = _BrokenMetrics()
    time.sleep(0.005)
    assert rec.sample() is False           # logged and dropped
    assert rec.errors == 1
    eng.metrics = good                     # recovers on the next tick
    eng.tick(tokens=5, wall=0.01)
    time.sleep(0.005)
    assert rec.sample() is True
    assert rec.errors == 1


def test_interval_throttle():
    eng = FakeEngine()
    rec = MetricsRecorder(eng, interval_s=10.0)
    assert rec.maybe_sample() is False     # inside the interval
    assert rec.samples == 0
    t = time.monotonic() + 11.0
    eng.tick(tokens=1, wall=1.0)
    assert rec.maybe_sample(now=t) is True


def test_jsonl_sink_refcounted_shared(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    sink = JsonlSink(path)
    engines = [FakeEngine(), FakeEngine()]
    recs = [MetricsRecorder(e, index=i, role="decode",
                            interval_s=0.001, sink=sink)
            for i, e in enumerate(engines)]
    for _ in range(2):
        for e, r in zip(engines, recs):
            e.tick(tokens=3, wall=0.01)
            time.sleep(0.003)
            assert r.sample()
    recs[0].close()
    assert sink._f is not None             # still held by recorder 1
    recs[1].close()
    assert sink._f is None                 # last release closes
    recs[1].close()                        # idempotent
    lines = [json.loads(ln) for ln in open(path) if ln.strip()]
    assert len(lines) >= 4 and len(lines) == sink.lines
    for doc in lines:
        assert doc["engine"] in (0, 1) and doc["role"] == "decode"
        assert set(doc["d"]) == set(COUNTERS)
        assert set(doc["g"]) == set(GAUGES)
        assert doc["dt"] > 0


def test_fleet_fan_in_sums_deltas_before_deriving():
    """Engine A: 1 s sampled, fully busy. Engine B: 3 s sampled, fully
    idle. Correct fleet busy fraction is 1/4 (one busy second out of
    four decode-thread seconds); averaging per-engine fractions would
    say 1/2."""
    t0 = time.monotonic()
    a, b = FakeEngine(), FakeEngine()
    ra = MetricsRecorder(a, index=0, role="prefill", interval_s=0.001)
    rb = MetricsRecorder(b, index=1, role="decode", interval_s=0.001)
    ra._last_t = t0 + 2.0                  # A's sample spans [2, 3)
    rb._last_t = t0
    a.tick(tokens=80, busy=1.0, wall=1.0, prefill=1.0)
    assert ra.sample(now=t0 + 3.0)
    b.tick(tokens=0, busy=0.0, wall=3.0)
    assert rb.sample(now=t0 + 3.0)

    doc = fleet_series([ra, rb], window_s=4.0, step_s=4.0,
                       now=t0 + 3.5)
    assert doc["engines"] == 2
    assert doc["rates"]["busy_frac"][-1] == pytest.approx(0.25)
    assert doc["rates"]["tok_s"][-1] == pytest.approx(80 / 4.0)
    # per-pool view keeps each role's own fraction
    assert set(doc["pools"]) == {"prefill", "decode"}
    assert doc["pools"]["prefill"]["engines"] == 1
    assert doc["pools"]["prefill"]["busy_frac"][-1] \
        == pytest.approx(1.0)
    assert doc["pools"]["decode"]["busy_frac"][-1] \
        == pytest.approx(0.0)
    assert doc["pools"]["prefill"]["prefill_busy_frac"][-1] \
        == pytest.approx(1.0)


def test_timeline_doc_skips_recorderless_loops():
    eng = FakeEngine()
    rec = MetricsRecorder(eng, interval_s=0.001)
    eng.tick(tokens=10, wall=0.01)
    time.sleep(0.005)
    assert rec.sample()
    loops = [types.SimpleNamespace(recorder=rec, role="both"),
             types.SimpleNamespace()]      # no recorder attached
    doc = timeline_doc(loops, window_s=10.0, step_s=1.0)
    assert doc["engines_total"] == 2
    assert doc["engines_reporting"] == 1
    assert len(doc["t"]) == 10 and doc["t"][-1] == 0.0
    assert doc["t"][0] == -9.0
    assert len(doc["engines"]) == 1
    assert doc["fleet"]["engines"] == 1
    json.dumps(doc)                        # wire-serializable


def test_timeline_doc_empty_fleet():
    doc = timeline_doc([types.SimpleNamespace()], window_s=10.0,
                       step_s=1.0)
    assert doc["engines_reporting"] == 0 and doc["fleet"] is None


# --------------------------------------------------------- concurrency

def test_writer_reader_hammer():
    """Writer thread samples flat out while the reader derives series
    and snapshots continuously: no tearing, no exceptions, and every
    datum the reader sees is well-formed."""
    eng = FakeEngine()
    rec = MetricsRecorder(eng, interval_s=1e-6, max_bytes=64 << 10)
    stop = threading.Event()
    wrote = {"n": 0}

    def writer():
        while not stop.is_set():
            eng.tick(tokens=4, requests=1, busy=0.001, wall=0.002,
                     decode=0.001, nfe=2)
            if rec.sample():
                wrote["n"] += 1

    th = threading.Thread(target=writer, daemon=True)
    th.start()
    try:
        deadline = time.monotonic() + 2.0
        reads = 0
        while time.monotonic() < deadline:
            doc = rec.series(window_s=1.0, step_s=0.05)
            assert doc["buckets"] == 20
            for vals in doc["rates"].values():
                assert len(vals) == 20
                assert all(v is None or v >= 0 for v in vals)
            last = rec.last_rates()
            if last["samples"]:
                assert last["dt_s"] >= 0
            reads += 1
    finally:
        stop.set()
        th.join(timeout=10)
    assert reads > 50 and wrote["n"] > 100
    assert rec.errors == 0
    assert rec.samples == wrote["n"]


# --------------------------------------------------------- integration

def make_engine(store=None, prefill_only=False, max_slots=2):
    dcfg = DecodeConfig(method="streaming", gen_len=MAX_TOKENS,
                        block_size=BLOCK, window=4, tau0=0.5,
                        prefix_cache=store is not None,
                        cache_chunk=CHUNK)
    return ContinuousEngine(CFG, PARAMS, dcfg, max_slots=max_slots,
                            prefix_cache=store,
                            prefill_only=prefill_only)


@contextlib.asynccontextmanager
async def _server(metrics_log=None):
    eng = make_engine()
    loop = EngineLoop(eng, max_pending=16, idle_poll_s=0.002)
    sink = JsonlSink(metrics_log) if metrics_log else None
    loop.recorder = MetricsRecorder(eng, index=0, role="both",
                                    interval_s=0.02, sink=sink,
                                    loop=loop)
    front = await HttpFrontend(loop, port=0).start()
    try:
        yield front, eng, loop
    finally:
        await front.shutdown(drain=True, timeout_s=30)


def test_http_timeline_and_console(tmp_path):
    log_path = str(tmp_path / "metrics.jsonl")

    async def scenario():
        async with _server(metrics_log=log_path) as (front, eng, loop):
            host, port = front.host, front.port
            for p in PROMPTS[:2]:
                status, _, doc = await C.complete(
                    host, port, {"prompt": p, "max_tokens": MAX_TOKENS})
                assert status == 200

            status, headers, body = await C.request(
                host, port, "GET", "/debug/timeline?window=30&step=1")
            assert status == 200
            assert headers["content-type"].startswith("application/json")
            doc = json.loads(body)
            assert doc["window_s"] == 30.0 and doc["step_s"] == 1.0
            assert len(doc["t"]) == 30
            assert doc["engines_reporting"] == 1
            tok_s = doc["fleet"]["rates"]["tok_s"]
            assert any(v for v in tok_s if v), tok_s
            busy = doc["fleet"]["rates"]["busy_frac"]
            assert any(v is not None for v in busy)

            # hostile parameters clamp to sane defaults, never 500
            status, _, body = await C.request(
                host, port, "GET",
                "/debug/timeline?window=bogus&step=-5&junk=1")
            assert status == 200
            doc = json.loads(body)
            assert doc["window_s"] == 120.0 and doc["step_s"] == 0.1

            status, headers, page = await C.request(
                host, port, "GET", "/console")
            assert status == 200
            assert headers["content-type"].startswith("text/html")
            text = page.decode()
            assert text.lstrip().lower().startswith("<!doctype html>")
            assert "/debug/timeline" in text
            # zero external deps: no other-origin fetches in the page
            assert "https://" not in text and "cdn." not in text

            status, _, body = await C.request(host, port, "GET",
                                              "/debug/vars")
            assert status == 200
            dv = json.loads(body)
            eng_vars = dv["engines"][0]
            assert eng_vars["recorder"]["samples"] >= 1
            assert "tok_s" in eng_vars["recorder"]
            rec = loop.recorder
        # graceful drain closed the recorder (final tail sample) and
        # released the shared sink
        assert rec._closed
        lines = [json.loads(ln) for ln in open(log_path) if ln.strip()]
        assert len(lines) == rec.stats()["log_lines"] >= 1
        assert all(set(d["d"]) == set(COUNTERS) for d in lines)

    _run(scenario())


# strict exposition-format grammar (the subset Prometheus accepts for
# text format 0.0.4): used to parse the full /metrics payload below
_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_LABEL = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
_SAMPLE = re.compile(rf"^({_NAME})(?:\{{(.*)\}})?\s(\S+)$")


def parse_exposition(text):
    """Strict parse: HELP before TYPE before samples per family, legal
    types, parseable label sets and float values, no duplicate
    (name, labels) pairs. Returns {family: {"type", "samples"}}."""
    fams, seen = {}, set()
    for ln in text.splitlines():
        if not ln.strip():
            continue
        if ln.startswith("# HELP "):
            name, _, help_text = ln[len("# HELP "):].partition(" ")
            assert re.fullmatch(_NAME, name), ln
            assert name not in fams, f"duplicate HELP for {name}"
            fams[name] = {"type": None, "help": help_text,
                          "samples": []}
        elif ln.startswith("# TYPE "):
            name, _, mtype = ln[len("# TYPE "):].partition(" ")
            assert mtype in ("counter", "gauge", "summary",
                             "histogram"), ln
            assert name in fams, f"TYPE before HELP: {ln}"
            assert fams[name]["type"] is None, f"duplicate TYPE {name}"
            fams[name]["type"] = mtype
        else:
            assert not ln.startswith("#"), f"unknown comment: {ln!r}"
            m = _SAMPLE.match(ln)
            assert m, f"unparseable sample line: {ln!r}"
            name, labels, value = m.groups()
            float(value)                   # must parse
            fam = name
            for suffix in ("_bucket", "_sum", "_count"):
                base = name[:-len(suffix)] if name.endswith(suffix) \
                    else None
                if base in fams and fams[base]["type"] in ("summary",
                                                           "histogram"):
                    fam = base
            assert fam in fams, f"sample without HELP/TYPE: {ln!r}"
            if labels:
                consumed = _LABEL.sub("", labels).strip(", ")
                assert not consumed, f"bad label syntax: {labels!r}"
            key = (name, labels or "")
            assert key not in seen, f"duplicate sample {key}"
            seen.add(key)
            fams[fam]["samples"].append((name, labels or "",
                                         float(value)))
    for name, fam in fams.items():
        assert fam["type"] is not None, f"HELP without TYPE: {name}"
    return fams


def test_metrics_text_strict_exposition():
    """Every line of /metrics from a *loaded* two-engine frontend (real
    requests decoded, recorders sampling) passes a strict
    exposition-format parse, and the repro_series_* families report
    the recorders' true totals."""
    engines = [make_engine() for _ in range(2)]
    for eng in engines:
        for p in PROMPTS[:2]:
            eng.submit(p, max_tokens=MAX_TOKENS)
        eng.run_to_completion()
    loops = []
    for i, eng in enumerate(engines):
        rec = MetricsRecorder(eng, index=i, role="both",
                              interval_s=0.001)
        time.sleep(0.003)
        assert rec.sample()
        loops.append(types.SimpleNamespace(recorder=rec, role="both"))
    front = HttpFrontend(types.SimpleNamespace(engines=engines,
                                               loops=loops,
                                               inflight=0, pending=0),
                         port=0)
    fams = parse_exposition(front._metrics_text())
    assert len(fams) > 20
    for name in ("repro_series_samples_total",
                 "repro_series_dropped_total",
                 "repro_series_errors_total", "repro_series_ring_bytes",
                 "repro_series_log_lines_total"):
        assert name in fams, sorted(fams)
    n_samples = sum(r.recorder.samples for r in loops)
    assert fams["repro_series_samples_total"]["samples"][0][2] \
        == n_samples
    assert fams["repro_series_errors_total"]["samples"][0][2] == 0
    assert fams["repro_tokens_total"]["samples"][0][2] > 0


def test_pool_busy_fractions_live_fleet():
    """A real prefill:1,decode:1 fleet under load reports per-pool
    busy fractions through the timeline doc: the prefill pool shows
    prefill-phase work, the decode pool shows decode-phase work — the
    N:M sizing signal from ROADMAP open item 1."""
    store = PrefixKVCache(chunk_tokens=CHUNK, shared=True)
    engines = [make_engine(store, prefill_only=True),
               make_engine(store)]
    loops = [EngineLoop(e, max_pending=32, idle_poll_s=0.002, index=i,
                        role="prefill" if i == 0 else "decode")
             for i, e in enumerate(engines)]
    for lp, eng in zip(loops, engines):
        lp.recorder = MetricsRecorder(eng, index=lp.index,
                                      role=lp.role, interval_s=0.01,
                                      loop=lp)
    router = EngineRouter(loops)
    for lp in loops:
        lp.start()
    try:
        done = []
        for p in PROMPTS:
            ev = threading.Event()

            def deliver(event, ev=ev):
                if event[0] == "done":
                    ev.set()

            router.submit(ServerRequest(prompt=p,
                                        max_tokens=MAX_TOKENS), deliver)
            done.append(ev)
        for ev in done:
            assert ev.wait(timeout=TEST_TIMEOUT_S)
        time.sleep(0.05)                   # one more sampling tick
    finally:
        router.close(drain=True, timeout_s=60)

    doc = timeline_doc(loops, window_s=60.0, step_s=60.0)
    assert doc["engines_reporting"] == 2
    pools = doc["fleet"]["pools"]
    assert set(pools) == {"prefill", "decode"}
    assert pools["prefill"]["engines"] == 1
    assert pools["decode"]["engines"] == 1

    def last(series):
        vals = [v for v in series if v is not None]
        return vals[-1] if vals else None

    # the prefill pool did prefill-phase work; the decode pool
    # generated the tokens
    assert last(pools["prefill"]["prefill_busy_frac"]) > 0
    assert last(pools["decode"]["decode_busy_frac"]) > 0
    assert last(pools["decode"]["tok_s"]) > 0
    json.dumps(doc)
