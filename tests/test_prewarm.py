"""Host budgeting + persistent compile cache + pre-warm watchdog.

Unit half (jax-free): ``repro.launch.host`` budget derivation and the
subprocess env composition every benchmark/test child runs under.

Watchdog half: ``tests/_prewarm_child.py`` in a subprocess whose env
comes from ``budget_env`` (8 forced host devices, per-engine thread
budget) builds a mixed-method two-engine fleet, pre-warms both shape
buckets, then drives a merge/queue/preempt-heavy load — and must record
ZERO post-warm compiles per engine. This is the regression gate for the
"N engines compiling inside each other's decode window" collapse.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import host as hostmod

# ----------------------------------------------------------- unit half


def test_budget_derivation_partitions_cores():
    b = hostmod.compute_host_budget(4, cores=16)
    assert (b.engines, b.cores, b.intra_op, b.source) == \
        (4, 16, 4, "derived")
    assert "4 intra-op" in b.describe()


def test_budget_floors_at_one_thread():
    assert hostmod.compute_host_budget(8, cores=2).intra_op == 1
    assert hostmod.compute_host_budget(1, cores=0 or 1).intra_op == 1


def test_budget_override_wins():
    b = hostmod.compute_host_budget(4, threads_per_engine=3, cores=16)
    assert (b.intra_op, b.source) == (3, "override")


def test_budget_env_composes_without_mutating_process():
    before = dict(os.environ)
    b = hostmod.compute_host_budget(2, cores=2)     # -> 1 thread/engine
    env = hostmod.budget_env(b, host_devices=8, platform="cpu",
                             base={})
    assert env["PJRT_NPROC"] == "1"
    assert "--xla_cpu_multi_thread_eigen=false" in env["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    assert env["JAX_PLATFORMS"] == "cpu"
    assert dict(os.environ) == before               # pure composition


def test_budget_env_respects_existing_flags():
    base = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
            "JAX_PLATFORMS": "tpu"}
    env = hostmod.budget_env(hostmod.compute_host_budget(1, cores=8),
                             host_devices=8, platform="cpu", base=base)
    # never override a caller's explicit choices
    assert env["XLA_FLAGS"].count(
        "--xla_force_host_platform_device_count") == 1
    assert env["JAX_PLATFORMS"] == "tpu"
    assert env["PJRT_NPROC"] == "8"


def test_apply_host_budget_refuses_live_backend():
    import jax
    jax.devices()                                   # force backend init
    with pytest.raises(RuntimeError, match="before the first jax"):
        hostmod.apply_host_budget(hostmod.compute_host_budget(1))


# ------------------------------------------------------- watchdog half

_REPORT = {}


def _report(tmp_path_factory):
    if not _REPORT:
        cache = str(tmp_path_factory.mktemp("compile_cache"))
        env = hostmod.budget_env(
            hostmod.compute_host_budget(2), host_devices=8,
            platform="cpu")
        env["PYTHONPATH"] = "src"
        r = subprocess.run(
            [sys.executable, os.path.join("tests", "_prewarm_child.py"),
             cache],
            capture_output=True, text=True, timeout=560, env=env, cwd=".")
        assert r.returncode == 0, r.stdout + r.stderr
        _REPORT.update(json.loads(r.stdout.strip().splitlines()[-1]))
        _REPORT["cache_entries"] = len(os.listdir(cache))
    return _REPORT


def test_zero_post_warm_compiles_under_mixed_load(tmp_path_factory):
    rep = _report(tmp_path_factory)
    assert rep["n_devices"] == 8
    assert {e["method"] for e in rep["per_engine"]} == \
        {"streaming", "fast"}
    for e in rep["per_engine"]:
        assert e["requests"] == 11
        assert e["prewarm_variants"] > 0
        assert e["post_warm_compiles"] == 0, e    # the watchdog itself


def test_budget_and_cache_reach_the_engines(tmp_path_factory):
    rep = _report(tmp_path_factory)
    for e in rep["per_engine"]:
        assert e["host_threads"] == rep["pjrt_nproc"] >= 1
        assert e["compile_misses"] >= e["prewarm_variants"]
    if rep["persistent_cache"]:   # this jax build has the cache
        assert rep["cache_entries"] > 0
