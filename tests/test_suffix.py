"""Property tests for attenuation-guided suffix pruning (Eq. 7)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.suffix import steady_state_query_len, suffix_query_region


@settings(deadline=None, max_examples=200)
@given(st.integers(1, 64), st.integers(1, 16), st.integers(1, 8),
       st.integers(0, 512), st.data())
def test_region_invariants(K, n_blocks, _r, gen_start, data):
    L = K * n_blocks
    c = data.draw(st.integers(0, n_blocks - 1))
    w = data.draw(st.one_of(st.just(-1), st.integers(0, L)))
    r = suffix_query_region(gen_start=gen_start, gen_len=L, block_size=K,
                            block_idx=c, window=w)
    pos = r.positions
    # block positions come first and are exactly the block
    assert (pos[:K] == np.arange(r.block_start, r.block_start + K)).all()
    # all positions inside the generation region, unique, sorted
    assert pos.min() >= gen_start and pos.max() < gen_start + L
    assert len(set(pos.tolist())) == len(pos)
    assert (np.diff(pos) > 0).all()
    # suffix window is contiguous after the block
    if r.suffix_len:
        assert pos[K] == r.block_start + K
    # trailing position present iff window doesn't reach the end
    remaining = gen_start + L - (r.block_start + K)
    if w >= 0 and w < remaining:
        assert r.trailing_pos == gen_start + L - 1
        assert pos[-1] == gen_start + L - 1
    else:
        assert r.trailing_pos == -1


def test_full_window_covers_everything():
    r = suffix_query_region(gen_start=10, gen_len=64, block_size=16,
                            block_idx=1, window=-1)
    assert r.query_len == 64 - 16  # current block + all remaining suffix
    assert r.trailing_pos == -1


def test_steady_state_len():
    assert steady_state_query_len(32, 96) == 129
    assert steady_state_query_len(32, -1) == 33


def test_last_block_has_no_suffix():
    r = suffix_query_region(gen_start=0, gen_len=64, block_size=16,
                            block_idx=3, window=8)
    assert r.suffix_len == 0 and r.trailing_pos == -1
    assert r.query_len == 16
