"""HTTP front-end tests (`repro.server`) over real loopback sockets:
round-trip identity with the in-process engine, SSE chunk ordering,
bounded admission (429), deadline expiry → partial completion, client
disconnect → slot release without disturbing concurrent requests, and
graceful drain shutdown. Everything runs on the tiny config with the
stdlib-only loopback client."""
import asyncio
import contextlib
import json
import time

import jax
import numpy as np
import pytest

from repro.core.decoder import DecodeConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.server import EngineLoop, HttpFrontend, ServerRequest
from repro.server import client as C
from repro.server.types import BadRequest
from repro.serving import ContinuousEngine

CFG = get_config("tiny")
PARAMS = init_params(CFG, jax.random.PRNGKey(3))
TOK = ByteTokenizer(CFG.vocab_size)
PROMPT = "Q:12+34=? A:"
PROMPT_B = "Q:56+11=? A:"          # same length -> same shape bucket
TEST_TIMEOUT_S = 240


def _dcfg(gen_len=16):
    return DecodeConfig(method="streaming", gen_len=gen_len, block_size=8,
                        window=8, early_exit=False)


def _engine(gen_len=16, max_slots=4):
    return ContinuousEngine(CFG, PARAMS, _dcfg(gen_len),
                            max_slots=max_slots, tokenizer=TOK)


_REF = {}


def _reference(prompt, max_tokens, gen_len):
    """In-process ContinuousEngine.run_to_completion() ground truth."""
    key = (prompt, max_tokens, gen_len)
    if key not in _REF:
        eng = _engine(gen_len)
        eng.submit(prompt, max_tokens=max_tokens)
        _REF[key] = eng.run_to_completion()[0]
    return _REF[key]


@contextlib.asynccontextmanager
async def _server(gen_len=16, max_slots=4, max_pending=16):
    eng = _engine(gen_len, max_slots)
    loop = EngineLoop(eng, max_pending=max_pending, idle_poll_s=0.005)
    frontend = await HttpFrontend(loop, port=0).start()
    try:
        yield frontend, eng
    finally:
        await frontend.shutdown(drain=False, timeout_s=30)


def _run(coro):
    asyncio.run(asyncio.wait_for(coro, TEST_TIMEOUT_S))


async def _await_idle(eng, loop, timeout_s=60.0):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout_s:
        if eng.scheduler.idle and loop.inflight == 0:
            return
        await asyncio.sleep(0.01)
    raise AssertionError("engine did not return to idle")


# ------------------------------------------------------------ round trip


def test_http_roundtrip_matches_engine():
    """Acceptance: the HTTP JSON response carries exactly the tokens the
    in-process engine produces for the same prompt/config."""
    ref = _reference(PROMPT, 13, 16)

    async def main():
        async with _server() as (fe, eng):
            status, _, doc = await C.complete(
                fe.host, fe.port, {"prompt": PROMPT, "max_tokens": 13})
            assert status == 200
            assert doc["text"] == ref.text
            assert doc["n_tokens"] == ref.n_tokens == 13
            assert doc["max_tokens"] == 13          # never over-returns
            assert doc["finish_reason"] in ("stop", "length")
            assert not doc["cancelled"]
    _run(main())


def test_sse_stream_ordering_and_identity():
    """Acceptance: SSE chunks arrive in block order and their joined
    text equals the in-process Completion text; the stream ends with a
    summary event and the [DONE] sentinel."""
    ref = _reference(PROMPT, 13, 16)

    async def main():
        async with _server() as (fe, eng):
            stream = await C.SSEStream.open(
                fe.host, fe.port, {"prompt": PROMPT, "max_tokens": 13})
            assert stream.status == 200
            events = [e async for e in stream.events()]
            await stream.close()
            blocks = [e for e in events if "block" in e]
            finals = [e for e in events if "finish_reason" in e]
            assert [b["block"] for b in blocks] == \
                list(range(len(blocks)))            # ordered, gapless
            assert blocks[-1]["finished"]
            assert len(finals) == 1
            joined = "".join(b["text"] for b in blocks)
            assert joined == ref.text == finals[0]["text"]
    _run(main())


# ------------------------------------------------------------ admission


def test_429_on_full_admission_queue():
    async def main():
        async with _server(gen_len=32, max_pending=1) as (fe, eng):
            stream = await C.SSEStream.open(
                fe.host, fe.port, {"prompt": PROMPT, "max_tokens": 32})
            # the stream's ticket is in flight -> the queue (depth 1)
            # is full and the next request must bounce with Retry-After
            status, headers, doc = await C.complete(
                fe.host, fe.port, {"prompt": PROMPT, "max_tokens": 8})
            assert status == 429
            assert int(headers["retry-after"]) >= 1
            assert "error" in doc
            async for _ in stream.events():
                pass
            await stream.close()
            await _await_idle(eng, fe.loop)
            assert eng.metrics.admission_rejects == 1
    _run(main())


def test_bad_requests_are_400():
    async def main():
        async with _server() as (fe, eng):
            for payload in ({}, {"prompt": 7}, {"prompt": ""},
                            {"prompt": "x", "max_tokens": 0},
                            {"prompt": "x", "bogus": 1},
                            {"prompt": "x", "timeout_s": -1}):
                status, _, doc = await C.complete(fe.host, fe.port, payload)
                assert status == 400, payload
                assert "error" in doc
            status, _, body = await C.request(fe.host, fe.port, "GET",
                                              "/nope")
            assert status == 404
            status, _, body = await C.request(fe.host, fe.port, "GET",
                                              "/v1/completions")
            assert status == 405
    _run(main())


# ------------------------------------------------------------ lifecycle


def test_deadline_returns_partial_completion():
    """timeout_s expiry cancels at a block boundary: the response is a
    partial completion marked finish_reason=deadline, and the engine
    counts the miss."""
    async def main():
        async with _server(gen_len=64) as (fe, eng):
            status, _, doc = await C.complete(
                fe.host, fe.port,
                {"prompt": PROMPT, "max_tokens": 64, "timeout_s": 0.03})
            assert status == 200
            assert doc["cancelled"]
            assert doc["finish_reason"] == "deadline"
            assert doc["n_tokens"] < 64
            await _await_idle(eng, fe.loop)
            assert eng.metrics.deadline_misses == 1
            assert eng.metrics.cancelled == 1
    _run(main())


def test_disconnect_mid_stream_releases_slot():
    """Acceptance: a client that vanishes mid-stream frees its decode
    slot (engine returns to idle) and concurrent requests' tokens are
    untouched (bit-identical to a solo run)."""
    ref_b = _reference(PROMPT_B, 32, 32)

    async def main():
        async with _server(gen_len=32) as (fe, eng):
            sa = await C.SSEStream.open(
                fe.host, fe.port, {"prompt": PROMPT, "max_tokens": 32})
            sb = await C.SSEStream.open(
                fe.host, fe.port, {"prompt": PROMPT_B, "max_tokens": 32})
            events_b = []
            it_b = sb.events()
            events_b.append(await it_b.__anext__())   # both streams live
            sa.abort()                                # client A vanishes
            async for e in it_b:
                events_b.append(e)
            await sb.close()
            finals = [e for e in events_b if "finish_reason" in e]
            assert len(finals) == 1
            assert not finals[0]["cancelled"]
            assert finals[0]["text"] == ref_b.text    # B undisturbed
            await _await_idle(eng, fe.loop)           # A's slot released
            assert eng.metrics.cancelled == 1
    _run(main())


def test_graceful_drain_completes_inflight():
    """shutdown(drain=True) closes the listener but lets the in-flight
    request finish with a full (non-cancelled) response."""
    async def main():
        eng = _engine(gen_len=32)
        frontend = await HttpFrontend(
            EngineLoop(eng, max_pending=4, idle_poll_s=0.005),
            port=0).start()
        task = asyncio.create_task(C.complete(
            frontend.host, frontend.port,
            {"prompt": PROMPT, "max_tokens": 32}))
        while not (frontend.loop.inflight or task.done()):
            await asyncio.sleep(0.005)                # admitted
        await frontend.shutdown(drain=True, timeout_s=60)
        status, _, doc = await task
        assert status == 200
        assert not doc["cancelled"]
        assert doc["n_tokens"] > 0
        assert eng.scheduler.idle
    _run(main())


# ------------------------------------------------------------ observability


def test_healthz_and_metrics():
    async def main():
        async with _server() as (fe, eng):
            status, _, body = await C.request(fe.host, fe.port, "GET",
                                              "/healthz")
            assert status == 200
            health = json.loads(body)
            assert health["status"] == "ok" and health["idle"]
            st, _, doc = await C.complete(
                fe.host, fe.port, {"prompt": PROMPT, "max_tokens": 8})
            assert st == 200
            status, _, body = await C.request(fe.host, fe.port, "GET",
                                              "/metrics")
            assert status == 200
            text = body.decode()
            assert "repro_requests_total 1" in text
            for metric in ("repro_admission_rejects_total",
                           "repro_cancelled_total",
                           "repro_deadline_misses_total",
                           "repro_queue_depth",
                           'repro_latency_seconds{quantile="0.99"}'):
                assert metric in text, metric
    _run(main())


def test_keepalive_serves_many_requests_per_socket():
    """HTTP/1.1 keep-alive: a ClientSession issues several completions
    (and a /metrics scrape) over ONE TCP connection, each response
    matches the per-connection path bit for bit, and an explicit
    Connection: close still closes."""
    ref = _reference(PROMPT, 13, 16)
    ref_b = _reference(PROMPT_B, 13, 16)

    async def main():
        async with _server() as (fe, eng):
            sess = C.ClientSession(fe.host, fe.port)
            for expect in (ref, ref_b, ref):
                status, headers, doc = await sess.complete(
                    {"prompt": PROMPT if expect is not ref_b else PROMPT_B,
                     "max_tokens": 13})
                assert status == 200
                assert headers["connection"] == "keep-alive"
                assert doc["text"] == expect.text
            status, _, body = await sess.request("GET", "/metrics")
            assert status == 200
            assert b"repro_requests_total" in body
            assert sess.connects == 1, "all exchanges must share a socket"
            assert sess.requests == 4
            await sess.close()
            # legacy one-shot path still gets Connection: close
            status, headers, _ = await C.complete(
                fe.host, fe.port, {"prompt": PROMPT, "max_tokens": 13})
            assert status == 200
            assert headers["connection"] == "close"
    _run(main())


def test_keepalive_session_survives_server_side_close():
    """A stale keep-alive socket (server idle-timeout closed it) must
    reconnect transparently on the next request."""
    async def main():
        async with _server() as (fe, eng):
            fe.request_timeout_s = 0.2        # aggressive idle timeout
            sess = C.ClientSession(fe.host, fe.port)
            st, _, doc = await sess.complete(
                {"prompt": PROMPT, "max_tokens": 8})
            assert st == 200
            await asyncio.sleep(0.6)          # server times the socket out
            st, _, doc = await sess.complete(
                {"prompt": PROMPT, "max_tokens": 8})
            assert st == 200
            assert sess.connects == 2         # exactly one reconnect
            await sess.close()
    _run(main())


# ------------------------------------------------------------ validation


def test_server_request_validation_unit():
    ok = ServerRequest.from_json(
        {"prompt": "x", "max_tokens": 3, "stream": True,
         "timeout_s": 2, "priority": 5})
    assert (ok.max_tokens, ok.stream, ok.timeout_s, ok.priority) == \
        (3, True, 2.0, 5)
    for bad in ([], {"prompt": "x", "max_tokens": True},
                {"prompt": "x", "stream": "yes"},
                {"prompt": "x", "priority": 1.5},
                {"prompt": "x" * (ServerRequest.PROMPT_CAP + 1)}):
        with pytest.raises(BadRequest):
            ServerRequest.from_json(bad)


# ------------------------------------------------------------ multi-engine


def test_two_engine_loops_behind_one_frontend():
    """Acceptance: two EngineLoops (independent engines/schedulers)
    behind one HttpFrontend serve a concurrent loopback workload with
    correct per-request results, spread across both engines, and
    /metrics aggregates with per-engine labels."""
    from repro.server import EngineRouter

    async def main():
        engines = [_engine(), _engine()]
        router = EngineRouter([
            EngineLoop(e, max_pending=16, idle_poll_s=0.005)
            for e in engines])
        frontend = await HttpFrontend(router, port=0).start()
        try:
            ref = _reference(PROMPT, 8, 16)
            n = 6
            results = await asyncio.gather(*[
                C.complete(frontend.host, frontend.port,
                           {"prompt": PROMPT, "max_tokens": 8})
                for _ in range(n)])
            for status, _, doc in results:
                assert status == 200
                assert doc["text"] == ref.text, "routed result diverged"
                assert doc["finish_reason"] in ("stop", "length")
            served = [len(e.metrics.requests) for e in engines]
            assert sum(served) == n
            assert all(s > 0 for s in served), \
                f"least-loaded routing left an engine idle: {served}"
            # one SSE stream through the router for good measure
            stream = await C.SSEStream.open(
                frontend.host, frontend.port,
                {"prompt": PROMPT, "max_tokens": 8})
            events = [ev async for ev in stream.events()]
            await stream.close()
            assert events[-1]["text"] == ref.text
            status, _, body = await C.request(
                frontend.host, frontend.port, "GET", "/metrics")
            assert status == 200
            text = body.decode()
            assert f"repro_requests_total {n + 1}" in text
            assert "repro_engines 2" in text
            assert 'repro_engine_requests_total{engine="0"}' in text
            assert 'repro_engine_requests_total{engine="1"}' in text
            assert 'repro_latency_seconds{quantile="0.99"}' in text
            status, _, body = await C.request(
                frontend.host, frontend.port, "GET", "/healthz")
            health = json.loads(body)
            assert health["engines"] == 2 and health["idle"]
        finally:
            await frontend.shutdown(drain=False, timeout_s=30)

    _run(main())


def test_router_falls_back_when_one_engine_full():
    """A loop whose bounded budget is exhausted must not turn traffic
    away while its peer has room: the router tries engines in load
    order and only 429s when every engine rejects."""
    from repro.server import EngineRouter
    from repro.server.types import AdmissionRejected

    def deliver(_):
        pass

    engines = [_engine(), _engine()]
    loops = [EngineLoop(e, max_pending=1, idle_poll_s=0.005)
             for e in engines]
    router = EngineRouter(loops)       # loops NOT started: nothing drains
    try:
        tickets = [router.submit(ServerRequest(prompt=PROMPT), deliver)
                   for _ in range(2)]
        assert {t.loop for t in tickets} == set(loops), \
            "second submit must spill to the other engine"
        # a spill that got served is not a 429: no reject counted yet
        assert sum(e.metrics.admission_rejects for e in engines) == 0
        with pytest.raises(AdmissionRejected):
            router.submit(ServerRequest(prompt=PROMPT), deliver)
        # ...while a full-fleet rejection counts exactly once
        assert sum(e.metrics.admission_rejects for e in engines) == 1
    finally:
        router.close(drain=False, timeout_s=5)
