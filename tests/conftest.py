import os
import sys

# Tests run on the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS; never set device-count flags globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
from hypothesis import settings  # noqa: E402

jax.config.update("jax_enable_x64", False)

# jit compiles inside property bodies blow the default 200ms deadline
settings.register_profile("repro", deadline=None, max_examples=30)
settings.load_profile("repro")
