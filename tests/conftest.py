import os
import sys

# Tests run on the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS; never set device-count flags globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402

try:
    from hypothesis import settings  # noqa: E402
except ModuleNotFoundError:
    # The image doesn't ship hypothesis and installing packages is not
    # allowed; _mini_hypothesis registers an API-compatible subset under
    # sys.modules['hypothesis'] so the property tests still run.
    import _mini_hypothesis  # noqa: E402,F401
    from hypothesis import settings  # noqa: E402

jax.config.update("jax_enable_x64", False)

# jit compiles inside property bodies blow the default 200ms deadline
settings.register_profile("repro", deadline=None, max_examples=30)
settings.load_profile("repro")
