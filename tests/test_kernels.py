"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret
mode executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (block_attention, confidence_argmax,
                               sliding_window_attention)

KEY = jax.random.PRNGKey(7)


def _mk(B, Sq, Skv, H, Hkv, D, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    qp = jnp.broadcast_to(jnp.arange(100, 100 + Sq)[None], (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv)[None], (B, Skv))
    km = jax.random.uniform(ks[3], (B, Skv)) < 0.75
    km = km.at[:, 0].set(True)  # at least one valid key
    return q, k, v, qp, kp, km


@pytest.mark.parametrize("shape", [
    (1, 8, 16, 2, 1, 16), (2, 33, 100, 4, 2, 32), (1, 129, 257, 8, 4, 64),
    (2, 16, 512, 4, 4, 128), (1, 64, 64, 6, 2, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_attention_shapes(shape, dtype):
    B, Sq, Skv, H, Hkv, D = shape
    q, k, v, qp, kp, km = _mk(B, Sq, Skv, H, Hkv, D, dtype)
    out = block_attention(q, k, v, qp, kp, km, tq=16, tk=32)
    want = ref.block_attention_ref(q, k, v, qp, kp, km,
                                   scale=1 / np.sqrt(D))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("softcap", [0.0, 20.0])
@pytest.mark.parametrize("window", [0, 8, 64])
def test_block_attention_features(softcap, window):
    q, k, v, qp, kp, km = _mk(2, 40, 120, 4, 2, 32, jnp.float32)
    out = block_attention(q, k, v, qp, kp, km, softcap=softcap,
                          window=window, tq=16, tk=32)
    want = ref.block_attention_ref(q, k, v, qp, kp, km, scale=1 / np.sqrt(32),
                                   softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_block_attention_fully_masked_rows_are_finite():
    q, k, v, qp, kp, _ = _mk(1, 16, 32, 2, 1, 16, jnp.float32)
    km = jnp.zeros((1, 32), bool)  # nothing valid
    out = block_attention(q, k, v, qp, kp, km, tq=16, tk=16)
    assert np.isfinite(np.asarray(out)).all()


def test_sliding_window_matches_full_when_window_huge():
    q, k, v, qp, kp, km = _mk(1, 24, 48, 4, 2, 32, jnp.float32)
    full = block_attention(q, k, v, qp, kp, jnp.ones_like(km), tq=8, tk=16)
    win = sliding_window_attention(q, k, v, qp, kp, window=10_000, tq=8, tk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(win), atol=1e-6)


@pytest.mark.parametrize("NV", [(5, 64), (37, 777), (128, 2048), (3, 50304)])
def test_confidence_argmax(NV):
    N, V = NV
    logits = jax.random.normal(jax.random.PRNGKey(N), (N, V)) * 4
    c, i = confidence_argmax(logits, ts=16, tv=256)
    cr, ir = ref.confidence_argmax_ref(logits)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-5)
    assert (np.asarray(i) == np.asarray(ir)).all()


def test_confidence_argmax_batched_shape():
    logits = jax.random.normal(KEY, (2, 9, 333))
    c, i = confidence_argmax(logits)
    assert c.shape == (2, 9) and i.shape == (2, 9)
    cr, ir = ref.confidence_argmax_ref(logits.reshape(-1, 333))
    np.testing.assert_allclose(np.asarray(c).ravel(), np.asarray(cr), atol=1e-5)


def test_confidence_matches_schedule_helper():
    from repro.core.schedule import confidence_and_tokens
    logits = jax.random.normal(KEY, (4, 11, 500)) * 3
    c1, t1 = confidence_and_tokens(logits)
    c2, t2 = confidence_argmax(logits)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)
    assert (np.asarray(t1) == np.asarray(t2)).all()
