"""MoE: routing invariants, dense-path math, and the EP shard_map path
(multi-device checks run in a subprocess with fake devices)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config, init_params
from repro.models.moe import (_rank_within, apply_moe_dense, init_moe,
                              load_balance_loss)

CFG = get_config("tiny-moe")


def test_dense_path_matches_manual():
    key = jax.random.PRNGKey(0)
    p = init_moe(key, CFG, jnp.float32)
    x = jax.random.normal(key, (2, 6, CFG.d_model))
    y, aux = apply_moe_dense(CFG, p, x)
    assert y.shape == x.shape
    # manual: route, gate, combine
    x2 = x.reshape(-1, CFG.d_model)
    logits = x2 @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, CFG.moe_top_k)
    w = w / w.sum(-1, keepdims=True)
    want = np.zeros_like(x2)
    for t in range(x2.shape[0]):
        for j in range(CFG.moe_top_k):
            e = int(ids[t, j])
            h = jax.nn.silu(x2[t] @ p["w_gate"][e]) * (x2[t] @ p["w_up"][e])
            want[t] += float(w[t, j]) * np.asarray(h @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, CFG.d_model)), want,
                               atol=1e-4, rtol=1e-4)


def test_rank_within():
    keys = jnp.asarray([2, 0, 2, 1, 0, 2], jnp.int32)
    r = np.asarray(_rank_within(keys, 3))
    assert r.tolist() == [0, 0, 1, 0, 1, 2]


def test_load_balance_loss_uniform_is_one():
    # perfectly uniform routing -> loss == E * E*(1/E)*(1/E)*... == 1
    E, T, k = 8, 1024, 2
    probs = jnp.full((T, E), 1.0 / E)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, E, (T, k)))
    val = float(load_balance_loss(
        type("c", (), {"n_experts": E})(), probs, ids))
    assert abs(val - 1.0) < 0.05


_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.models import get_config
    from repro.models.moe import init_moe, apply_moe_dense, apply_moe_ep, \\
        apply_moe_ep_replicated
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(data=4, model=2)
    cfg = get_config("tiny-moe", moe_capacity_factor=8.0)  # no drops
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (4, 8, cfg.d_model))
    y_dense, aux_d = apply_moe_dense(cfg, p, x)
    with mesh:
        y_ep, aux_e = jax.jit(
            lambda p, x: apply_moe_ep(cfg, p, x, mesh))(p, x)
        y_rep, aux_r = jax.jit(
            lambda p, x: apply_moe_ep_replicated(cfg, p, x, mesh))(p, x)
    err = float(jnp.abs(y_ep - y_dense).max())
    err_r = float(jnp.abs(y_rep - y_dense).max())
    aux_err = abs(float(aux_e) - float(aux_d))
    assert err < 1e-4, f"ep vs dense {err}"
    assert err_r < 1e-4, f"ep_replicated vs dense {err_r}"
    assert aux_err < 1e-4, f"aux {aux_err}"
    print("EP_OK", err, err_r)
""")


def test_ep_matches_dense_multidevice():
    """Expert-parallel all-to-all path == dense oracle (cap high enough
    that nothing drops). Runs on 8 fake devices in a subprocess."""
    r = subprocess.run([sys.executable, "-c", _EP_SCRIPT], cwd=".",
                       capture_output=True, text=True, timeout=600)
    assert "EP_OK" in r.stdout, r.stdout + r.stderr


def test_capacity_drops_are_graceful():
    """With capacity factor ~0, EP output degrades but never NaNs."""
    script = _EP_SCRIPT.replace('moe_capacity_factor=8.0',
                                'moe_capacity_factor=0.05') \
        .replace('assert err < 1e-4, f"ep vs dense {err}"',
                 'assert np.isfinite(np.asarray(y_ep)).all()') \
        .replace('assert err_r < 1e-4, f"ep_replicated vs dense {err_r}"', '') \
        .replace('assert aux_err < 1e-4, f"aux {aux_err}"', '')
    r = subprocess.run([sys.executable, "-c", script], cwd=".",
                       capture_output=True, text=True, timeout=600)
    assert "EP_OK" in r.stdout, r.stdout + r.stderr
