"""Distribution layer: spec construction, divisibility guards, and a
reduced-mesh dry-run (subprocess with fake devices)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

from repro.models import get_config


def test_spec_shapes_match_params():
    import jax
    from repro.launch.mesh import make_production_mesh  # noqa
    # spec construction must mirror param structure exactly (CPU, no mesh
    # devices needed: use a 1x1 mesh)
    from repro.launch.sharding import SpecBuilder
    from repro.models.model import init_cache, init_params
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name in ["tiny", "tiny-moe", "xlstm-350m-smoke",
                 "recurrentgemma-9b-smoke", "gemma2-27b-smoke"]:
        cfg = get_config(name)
        sb = SpecBuilder(cfg, mesh, mode="train")
        pspec = sb.params()
        shapes = jax.eval_shape(lambda c=cfg: init_params(c, jax.random.PRNGKey(0)))
        jax.tree.map(lambda sh, sp: None, shapes, pspec,
                     is_leaf=lambda x: hasattr(x, "_normalized_spec") or
                     type(x).__name__ == "PartitionSpec")
        cspec = sb.cache(2, 64)
        cshapes = jax.eval_shape(lambda c=cfg: init_cache(c, 2, 64))
        jax.tree.map(lambda sh, sp: None, cshapes, cspec,
                     is_leaf=lambda x: type(x).__name__ == "PartitionSpec")


@pytest.mark.parametrize("arch,shape", [
    ("tiny", "decode_32k"),
    ("tiny-moe", "train_4k"),
    ("xlstm-350m-smoke", "long_500k"),
])
def test_reduced_mesh_dryrun(arch, shape):
    """Lower+compile on a (2,4) fake-device mesh via the real dryrun
    entry point — proves in_shardings/out_shardings coherence."""
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ,
                   REPRO_XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   PYTHONPATH="src")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh-dims", "2,4", "--out", d],
            capture_output=True, text=True, timeout=560, env=env, cwd=".")
        assert r.returncode == 0, r.stdout + r.stderr
        files = os.listdir(d)
        assert len(files) == 1
        rec = json.load(open(os.path.join(d, files[0])))
        assert rec["per_device"]["flops"] > 0
        assert rec["dominant_term"] in ("compute_s", "memory_s",
                                        "collective_s")


def test_multipod_reduced_mesh():
    """(pod=2, data=2, model=2) multi-pod lowering."""
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ,
                   REPRO_XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   PYTHONPATH="src")
        code = (
            "from repro.launch.dryrun import run_one;"
            f"run_one('tiny-moe', 'decode_32k', True, out_dir={d!r},"
            "mesh_dims=(2,2))"
        )
        r = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                           capture_output=True, text=True, timeout=560)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "OK" in r.stdout
