"""End-to-end behaviour: train a tiny diffusion LM on arithmetic, then
decode with every method and check quality + efficiency orderings (the
miniature version of paper Tables 1-3)."""
import jax
import numpy as np
import pytest

from repro.core.decoder import DecodeConfig, DiffusionDecoder
from repro.data.synthetic import ArithmeticDataset, exact_match
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.training.train import TrainConfig, train


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("tiny", block_size=8)
    params, hist = train(cfg, TrainConfig(steps=250, batch_size=32,
                                          seq_len=28, log_every=100),
                         verbose=False)
    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=28)
    samples = ds.eval_set(24)
    prompts = np.stack([tok.encode(s.prompt) for s in samples]).astype(np.int32)
    return cfg, params, tok, samples, prompts, hist


def test_training_learns(trained):
    *_, hist = trained
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.5
    assert hist[-1]["masked_acc"] > 0.3


def test_methods_quality_and_efficiency(trained):
    cfg, params, tok, samples, prompts, _ = trained
    res = {}
    for m in ["vanilla", "prefix", "fast", "streaming"]:
        d = DecodeConfig(method=m, gen_len=16, block_size=8, window=8)
        r = DiffusionDecoder(cfg, params, d).generate(prompts.copy())
        res[m] = (exact_match(tok, r.tokens, samples), r)
    # parallel decoding uses fewer NFEs than the one-per-step baselines
    assert res["streaming"][1].nfe <= res["vanilla"][1].nfe
    assert res["fast"][1].nfe <= res["prefix"][1].nfe
    # 250 steps is weak, but streaming must not be catastrophically
    # worse than vanilla at equal budget
    assert res["streaming"][0] >= res["vanilla"][0] - 0.35


def test_generation_is_text(trained):
    cfg, params, tok, samples, prompts, _ = trained
    d = DecodeConfig(method="streaming", gen_len=16, block_size=8, window=8)
    r = DiffusionDecoder(cfg, params, d).generate(prompts.copy())
    for row in r.tokens:
        tok.decode(row)  # must not raise
