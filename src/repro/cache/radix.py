"""Hash-chained radix tree over fixed-size prompt token chunks.

The tree indexes prompt *content*: level ``i`` holds the i-th chunk of
``chunk_tokens`` prompt ids, and a node's identity is the hash chain
``H(parent_id || chunk_bytes)`` — equal prefixes collide into one node
no matter which request inserted them, and a node's payload (the KV
slice attached by ``repro.cache.store``) is valid for *every* request
whose prompt starts with that chunk chain. Matching walks from the
root consuming whole chunks; the unaligned remainder of a prompt is
never indexed (it is recomputed per request — see the decoder's
chunk-aligned prefill).

Eviction is leaf-only LRU with refcount pinning: an interior node is
by construction older than its children (chains are inserted root to
leaf), so evicting leaves first preserves the invariant that every
stored chain is contiguous from the root — a partial chain with a hole
could never be assembled into a prefill. Pinned nodes (``refs > 0``)
are skipped: a scheduler that matched a chain holds it pinned until
the KV copy into the gang buffer is done, so eviction pressure can
never free bytes mid-assembly.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional

import numpy as np


def chunk_key(tokens: np.ndarray) -> bytes:
    """Canonical dict key for one chunk of token ids."""
    return np.ascontiguousarray(np.asarray(tokens, np.int32)).tobytes()


class ChunkNode:
    """One chunk of one cached prompt prefix. ``payload`` is opaque to
    the tree (the store attaches per-layer KV slices); ``nbytes`` is
    accounted by the store at insert time."""

    __slots__ = ("node_id", "parent", "key", "tokens", "payload",
                 "nbytes", "children", "refs", "stamp")

    def __init__(self, parent: Optional["ChunkNode"], tokens: np.ndarray,
                 payload, nbytes: int, stamp: int):
        self.parent = parent
        self.key = chunk_key(tokens)
        parent_id = parent.node_id if parent is not None else b"root"
        self.node_id = hashlib.blake2b(
            parent_id + self.key, digest_size=16).digest()
        self.tokens = np.asarray(tokens, np.int32).copy()
        self.payload = payload
        self.nbytes = nbytes
        self.children: Dict[bytes, "ChunkNode"] = {}
        self.refs = 0
        self.stamp = stamp

    @property
    def depth(self) -> int:
        """Chunks in the prefix this node terminates (self-inclusive)."""
        d, n = 1, self.parent
        while n is not None:
            d, n = d + 1, n.parent
        return d


class RadixTree:
    def __init__(self, chunk_tokens: int):
        assert chunk_tokens > 0
        self.chunk_tokens = chunk_tokens
        self.root_children: Dict[bytes, ChunkNode] = {}
        self.nodes: set = set()          # all live ChunkNodes (O(1) remove)
        self._stamp = 0

    def __len__(self) -> int:
        return len(self.nodes)

    def _tick(self) -> int:
        self._stamp += 1
        return self._stamp

    def _children_of(self, node: Optional[ChunkNode]) -> Dict[bytes,
                                                              ChunkNode]:
        return self.root_children if node is None else node.children

    # ------------------------------------------------------ lookup

    def walk(self, tokens: np.ndarray, *, touch: bool = False) \
            -> List[ChunkNode]:
        """Longest chunk-aligned cached prefix of ``tokens``: the node
        chain root→leafward. ``touch`` refreshes LRU stamps."""
        tokens = np.asarray(tokens, np.int32)
        C = self.chunk_tokens
        chain: List[ChunkNode] = []
        node: Optional[ChunkNode] = None
        for c in range(len(tokens) // C):
            child = self._children_of(node).get(
                chunk_key(tokens[c * C:(c + 1) * C]))
            if child is None:
                break
            if touch:
                child.stamp = self._tick()
            chain.append(child)
            node = child
        return chain

    def match_tokens(self, tokens: np.ndarray) -> int:
        """Length (in tokens) of the longest cached prefix. Pure read —
        no pin, no LRU touch; safe as a cross-thread routing heuristic."""
        return len(self.walk(tokens)) * self.chunk_tokens

    # ------------------------------------------------------ mutation

    def extend(self, parent: Optional[ChunkNode], tokens: np.ndarray,
               payload, nbytes: int) -> ChunkNode:
        """Add (or return the existing) child of ``parent`` for one
        chunk. An existing node keeps its payload — two rows of one
        gang inserting the same template must not double-store."""
        siblings = self._children_of(parent)
        key = chunk_key(tokens)
        node = siblings.get(key)
        if node is not None:
            node.stamp = self._tick()
            return node
        node = ChunkNode(parent, tokens, payload, nbytes, self._tick())
        siblings[key] = node
        self.nodes.add(node)
        return node

    def remove(self, node: ChunkNode) -> None:
        assert not node.children, "only leaves are evictable"
        self._children_of(node.parent).pop(node.key, None)
        self.nodes.discard(node)

    def evictable_leaves(self) -> List[ChunkNode]:
        """Unpinned leaves, oldest stamp first (the LRU eviction
        frontier)."""
        leaves = [n for n in self.nodes if not n.children and n.refs == 0]
        leaves.sort(key=lambda n: n.stamp)
        return leaves
