"""Cross-request prefix KV cache (content-addressed, placement-aware).

Layering:
    PrefixKVCache  — chunk store: radix-tree prompt matching, pinned
                     (ref-counted) LRU eviction under a byte budget,
                     placement-keyed like ``PrefixKVPool``
    RadixTree      — hash-chained chunk index (``radix``)
    slicing        — KV-pytree time-slice extract/assemble helpers

Consumed by ``DiffusionDecoder.prime_prompt_kv`` (chunk-aligned
prefill: assemble the longest cached prefix, compute only the novel
tail), ``BlockScheduler`` (hit-aware admission grouping), and
``EngineRouter`` (cache-affinity placement). Distinct from
``repro.serving.PrefixKVPool``, which recycles *buffers* by shape;
this store reuses *content*.
"""
from repro.cache.radix import ChunkNode, RadixTree, chunk_key
from repro.cache.slicing import (assemble_batch, assemble_rows,
                                 concat_chunks, extract_row, slice_nbytes,
                                 write_row)
from repro.cache.store import HOST_PLACEMENT, PrefixKVCache

__all__ = [
    "PrefixKVCache", "RadixTree", "ChunkNode", "chunk_key",
    "extract_row", "write_row", "concat_chunks", "assemble_rows",
    "assemble_batch", "slice_nbytes", "HOST_PLACEMENT",
]
