"""Cross-request prefix KV cache: content-addressed chunk store.

``PrefixKVCache`` maps *prompt content* to prefill KV: prompt token
ids are chunked into ``chunk_tokens`` blocks, indexed in a
hash-chained radix tree (``repro.cache.radix``), and each node carries
the per-layer KV slice the chunk-aligned prefill pass computed for it.
Because the cached prefill is chunk-causal (chunk *i* attends to
chunks ``0..i`` only — see ``DiffusionDecoder.prime_prompt_kv``), a
chunk's KV depends on nothing but the tokens up to and including it,
which is exactly what the radix chain addresses — so a slice computed
for one request is byte-valid for every other request sharing the
prefix, across gen-length buckets and across decode methods.

Placement: KV numerics and shapes are mesh-specific (tensor-parallel
head padding, sharded-matmul reduction order), so a store is keyed by
the ``DecodeExecutor`` placement exactly like ``PrefixKVPool`` — the
scheduler refuses a store bound to a different mesh, and a co-located
multi-engine deployment holds one store per engine (which is what
makes the router's cache-affinity policy meaningful).

Sharing: disaggregated prefill/decode pools need ONE store visible to
every engine — the prefill pool publishes chunk KV here and the decode
pool re-assembles it. Numerics depend on the mesh *shape* (reduction
order, head padding), not on which device ids back it, so a shared
store is keyed by ``DecodeExecutor.shape_key`` instead of the
device-id placement and constructed with ``shared=True``, which also
turns on internal locking (N engine threads match/insert/evict
concurrently; pins protect chunks across multi-call spans, the lock
protects the tree structure within each call).

Eviction: ref-counted LRU over leaf chunks with a byte budget
(``max_bytes``). ``match`` pins the returned chain; the caller unpins
after assembling the KV into its gang buffer, so chunks in active use
are never freed. Slices live as host numpy arrays — host staging keeps
the store off the accelerator's HBM budget; device-resident chunk
storage is a future optimization, not a semantic change.
"""
from __future__ import annotations

import contextlib
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.cache.radix import ChunkNode, RadixTree

HOST_PLACEMENT = ("host",)    # mirrors repro.serving.pool


class PrefixKVCache:
    def __init__(self, chunk_tokens: int = 16,
                 max_bytes: int = 256 << 20,
                 placement: Tuple = HOST_PLACEMENT,
                 shared: bool = False):
        self.chunk_tokens = chunk_tokens
        self.max_bytes = max_bytes
        self.placement = tuple(placement)
        self.shared = shared
        # single-engine stores are touched only by that engine's decode
        # thread (plus lock-free match_len probes) — no lock overhead
        self._lock = (threading.RLock() if shared
                      else contextlib.nullcontext())
        self.tree = RadixTree(chunk_tokens)
        self.bytes = 0
        self.evictions = 0
        self.inserts = 0
        self.lookups = 0
        self.lookup_hits = 0
        self.lookup_hit_tokens = 0

    def __repr__(self):
        return (f"PrefixKVCache(chunk={self.chunk_tokens}, "
                f"nodes={len(self.tree)}, bytes={self.bytes}, "
                f"placement={self.placement}, shared={self.shared})")

    # ------------------------------------------------------ lookup

    def match_len(self, prompt_tokens: np.ndarray) -> int:
        """Longest cached prefix in tokens. Pure read (no pin, no LRU
        touch, no counters) — the admission grouper and the router's
        affinity heuristic call this from other threads. A shared
        store locks so the walk never races a sibling's eviction."""
        with self._lock:
            return self.tree.match_tokens(prompt_tokens)

    def match(self, prompt_tokens: np.ndarray) -> List[ChunkNode]:
        """Longest cached prefix as a *pinned* node chain. The caller
        owns one reference per returned node and must ``unpin`` the
        chain once the KV has been copied out."""
        with self._lock:
            chain = self.tree.walk(prompt_tokens, touch=True)
            for node in chain:
                node.refs += 1
            self.lookups += 1
            if chain:
                self.lookup_hits += 1
                self.lookup_hit_tokens += len(chain) * self.chunk_tokens
            return chain

    def unpin(self, chain: Sequence[ChunkNode]) -> None:
        with self._lock:
            for node in chain:
                assert node.refs > 0
                node.refs -= 1

    # ------------------------------------------------------ mutation

    def insert(self, prompt_tokens: np.ndarray, start_chunk: int,
               chunk_kvs: List[dict],
               parent_chain: Optional[Sequence[ChunkNode]] = None) -> int:
        """Attach freshly computed chunk KV for chunks
        ``start_chunk .. start_chunk+len(chunk_kvs)`` of the prompt.
        The chain below ``start_chunk`` must already exist (it is the
        pinned match the prefill assembled or recomputed over);
        ``parent_chain`` skips re-walking it. Returns nodes created —
        an existing node (two gang rows sharing a template) is kept,
        never double-stored."""
        from repro.cache.slicing import slice_nbytes
        tokens = np.asarray(prompt_tokens, np.int32)
        C = self.chunk_tokens
        with self._lock:
            if (parent_chain is not None
                    and len(parent_chain) >= start_chunk):
                chain = list(parent_chain[:start_chunk])
            else:
                chain = self.tree.walk(tokens)
                if len(chain) < start_chunk:
                    return 0  # parent chain evicted under us: give up
                chain = chain[:start_chunk]
            parent = chain[-1] if chain else None
            created = 0
            for i, kv in enumerate(chunk_kvs):
                c = start_chunk + i
                nb = slice_nbytes(kv)
                before = len(self.tree)
                parent = self.tree.extend(parent,
                                          tokens[c * C:(c + 1) * C],
                                          kv, nb)
                if len(self.tree) > before:
                    created += 1
                    self.bytes += nb
                    self.inserts += 1
            self._evict_to_budget()
            return created

    def _evict_to_budget(self) -> None:
        """Level-wise LRU sweep: consume one sorted leaf scan in stamp
        order, then rescan only if evictions exposed new leaves (their
        parents) and the budget is still blown — O(levels · n log n),
        not one full scan per evicted chunk."""
        while self.bytes > self.max_bytes:
            leaves = self.tree.evictable_leaves()
            if not leaves:
                return        # everything left is pinned (or interior)
            for victim in leaves:
                if self.bytes <= self.max_bytes:
                    return
                if victim.children:
                    continue  # a later sibling eviction can't re-leaf it;
                              # defensive only
                self.tree.remove(victim)
                self.bytes -= victim.nbytes
                self.evictions += 1

    # ------------------------------------------------------ reporting

    @property
    def nodes(self) -> int:
        return len(self.tree)

    def stats(self) -> dict:
        with self._lock:
            return {"nodes": len(self.tree), "bytes": self.bytes,
                    "chunk_tokens": self.chunk_tokens,
                    "max_bytes": self.max_bytes, "shared": self.shared,
                    "evictions": self.evictions, "inserts": self.inserts,
                    "lookups": self.lookups,
                    "lookup_hits": self.lookup_hits,
                    "lookup_hit_tokens": self.lookup_hit_tokens}
