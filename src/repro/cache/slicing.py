"""Time-axis slice helpers for the decoder's KV cache pytree.

The cache layout (``repro.models.model.init_cache``) is
``{"scan": ((k, v), ...), "tail": ((k, v), ...)}`` with attention
buffers shaped ``(reps, B, T, H, D)`` for the scan-stacked pattern
groups and ``(B, T, H, D)`` for tail layers. The prefix cache stores
*per-row, per-chunk* time slices of that tree as host numpy arrays —
byte copies, so a chunk assembled back into a gang buffer carries
exactly the values the original prefill pass wrote (the bit-identity
the cached-prefill tests assert).

Only attention caches have a time axis; ``repro.cache`` is gated to
attention-only layouts (the decoder asserts it), so every leaf here is
4- or 5-dimensional KV.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def extract_row(cache, row: int, t0: int, t1: int):
    """One row's KV for time span [t0, t1) as a host pytree (blocking
    device→host copies; ``np.asarray`` preserves bytes incl. bf16)."""
    return {
        "scan": jax.tree.map(lambda a: np.asarray(a[:, row, t0:t1]),
                             cache["scan"]),
        "tail": jax.tree.map(lambda a: np.asarray(a[row, t0:t1]),
                             cache["tail"]),
    }


def write_row(cache, row: int, t0: int, kv):
    """Write a host KV slice back at [t0, t0+span) of one row. Returns
    the updated cache pytree (functional, like every cache op)."""
    return {
        "scan": jax.tree.map(
            lambda a, s: a.at[:, row, t0:t0 + s.shape[1]].set(
                jnp.asarray(s, a.dtype)), cache["scan"], kv["scan"]),
        "tail": jax.tree.map(
            lambda a, s: a.at[row, t0:t0 + s.shape[0]].set(
                jnp.asarray(s, a.dtype)), cache["tail"], kv["tail"]),
    }


def concat_chunks(chunks: List[dict]):
    """Fuse consecutive chunk slices into one contiguous slice, so
    assembling a long cached prefix costs one device write per row
    instead of one per chunk."""
    if len(chunks) == 1:
        return chunks[0]
    return {
        "scan": jax.tree.map(lambda *xs: np.concatenate(xs, axis=1),
                             *[c["scan"] for c in chunks]),
        "tail": jax.tree.map(lambda *xs: np.concatenate(xs, axis=0),
                             *[c["tail"] for c in chunks]),
    }


def assemble_rows(cache, row_chunks: Dict[int, List[dict]]):
    """Copy each row's cached chunk chain into the gang cache starting
    at time 0 (prompt region). ``row_chunks`` maps row index → ordered
    chunk KV slices."""
    for row, chunks in row_chunks.items():
        if chunks:
            cache = write_row(cache, row, 0, concat_chunks(chunks))
    return cache


def assemble_batch(cache, per_row_chunks: List[List[dict]]):
    """Assembly for a whole gang at a common hit depth: every row gets
    the SAME number of chunks (its own content), so the per-row chains
    stack into one host array per leaf and land in ONE device write per
    leaf — a `.at[].set` outside jit copies the entire buffer, so the
    per-row path costs B full-cache copies where this costs one."""
    if not per_row_chunks or not per_row_chunks[0]:
        return cache
    assert len({len(c) for c in per_row_chunks}) == 1, \
        "assemble_batch wants a common chunk depth across rows"
    rows = [concat_chunks(chunks) for chunks in per_row_chunks]
    # stack rows: scan slices (reps, L, H, D) -> (reps, B, L, H, D) at
    # axis 1, tail slices (L, H, D) -> (B, L, H, D) at axis 0
    kv = {
        "scan": jax.tree.map(lambda *xs: np.stack(xs, axis=1),
                             *[r["scan"] for r in rows]),
        "tail": jax.tree.map(lambda *xs: np.stack(xs, axis=0),
                             *[r["tail"] for r in rows]),
    }
    return {
        "scan": jax.tree.map(
            lambda a, s: a.at[:, :, :s.shape[2]].set(
                jnp.asarray(s, a.dtype)), cache["scan"], kv["scan"]),
        "tail": jax.tree.map(
            lambda a, s: a.at[:, :s.shape[1]].set(
                jnp.asarray(s, a.dtype)), cache["tail"], kv["tail"]),
    }


def slice_nbytes(kv) -> int:
    return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(kv))
