"""Dedicated decode-thread tick loop over ``ContinuousEngine``.

The continuous batcher is a synchronous pull loop: someone must call
``engine.step()`` for blocks to decode. ``EngineLoop`` owns that call
on a single daemon thread so the asyncio front end never blocks on
device work, and exposes the only thread-safe surface into the engine:

* ``submit(req, deliver)`` — called from any thread. Admission is
  checked synchronously against a bounded in-flight budget (reject →
  ``AdmissionRejected`` → HTTP 429); accepted requests enter a
  priority queue serviced by the decode thread.
* ``cancel(ticket, reason)`` — asynchronous; takes effect immediately
  for requests still queued in the front end, at the next block
  boundary for rows already decoding (see ``BlockScheduler.cancel``).
* events — the decode thread calls ``ticket.deliver(event)`` with
  ``("chunk", BlockChunk)`` per committed block and a final
  ``("done", Completion)``. The HTTP layer bridges ``deliver`` onto a
  per-request ``asyncio.Queue`` via ``call_soon_threadsafe``.

All engine/scheduler state is touched exclusively by the decode thread
(submissions and cancels are marshalled through a command queue), so
the serving subsystem itself needs no locks. Deadlines (``timeout_s``)
are enforced here each iteration: an expired request is cancelled with
reason ``deadline`` and counted in ``ServeMetrics.deadline_misses``.

Block-boundary work stealing (multi-engine fleets): when this loop has
free slots and nothing queued, it asks the ``EngineRouter`` for the
most-backlogged sibling and posts a ``steal`` command to it. The
*victim's* decode thread services the command between ticks — i.e. at a
block boundary, where every row's state is at rest — handing over (in
cheapest-first order) scheduler-waiting requests, front-end-pending
tickets, and finally parked (preempted) rows whose host-side
``DecodeState`` the thief adopts and resumes through the normal
pool-acquire + radix-re-prime path. Ticket ownership (``ticket.loop``)
moves with the request so cancels and deadlines keep routing to
whichever engine currently holds it; in-flight accounting transfers
under both loops' locks. This unfreezes the at-admission load split
that placement-only routing produces (ROADMAP open item 1).
"""
from __future__ import annotations

import heapq
import itertools
import queue
import threading
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.obs.log import get_logger
from repro.serving.types import Completion
from repro.server.types import AdmissionRejected, ServerRequest

log = get_logger(__name__)

Event = Tuple[str, object]


class Ticket:
    """Handle for one in-flight request: the cancellation token and the
    delivery target. ``uid`` is assigned once the request is handed to
    the scheduler; until then the ticket lives in the front-end queue
    and can be cancelled without the engine ever seeing it."""

    def __init__(self, req: ServerRequest,
                 deliver: Callable[[Event], None]):
        self.req = req
        self.deliver = deliver
        self.submit_time = time.perf_counter()
        self.deadline = (self.submit_time + req.timeout_s
                         if req.timeout_s else None)
        self.uid: Optional[int] = None
        self.done = False
        self.cancel_reason: Optional[str] = None
        self.loop = None          # owning EngineLoop (set by EngineRouter)
        self.trace_id = ""        # repro.obs correlation id ("" = off)
        self.accept_ns: Optional[int] = None  # HTTP-accept timestamp
        self.handoff_t: Optional[float] = None
                                  # prefill-pool extraction stamp; the
                                  # decode-pool adopter measures the
                                  # handoff wait from it

    def _emit(self, event: Event) -> None:
        try:
            self.deliver(event)
        except Exception:
            log.exception("ticket delivery failed (uid=%s)", self.uid)


class EngineLoop:
    def __init__(self, engine, max_pending: int = 64,
                 idle_poll_s: float = 0.05, tracer=None, index: int = 0,
                 role: Optional[str] = None):
        self.engine = engine
        self.max_pending = max_pending
        self.idle_poll_s = idle_poll_s
        self.index = index          # position in the fleet (track label)
        # pool role (disaggregated serving): "prefill" loops prime and
        # hand off, "decode" loops adopt and decode, "both" is the
        # co-located default. Derived from the engine when not given;
        # a stated role must agree with the engine's mode.
        derived = ("prefill" if getattr(engine, "prefill_only", False)
                   else "both")
        self.role = role or derived
        if (self.role == "prefill") != (derived == "prefill"):
            raise ValueError(
                f"role {self.role!r} does not match engine "
                f"prefill_only={getattr(engine, 'prefill_only', False)}")
        self.tracer = tracer
        if tracer is not None:
            engine.set_tracer(tracer, f"engine-{index}")
        self._cmds: "queue.Queue" = queue.Queue()
        self._pending: List[list] = []      # heap: [-priority, seq, ticket]
        self._seq = itertools.count()
        self._live = {}                     # uid -> Ticket
        self._inflight = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._drain_on_stop = True
        # block-boundary work stealing (set by EngineRouter)
        self.router = None
        self.steal = False
        # quality auditing / post-mortems (set by the front end):
        # SLOWatchdog observes each completion; FlightRecorder is the
        # dump sink for SLO breaches and decode-thread crashes
        self.watchdog = None
        self.flight = None
        # time-series recorder (repro.obs.series; set by the front end).
        # Sampled on the decode thread each iteration, closed at drain.
        self.recorder = None
        self._steal_inflight = False        # one outstanding steal ask
        self._next_steal_t = 0.0            # backoff after an empty grant
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-engine-loop")
        engine.on_chunk(None, self._on_chunk)

    # ------------------------------------------------- any-thread API

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._inflight

    @property
    def running(self) -> bool:
        return self._thread.is_alive()

    def debug_vars(self) -> dict:
        """Live JSON-safe state for ``GET /debug/vars`` and flight
        dumps: front-end queue depths plus the scheduler's occupancy
        snapshot and steal/compile/audit counters. Read from the
        asyncio thread while the decode thread runs — values may be one
        tick stale but never torn (GIL + list snapshots)."""
        eng = self.engine
        out = {
            "index": self.index,
            "role": self.role,
            "running": self.running,
            "inflight": self.inflight,
            "pending": len(self._pending),
            "live": len(self._live),
            "max_pending": self.max_pending,
            "steals_out": eng.metrics.steals_out,
            "steals_in": eng.metrics.steals_in,
            "handoffs_out": eng.metrics.handoffs_out,
            "handoffs_in": eng.metrics.handoffs_in,
            "scheduler": eng.scheduler.debug_state(),
        }
        if eng.auditor is not None:
            out["audit"] = eng.auditor.stats()
        if self.recorder is not None:
            out["recorder"] = self.recorder.last_rates()
        return out

    def start(self) -> "EngineLoop":
        self._thread.start()
        return self

    def submit(self, req: ServerRequest,
               deliver: Callable[[Event], None],
               count_reject: bool = True) -> Ticket:
        """Admit or reject *synchronously*; never blocks on the engine.
        The bounded budget covers everything submitted but unfinished
        (front-end queue + scheduler queue + decoding rows).

        ``count_reject=False`` raises without touching the rejection
        counter — the multi-engine router spills a rejected request to
        a peer engine, and a spill that gets *served* is not a 429; the
        router counts exactly once when every engine rejects.

        Counter ownership: ``admission_rejects`` is written only here
        and in ``count_admission_reject``, under ``_lock`` (the decode
        thread pre-checks ``max_waiting`` in ``_feed`` so the
        engine-side increment never fires); ``cancelled``/
        ``deadline_misses`` are written only by the decode thread. One
        writer per counter — no torn updates."""
        with self._lock:
            if self._stop.is_set():
                if count_reject:
                    self.engine.metrics.admission_rejects += 1
                raise AdmissionRejected("server is shutting down",
                                        retry_after_s=5.0)
            if self._inflight >= self.max_pending:
                if count_reject:
                    self.engine.metrics.admission_rejects += 1
                raise AdmissionRejected(
                    f"admission queue full ({self.max_pending} in flight)",
                    retry_after_s=1.0)
            self._inflight += 1
        ticket = Ticket(req, deliver)
        if self.tracer is not None:
            ticket.trace_id = self.tracer.new_trace_id()
        self._cmds.put(("submit", ticket, None))
        return ticket

    def count_admission_reject(self) -> None:
        """Record one client-visible 429 (router path: all engines
        rejected)."""
        with self._lock:
            self.engine.metrics.admission_rejects += 1

    def cancel(self, ticket: Ticket, reason: str = "cancelled") -> None:
        self._cmds.put(("cancel", ticket, reason))

    def request_stop(self, drain: bool = True) -> None:
        """Signal the decode thread to stop without waiting — the
        multi-engine router signals every loop first so their drains
        overlap instead of serializing."""
        self._drain_on_stop = drain
        self._stop.set()
        self._cmds.put(("wake", None, None))

    def join(self, timeout_s: float = 30.0) -> bool:
        if self._thread.is_alive():
            self._thread.join(timeout_s)
        return not self._thread.is_alive()

    def close(self, drain: bool = True, timeout_s: float = 30.0) -> bool:
        """Stop the loop. ``drain=True`` finishes everything already
        admitted first (new submits are rejected); ``drain=False``
        cancels all in-flight work. Returns True if the thread exited
        within ``timeout_s``."""
        self.request_stop(drain)
        return self.join(timeout_s)

    # ------------------------------------------------- decode thread

    def _run(self) -> None:
        # however the loop exits (drain, no-drain, or a crash that
        # escaped the per-step guard), the observability capture must
        # close: the recorder flushes its final sample and detaches
        # from the --metrics-log sink, and an active profiler capture
        # is stopped — a drained fleet leaks neither
        try:
            self._run_loop()
        finally:
            self._shutdown_obs()

    def _shutdown_obs(self) -> None:
        if self.recorder is not None:
            self.recorder.close()
        profiler = getattr(self.engine, "profiler", None)
        if profiler is not None:
            try:
                profiler.close()
            except Exception:
                log.exception("profiler close failed at drain")

    def _run_loop(self) -> None:
        eng = self.engine
        if self.tracer is not None:
            self.tracer.name_thread("decode", pid=eng.obs_pid)
        while True:
            busy = bool(self._pending or self._live
                        or not eng.scheduler.idle
                        or eng.audit_pending)
            self._drain_commands(block=not busy)
            if self._stop.is_set():
                if not self._drain_on_stop:
                    self._cancel_all("shutdown")
                elif not (self._pending or self._live or self.inflight
                          or not eng.scheduler.idle
                          or self._draining_prefill_peers()):
                    return
            self._check_deadlines()
            self._feed()
            self._maybe_steal()
            if not eng.scheduler.idle:
                try:
                    for comp in eng.step():
                        self._finish(comp)
                except Exception:
                    # an engine failure must not kill the serving
                    # thread: move still-portable work to healthy
                    # siblings, then fail whatever could not move and
                    # keep accepting
                    log.exception("engine.step failed; re-routing and "
                                  "failing in-flight requests")
                    if self.flight is not None:
                        self.flight.dump("crash")
                    # rows primed before the failure are store-backed
                    # and safe to migrate — dispatch them first so the
                    # blanket error-cancel below never reaches them
                    self._dispatch_handoffs()
                    moved = self._reroute_all()
                    if moved:
                        log.info("re-routed %d request(s) off engine %d "
                                 "after step failure", moved, self.index)
                    self._cancel_all("error")
            # prefill pool: migrate rows the step just primed (also
            # drains anything a mid-tick failure left extracted)
            self._dispatch_handoffs()
            # audit lane: one decoder call per iteration, and only when
            # the scheduler reports no waiting traffic (the auditor
            # checks again itself) — paying requests always preempt it
            # at the next block boundary
            eng.audit_tick()
            eng.metrics.queue_depth = (len(self._pending)
                                       + len(eng.scheduler.waiting))
            if self.recorder is not None:
                # cheap per-iteration cadence check; a real sample at
                # most once per interval (repro.obs.series)
                self.recorder.maybe_sample()
            if self._stop.is_set() and not self._drain_on_stop \
                    and not self._live and eng.scheduler.idle:
                return

    def _drain_commands(self, block: bool) -> None:
        try:
            cmd = self._cmds.get(timeout=self.idle_poll_s) if block \
                else self._cmds.get_nowait()
        except queue.Empty:
            return
        while True:
            self._exec(cmd)
            try:
                cmd = self._cmds.get_nowait()
            except queue.Empty:
                return

    def _exec(self, cmd) -> None:
        kind, ticket, reason = cmd
        if kind == "submit":
            heapq.heappush(self._pending,
                           [-ticket.req.priority, next(self._seq), ticket])
        elif kind == "cancel":
            self._cancel_ticket(ticket, reason)
        elif kind == "steal":            # I'm the victim: (thief, k)
            thief, k = ticket
            self._serve_steal(thief, k)
        elif kind == "steal_give":       # I'm the thief: a queued ticket
            self.engine.metrics.steals_in += 1
            heapq.heappush(self._pending,
                           [-ticket.req.priority, next(self._seq), ticket])
        elif kind == "adopt":            # I'm the thief: a parked row
            self._adopt(*ticket)
        elif kind == "handoff_give":     # I'm a decode engine: a row the
            self._adopt_handoff(*ticket)  # prefill pool just primed
        elif kind == "steal_done":       # grant report: ticket = count
            self._steal_inflight = False
            if not ticket:
                self._next_steal_t = (time.perf_counter()
                                      + 10 * self.idle_poll_s)

    # ------------------------------------------------- work stealing

    def _maybe_steal(self) -> None:
        """Thief side: with free slots and an empty local queue, ask the
        router for the most-backlogged sibling and post it a steal
        command (serviced on the victim's decode thread at its next
        block boundary). One outstanding ask at a time; an empty grant
        backs off so an idle fleet doesn't spin on steal traffic."""
        if (self.router is None or not self.steal or self._steal_inflight
                or self._stop.is_set()):
            return
        if time.perf_counter() < self._next_steal_t:
            return
        sched = self.engine.scheduler
        if self._pending or sched.waiting or sched.paused:
            return
        free = sched.max_slots - sched.slots_used
        if free <= 0:
            return
        victim, backlog = self.router.pick_victim(self)
        if victim is None:
            return
        self._steal_inflight = True
        victim._cmds.put(("steal", (self, max(1, min(free, backlog // 2))),
                          None))

    def _serve_steal(self, thief: "EngineLoop", k: int) -> None:
        """Victim side, on the decode thread between ticks: grant up to
        ``k`` requests, cheapest-to-move first — scheduler-waiting (no
        state), front-end-pending (never reached the engine), then
        parked rows (host DecodeState the thief resumes)."""
        given = 0
        for _ in range(k):
            if not self._steal_one(thief):
                break
            given += 1
        if given:
            log.info("stole %d request(s): engine %d -> engine %d",
                     given, self.index, thief.index)
        thief._cmds.put(("steal_done", given, None))

    def _steal_one(self, thief: "EngineLoop") -> bool:
        eng = self.engine
        req = eng.steal_waiting()
        if req is not None:
            ticket = self._live.pop(req.uid, None)
            if ticket is None:       # direct engine submission: not ours
                eng.scheduler.waiting.append(req)
                return False
            ticket.uid = None        # thief re-submits through its feed
            self._transfer(ticket, thief)
            thief._cmds.put(("steal_give", ticket, None))
            return True
        while self._pending:
            _, _, ticket = heapq.heappop(self._pending)
            if ticket.done:
                continue
            eng.metrics.steals_out += 1
            self._transfer(ticket, thief)
            thief._cmds.put(("steal_give", ticket, None))
            return True
        out = eng.steal_paused()
        if out is not None:
            req, state = out
            ticket = self._live.pop(req.uid, None)
            if ticket is None:
                eng.scheduler.paused.append(
                    (req, state, eng.scheduler.decoder_for(req.gen_len)))
                return False
            ticket.uid = None
            self._transfer(ticket, thief)
            thief._cmds.put(("adopt", (ticket, req, state), None))
            return True
        return False

    def _transfer(self, ticket: Ticket, thief: "EngineLoop") -> None:
        """Move in-flight accounting and cancel/deadline ownership to
        the thief. From here on ``cancel()`` on this loop forwards."""
        ticket.loop = thief
        with self._lock:
            self._inflight -= 1
        with thief._lock:
            thief._inflight += 1

    def _adopt(self, ticket: Ticket, req, state) -> None:
        """Thief side: adopt a stolen parked row. A cancel that raced
        the handoff already concluded the ticket — drop the state (it
        holds no device resources; parked rows travel cache-free)."""
        if ticket.done:
            return
        ticket.uid = self.engine.adopt_paused(req, state)
        self._live[ticket.uid] = ticket

    # ------------------------------------------------- handoff

    def _dispatch_handoffs(self) -> None:
        """Prefill-pool side: migrate every row the scheduler just
        primed to a decode-pool engine. The request travels bare — its
        chunk KV is already in the shared radix store, so the adopter's
        normal admission prefill reassembles it there (O(remainder)).
        The ticket transfers exactly like a steal: ownership moves
        first, so cancels queued behind this iteration forward to the
        adopter and conclude exactly once."""
        eng = self.engine
        if not getattr(eng, "prefill_only", False):
            return
        for req in eng.take_handoffs():
            ticket = self._live.pop(req.uid, None)
            if ticket is None:
                # direct engine submission (no front-end ticket):
                # unsupported on a loop-owned prefill engine — a
                # prefill pool can never complete it locally
                log.error("handoff-ready request without a ticket "
                          "(uid=%s) dropped — submit through the loop",
                          req.uid)
                continue
            if ticket.done:
                continue                 # cancel raced the extraction
            target = (self.router.pick_decode_loop(exclude=self)
                      if self.router is not None else None)
            if target is None:
                # no healthy decode engine: fail the request rather
                # than strand it (the prefill pool cannot decode)
                log.error("no decode-pool engine for handoff "
                          "(uid=%s); failing request", req.uid)
                ticket.uid = None
                self._cancel_ticket(ticket, "error")
                continue
            ticket.uid = None            # adopter assigns its own uid
            ticket.handoff_t = time.perf_counter()
            self._transfer(ticket, target)
            target._cmds.put(("handoff_give", (ticket, req), None))

    def _adopt_handoff(self, ticket: Ticket, req) -> None:
        """Decode-pool side: adopt a prefill-primed request. A cancel
        that raced the migration already concluded the ticket — the
        row's store chunks are unpinned (the prefill pass released
        them), so dropping the request leaks nothing."""
        if ticket.done:
            return
        wait = (time.perf_counter() - ticket.handoff_t
                if ticket.handoff_t is not None else None)
        ticket.handoff_t = None
        ticket.uid = self.engine.adopt_handoff(req, wait_s=wait)
        self._live[ticket.uid] = ticket

    def _draining_prefill_peers(self) -> bool:
        """A draining decode-capable loop may not exit while a prefill
        sibling still holds work — that work's tail is a handoff this
        loop must be alive to adopt. ``inflight`` is the signal — it is
        bumped synchronously at submit (command-queue entries that
        ``_pending``/``_live`` can't see yet) and moves to the adopter
        at transfer, exactly when the obligation moves. Racy cross-
        thread reads (GIL-safe, one-poll stale at worst); a dead
        prefill thread never blocks."""
        if self.router is None or self.role == "prefill":
            return False
        return any(lp.running and (lp.inflight
                                   or not lp.engine.scheduler.idle)
                   for lp in self.router.prefill_pool)

    def _reroute_all(self) -> int:
        """After an ``engine.step`` failure: move still-portable work —
        scheduler-waiting requests, front-end-pending tickets, parked
        host-portable rows — to healthy siblings (same pool first, then
        any decode-capable engine) via the steal machinery, so a
        crashed engine sheds its queue instead of failing it. Active
        gang rows stay: their device state died with the step."""
        if self.router is None:
            return 0
        moved = 0
        while True:
            target = self.router.pick_reroute_target(self)
            if target is None or not self._steal_one(target):
                return moved
            moved += 1

    def _feed(self) -> None:
        """Hand queued requests to the scheduler in priority order.
        The scheduler's own waiting queue is kept topped up to
        ``max_slots`` so its within-tick backfill always has material;
        everything beyond that waits here, where priority and
        pre-admission cancellation still apply. An engine-level
        ``max_waiting`` bound is respected by pre-checking, never by
        letting ``engine.submit`` raise — that path counts an
        admission *reject*, and backing off to retry is not one."""
        sched = self.engine.scheduler
        limit = sched.max_slots if sched.max_waiting is None \
            else min(sched.max_slots, sched.max_waiting)
        while self._pending and len(sched.waiting) < limit:
            _, _, ticket = heapq.heappop(self._pending)
            if ticket.done:
                continue
            try:
                ticket.uid = self.engine.submit(
                    ticket.req.prompt, max_tokens=ticket.req.max_tokens,
                    trace_id=ticket.trace_id)
            except RuntimeError:
                # defensive only (the pre-check makes this unreachable
                # on the single mutating thread): undo the spurious
                # reject count and park the ticket for the next round
                self.engine.metrics.admission_rejects -= 1
                heapq.heappush(self._pending,
                               [-ticket.req.priority, next(self._seq),
                                ticket])
                break
            self._live[ticket.uid] = ticket

    def _check_deadlines(self) -> None:
        now = time.perf_counter()
        expired = [t for t in
                   [e[2] for e in self._pending] + list(self._live.values())
                   if not t.done and t.deadline is not None
                   and now >= t.deadline]
        for t in expired:
            self.engine.metrics.deadline_misses += 1
            self._cancel_ticket(t, "deadline")

    def _cancel_all(self, reason: str) -> None:
        for entry in list(self._pending):
            self._cancel_ticket(entry[2], reason)
        for t in list(self._live.values()):
            self._cancel_ticket(t, reason)

    def _cancel_ticket(self, ticket: Ticket, reason: str) -> None:
        if ticket.done:
            return
        if ticket.loop is not None and ticket.loop is not self:
            # the ticket migrated (work stealing) after this cancel was
            # queued here — forward to the current owner; acting locally
            # would cancel whatever request now holds that uid
            ticket.loop.cancel(ticket, reason)
            return
        ticket.cancel_reason = reason
        if ticket.uid is None:
            # never reached the engine: synthesize the empty completion
            self.engine.metrics.cancelled += 1
            self._conclude(ticket, Completion(
                uid=-1, text="", tokens=np.zeros(0, np.int32),
                latency_s=time.perf_counter() - ticket.submit_time,
                nfe=0, max_tokens=ticket.req.max_tokens, cancelled=True))
            return
        comp = self.engine.cancel(ticket.uid)
        if comp is not None:    # was waiting/paused: finished immediately
            self._live.pop(ticket.uid, None)
            self._conclude(ticket, comp)
        # else: active row — Completion arrives via step() -> _finish

    def _on_chunk(self, chunk) -> None:
        ticket = self._live.get(chunk.uid)
        if ticket is not None and not ticket.done:
            ticket._emit(("chunk", chunk))

    def _finish(self, comp: Completion) -> None:
        ticket = self._live.pop(comp.uid, None)
        if self.watchdog is not None:
            self.watchdog.observe(comp)
        if ticket is not None:
            self._conclude(ticket, comp)

    def _conclude(self, ticket: Ticket, comp: Completion) -> None:
        ticket.done = True
        with self._lock:
            self._inflight -= 1
        ticket._emit(("done", comp))
