"""Network-facing request records and error types for ``repro.server``.

``ServerRequest`` is the wire-level request: what ``POST
/v1/completions`` accepts, plus the lifecycle fields the engine loop
acts on (deadline, priority). It is deliberately separate from
``repro.serving.types.ServeRequest`` — that record is the scheduler's
internal bookkeeping; this one is the validated client contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


class ServerError(Exception):
    """Base for errors that map onto an HTTP status code."""
    status = 500
    reason = "Internal Server Error"

    def __init__(self, message: str):
        super().__init__(message)
        self.message = message


class BadRequest(ServerError):
    status = 400
    reason = "Bad Request"


class AdmissionRejected(ServerError):
    """Bounded admission queue is full → HTTP 429 + ``Retry-After``."""
    status = 429
    reason = "Too Many Requests"

    def __init__(self, message: str, retry_after_s: float = 1.0):
        super().__init__(message)
        self.retry_after_s = retry_after_s


@dataclasses.dataclass
class ServerRequest:
    """One validated completion request.

    ``timeout_s`` is a deadline measured from submission: if the
    request has not finished by then it is *cancelled* (partial result,
    ``finish_reason="deadline"``), never silently truncated or left
    running. ``priority`` is best-effort: higher values leave the
    front-end admission queue first, but once requests are handed to
    the scheduler they are gang-batched by shape, so priority orders
    admission, not execution."""
    prompt: str
    max_tokens: int = 64
    stream: bool = False
    timeout_s: Optional[float] = None
    priority: int = 0
    trace: bool = False         # echo the request's span events in the
                                # completion JSON (needs a live Tracer)

    MAX_TOKENS_CAP = 4096
    PROMPT_CAP = 65536

    @classmethod
    def from_json(cls, obj) -> "ServerRequest":
        if not isinstance(obj, dict):
            raise BadRequest("request body must be a JSON object")
        if "prompt" not in obj or not isinstance(obj["prompt"], str):
            raise BadRequest("'prompt' (string) is required")
        if len(obj["prompt"]) > cls.PROMPT_CAP:
            raise BadRequest(f"'prompt' longer than {cls.PROMPT_CAP} chars")
        if not obj["prompt"]:
            raise BadRequest("'prompt' must be non-empty")
        mt = obj.get("max_tokens", 64)
        if not isinstance(mt, int) or isinstance(mt, bool) \
                or not 1 <= mt <= cls.MAX_TOKENS_CAP:
            raise BadRequest(
                f"'max_tokens' must be an int in [1, {cls.MAX_TOKENS_CAP}]")
        stream = obj.get("stream", False)
        if not isinstance(stream, bool):
            raise BadRequest("'stream' must be a boolean")
        timeout_s = obj.get("timeout_s")
        if timeout_s is not None:
            if isinstance(timeout_s, bool) \
                    or not isinstance(timeout_s, (int, float)) \
                    or timeout_s <= 0:
                raise BadRequest("'timeout_s' must be a positive number")
            timeout_s = float(timeout_s)
        priority = obj.get("priority", 0)
        if not isinstance(priority, int) or isinstance(priority, bool):
            raise BadRequest("'priority' must be an int")
        trace = obj.get("trace", False)
        if not isinstance(trace, bool):
            raise BadRequest("'trace' must be a boolean")
        unknown = set(obj) - {"prompt", "max_tokens", "stream",
                              "timeout_s", "priority", "trace"}
        if unknown:
            raise BadRequest(f"unknown fields: {sorted(unknown)}")
        return cls(prompt=obj["prompt"], max_tokens=mt, stream=stream,
                   timeout_s=timeout_s, priority=priority, trace=trace)


def finish_reason(comp, cancel_reason: Optional[str]) -> str:
    """OpenAI-style terminal cause for a ``Completion``: ``stop`` (EOS),
    ``length`` (token budget exhausted), or the cancel cause
    (``cancelled`` / ``disconnect`` / ``deadline`` / ``shutdown``)."""
    if comp.cancelled:
        return cancel_reason or "cancelled"
    if comp.n_tokens < comp.max_tokens:
        return "stop"
    return "length"
