"""Stdlib-only asyncio HTTP client for the repro server.

Exists so the test suite and the load harness can drive the server
over real sockets without external dependencies. Speaks exactly the
server's dialect: one request per connection, ``Connection: close``,
chunked SSE for streams.
"""
from __future__ import annotations

import asyncio
import json
from typing import AsyncIterator, Dict, Optional, Tuple

from repro.server import wire


def _request_bytes(method: str, path: str, host: str,
                   body: bytes = b"") -> bytes:
    head = [f"{method} {path} HTTP/1.1",
            f"Host: {host}",
            "Connection: close"]
    if body:
        head.append("Content-Type: application/json")
        head.append(f"Content-Length: {len(body)}")
    return ("\r\n".join(head) + "\r\n\r\n").encode("latin1") + body


async def _read_head(reader: asyncio.StreamReader) \
        -> Tuple[int, Dict[str, str]]:
    status_line = await reader.readline()
    status = int(status_line.split()[1])
    headers: Dict[str, str] = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        key, _, val = line.decode("latin1").partition(":")
        headers[key.strip().lower()] = val.strip()
    return status, headers


async def request(host: str, port: int, method: str, path: str,
                  payload: Optional[dict] = None) \
        -> Tuple[int, Dict[str, str], bytes]:
    """One fixed-length request/response exchange. Returns
    ``(status, headers, body)``."""
    body = json.dumps(payload).encode() if payload is not None else b""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(_request_bytes(method, path, host, body))
        await writer.drain()
        status, headers = await _read_head(reader)
        n = int(headers.get("content-length", 0) or 0)
        resp = await reader.readexactly(n) if n else await reader.read()
        return status, headers, resp
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def complete(host: str, port: int, payload: dict) \
        -> Tuple[int, Dict[str, str], Optional[dict]]:
    """``POST /v1/completions`` with ``stream:false`` semantics."""
    status, headers, body = await request(
        host, port, "POST", "/v1/completions", payload)
    doc = json.loads(body) if body else None
    return status, headers, doc


class SSEStream:
    """An open streaming completion. Iterate ``events()`` for parsed
    ``data:`` payloads (dicts; the ``[DONE]`` sentinel ends iteration);
    call ``abort()`` to drop the connection mid-stream — the server
    must treat that as a cancel."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter, status: int,
                 headers: Dict[str, str]):
        self._reader = reader
        self._writer = writer
        self.status = status
        self.headers = headers
        self.error: Optional[dict] = None

    @classmethod
    async def open(cls, host: str, port: int, payload: dict) \
            -> "SSEStream":
        payload = dict(payload, stream=True)
        body = json.dumps(payload).encode()
        reader, writer = await asyncio.open_connection(host, port)
        writer.write(_request_bytes("POST", "/v1/completions", host, body))
        await writer.drain()
        status, headers = await _read_head(reader)
        stream = cls(reader, writer, status, headers)
        if status != 200:
            n = int(headers.get("content-length", 0) or 0)
            raw = await reader.readexactly(n) if n else b""
            stream.error = json.loads(raw) if raw else None
            await stream.close()
        return stream

    async def events(self) -> AsyncIterator[dict]:
        buf = b""
        async for data in wire.read_chunked(self._reader):
            buf += data
            while b"\n\n" in buf:
                event, buf = buf.split(b"\n\n", 1)
                for line in event.split(b"\n"):
                    if not line.startswith(b"data: "):
                        continue
                    text = line[len(b"data: "):].decode()
                    if text == wire.SSE_DONE_SENTINEL:
                        return
                    yield json.loads(text)

    def abort(self) -> None:
        """Hard-drop the connection (simulates a vanished client)."""
        self._writer.close()

    async def close(self) -> None:
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionError, OSError):
            pass
