"""Stdlib-only asyncio HTTP client for the repro server.

Exists so the test suite and the load harness can drive the server
over real sockets without external dependencies. Speaks exactly the
server's dialect: ``Content-Length`` JSON exchanges (one-shot
``Connection: close`` via ``request``/``complete``, or a persistent
keep-alive socket via ``ClientSession`` — per-request TCP setup
dominates small-prompt TTFB, so closed-loop clients should reuse their
connection), and chunked SSE for streams.
"""
from __future__ import annotations

import asyncio
import json
from typing import AsyncIterator, Dict, Optional, Tuple

from repro.server import wire


def _request_bytes(method: str, path: str, host: str,
                   body: bytes = b"", keep_alive: bool = False) -> bytes:
    head = [f"{method} {path} HTTP/1.1",
            f"Host: {host}",
            f"Connection: {'keep-alive' if keep_alive else 'close'}"]
    if body:
        head.append("Content-Type: application/json")
        head.append(f"Content-Length: {len(body)}")
    return ("\r\n".join(head) + "\r\n\r\n").encode("latin1") + body


async def _read_head(reader: asyncio.StreamReader) \
        -> Tuple[int, Dict[str, str]]:
    status_line = await reader.readline()
    parts = status_line.split()
    if len(parts) < 2 or not parts[1].isdigit():
        # EOF or a torn partial line: the peer closed (or died on) the
        # possibly-stale keep-alive connection mid-response — surface a
        # connection error so ClientSession's reconnect retry fires,
        # never an IndexError from a half-flushed status line
        raise asyncio.IncompleteReadError(status_line, None)
    status = int(parts[1])
    headers: Dict[str, str] = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        key, _, val = line.decode("latin1").partition(":")
        headers[key.strip().lower()] = val.strip()
    return status, headers


async def request(host: str, port: int, method: str, path: str,
                  payload: Optional[dict] = None) \
        -> Tuple[int, Dict[str, str], bytes]:
    """One fixed-length request/response exchange. Returns
    ``(status, headers, body)``."""
    body = json.dumps(payload).encode() if payload is not None else b""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(_request_bytes(method, path, host, body))
        await writer.drain()
        status, headers = await _read_head(reader)
        n = int(headers.get("content-length", 0) or 0)
        resp = await reader.readexactly(n) if n else await reader.read()
        return status, headers, resp
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def complete(host: str, port: int, payload: dict) \
        -> Tuple[int, Dict[str, str], Optional[dict]]:
    """``POST /v1/completions`` with ``stream:false`` semantics."""
    status, headers, body = await request(
        host, port, "POST", "/v1/completions", payload)
    doc = json.loads(body) if body else None
    return status, headers, doc


class ClientSession:
    """A persistent keep-alive connection: many fixed-length exchanges
    over one socket. The server may close an idle session (its
    keep-alive timeout) — a send that hits a dead socket transparently
    reconnects once, so callers just keep issuing requests.

        sess = ClientSession(host, port)
        status, headers, doc = await sess.complete({...})
        ...
        await sess.close()
    """

    def __init__(self, host: str, port: int):
        self.host = host
        self.port = port
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None
        self.connects = 0          # sockets opened (1 = fully reused)
        self.requests = 0

    @property
    def connected(self) -> bool:
        return self._writer is not None and not self._writer.is_closing()

    async def _connect(self) -> None:
        await self.close()
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        self.connects += 1

    async def _exchange(self, data: bytes) -> Tuple[int, Dict[str, str],
                                                    bytes]:
        self._writer.write(data)
        await self._writer.drain()
        status, headers = await _read_head(self._reader)
        n = int(headers.get("content-length", 0) or 0)
        body = await self._reader.readexactly(n) if n else b""
        if headers.get("connection", "").lower() == "close":
            await self.close()     # server ended the session
        return status, headers, body

    async def request(self, method: str, path: str,
                      payload: Optional[dict] = None) \
            -> Tuple[int, Dict[str, str], bytes]:
        body = json.dumps(payload).encode() if payload is not None else b""
        data = _request_bytes(method, path, self.host, body,
                              keep_alive=True)
        if not self.connected:
            await self._connect()
        try:
            out = await self._exchange(data)
        except (ConnectionError, asyncio.IncompleteReadError):
            # stale keep-alive socket (server idle-timeout): retry once
            # on a fresh connection; a second failure is a real error
            await self._connect()
            out = await self._exchange(data)
        self.requests += 1
        return out

    async def complete(self, payload: dict) \
            -> Tuple[int, Dict[str, str], Optional[dict]]:
        status, headers, body = await self.request(
            "POST", "/v1/completions", payload)
        return status, headers, json.loads(body) if body else None

    async def close(self) -> None:
        w, self._reader, self._writer = self._writer, None, None
        if w is not None:
            w.close()
            try:
                await w.wait_closed()
            except (ConnectionError, OSError):
                pass


class SSEStream:
    """An open streaming completion. Iterate ``events()`` for parsed
    ``data:`` payloads (dicts; the ``[DONE]`` sentinel ends iteration);
    call ``abort()`` to drop the connection mid-stream — the server
    must treat that as a cancel."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter, status: int,
                 headers: Dict[str, str]):
        self._reader = reader
        self._writer = writer
        self.status = status
        self.headers = headers
        self.error: Optional[dict] = None

    @classmethod
    async def open(cls, host: str, port: int, payload: dict) \
            -> "SSEStream":
        payload = dict(payload, stream=True)
        body = json.dumps(payload).encode()
        reader, writer = await asyncio.open_connection(host, port)
        writer.write(_request_bytes("POST", "/v1/completions", host, body))
        await writer.drain()
        status, headers = await _read_head(reader)
        stream = cls(reader, writer, status, headers)
        if status != 200:
            n = int(headers.get("content-length", 0) or 0)
            raw = await reader.readexactly(n) if n else b""
            stream.error = json.loads(raw) if raw else None
            await stream.close()
        return stream

    async def events(self) -> AsyncIterator[dict]:
        buf = b""
        async for data in wire.read_chunked(self._reader):
            buf += data
            while b"\n\n" in buf:
                event, buf = buf.split(b"\n\n", 1)
                for line in event.split(b"\n"):
                    if not line.startswith(b"data: "):
                        continue
                    text = line[len(b"data: "):].decode()
                    if text == wire.SSE_DONE_SENTINEL:
                        return
                    yield json.loads(text)

    def abort(self) -> None:
        """Hard-drop the connection (simulates a vanished client)."""
        self._writer.close()

    async def close(self) -> None:
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionError, OSError):
            pass
