"""Async network front end over the continuous batcher.

Layering (top of the ``repro.serving`` stack):

    HttpFrontend   — hand-rolled HTTP/1.1 + SSE on asyncio streams:
                     POST /v1/completions, GET /healthz, GET /metrics,
                     429 + Retry-After admission, graceful drain
    EngineRouter   — N EngineLoops (one per device/mesh) behind one
                     front end; least-loaded-by-live-rows placement,
                     cross-engine admission fallback
    EngineLoop     — the dedicated decode thread that owns
                     ``ContinuousEngine`` and the only thread-safe
                     submit/cancel surface; enforces deadlines
    ServerRequest  — validated wire request (max_tokens, stream,
                     timeout_s, priority)
    client         — stdlib loopback client for tests and the load
                     harness (``benchmarks/bench_server.py``)

The split is deliberate: all device work and scheduler mutation happen
on one thread (no locks in the serving core), all network concurrency
lives in asyncio, and the two meet only through thread-safe queues —
see EXPERIMENTS.md for the decision record.
"""
from repro.server.http import HttpFrontend, run, serve
from repro.server.loop import EngineLoop, Ticket
from repro.server.router import EngineRouter
from repro.server.types import (AdmissionRejected, BadRequest,
                                ServerError, ServerRequest, finish_reason)

__all__ = [
    "HttpFrontend", "EngineLoop", "EngineRouter", "Ticket",
    "ServerRequest", "ServerError", "BadRequest", "AdmissionRejected",
    "finish_reason", "serve", "run",
]
