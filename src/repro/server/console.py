"""``GET /console`` — the fleet ops console, one self-contained page.

A single static HTML string with inline CSS/JS and zero external
dependencies (no CDN, no fonts, no framework): the page must render
from an air-gapped serving host, and the stdlib frontend has no static
file tree to serve. The JS polls ``/debug/timeline`` and
``/debug/vars`` on an interval and redraws inline-SVG sparklines —
fleet rollup first (tok/s, goodput, rps, busy fractions with the
prefill:N,decode:M sizing signal when pools exist), then one row per
engine, with SLO-breach markers and steal/handoff/compile event ticks
under each lane.

Served with ``Cache-Control: no-cache`` so a console left open across
a redeploy picks up the new page on refresh.
"""

CONSOLE_HTML = """<!doctype html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>repro fleet console</title>
<style>
  :root { --bg:#11151c; --panel:#1a2029; --ink:#d7dde6; --dim:#7b8494;
          --accent:#5cc8ff; --good:#7fd962; --warn:#ffb454; --bad:#f0616d;
          --grid:#242c38; }
  * { box-sizing: border-box; }
  body { margin:0; background:var(--bg); color:var(--ink);
         font:13px/1.45 ui-monospace,SFMono-Regular,Menlo,Consolas,monospace; }
  header { display:flex; gap:16px; align-items:baseline; padding:10px 16px;
           border-bottom:1px solid var(--grid); position:sticky; top:0;
           background:var(--bg); z-index:2; }
  header h1 { font-size:15px; margin:0; color:var(--accent); }
  header .meta { color:var(--dim); }
  header .err { color:var(--bad); }
  #panels { padding:12px 16px; display:flex; flex-direction:column; gap:12px; }
  .panel { background:var(--panel); border:1px solid var(--grid);
           border-radius:6px; padding:10px 12px; }
  .panel h2 { margin:0 0 6px; font-size:13px; font-weight:600; }
  .panel h2 .sub { color:var(--dim); font-weight:400; margin-left:8px; }
  .lanes { display:grid; grid-template-columns:repeat(auto-fill,minmax(230px,1fr));
           gap:8px 14px; }
  .lane .label { color:var(--dim); display:flex; justify-content:space-between; }
  .lane .label b { color:var(--ink); font-weight:600; }
  svg { display:block; width:100%; height:38px; }
  .spark { stroke:var(--accent); fill:none; stroke-width:1.4; }
  .fill  { fill:var(--accent); opacity:.12; stroke:none; }
  .evt   { stroke-width:2; }
  .breach { fill:var(--bad); opacity:.25; stroke:none; }
  .axis  { stroke:var(--grid); stroke-width:1; }
  .legend { color:var(--dim); margin-top:4px; }
  .legend i { display:inline-block; width:8px; height:8px; border-radius:2px;
              margin:0 4px 0 10px; vertical-align:baseline; }
  .pools td, .pools th { padding:2px 10px 2px 0; text-align:right; }
  .pools th { color:var(--dim); font-weight:400; }
  .pools td:first-child, .pools th:first-child { text-align:left; }
  .hint { color:var(--dim); margin-top:6px; }
</style>
</head>
<body>
<header>
  <h1>repro fleet console</h1>
  <span class="meta" id="status">connecting&hellip;</span>
  <span class="meta">window <select id="win">
    <option value="60">60s</option>
    <option value="120" selected>120s</option>
    <option value="300">300s</option>
    <option value="600">600s</option>
  </select> &middot; step <select id="step">
    <option value="1">1s</option>
    <option value="2">2s</option>
    <option value="5" selected>5s</option>
    <option value="15">15s</option>
  </select></span>
</header>
<div id="panels"></div>
<script>
"use strict";
const SERIES = [
  ["tok_s", "tok/s"], ["goodput_tok_s", "goodput tok/s"], ["rps", "req/s"],
  ["busy_frac", "busy frac"], ["prefill_busy_frac", "prefill busy frac"],
  ["decode_busy_frac", "decode busy frac"], ["cache_hit_tok_s", "cache-hit tok/s"],
  ["steal_s", "steals/s"], ["handoff_s", "handoffs/s"],
];
const GAUGES = [["queue_depth", "queue"], ["live_rows", "live rows"]];
const EVT_COLORS = { steals:"#ffb454", handoffs:"#5cc8ff",
                     compiles:"#c792ea", slo_breaches:"#f0616d" };
const esc = s => String(s).replace(/[&<>"]/g,
  c => ({"&":"&amp;","<":"&lt;",">":"&gt;",'"':"&quot;"}[c]));
const fmt = v => v == null ? "&ndash;"
  : Math.abs(v) >= 100 ? v.toFixed(0)
  : Math.abs(v) >= 1 ? v.toFixed(1) : v.toFixed(3);

function spark(vals, events, breachMask) {
  const W = 230, H = 38, PAD = 2, n = Math.max(vals.length, 2);
  const xs = i => PAD + i * (W - 2 * PAD) / (n - 1);
  const nums = vals.filter(v => v != null);
  const max = nums.length ? Math.max(...nums, 1e-9) : 1;
  const ys = v => H - 8 - (H - 14) * (v / max);
  let segs = [], seg = [];
  vals.forEach((v, i) => {
    if (v == null) { if (seg.length) segs.push(seg); seg = []; }
    else seg.push([xs(i), ys(v)]);
  });
  if (seg.length) segs.push(seg);
  let out = `<svg viewBox="0 0 ${W} ${H}" preserveAspectRatio="none">`;
  out += `<line class="axis" x1="0" y1="${H - 8}" x2="${W}" y2="${H - 8}"/>`;
  (breachMask || []).forEach((b, i) => {
    if (b) out += `<rect class="breach" x="${xs(i) - 2}" y="0" width="4"
      height="${H - 8}"/>`;
  });
  for (const s of segs) {
    if (s.length === 1) {
      out += `<circle cx="${s[0][0]}" cy="${s[0][1]}" r="1.6"
        fill="var(--accent)"/>`;
      continue;
    }
    const pts = s.map(p => p.map(x => x.toFixed(1)).join(",")).join(" ");
    out += `<polygon class="fill" points="${s[0][0].toFixed(1)},${H - 8}
      ${pts} ${s[s.length - 1][0].toFixed(1)},${H - 8}"/>`;
    out += `<polyline class="spark" points="${pts}"/>`;
  }
  for (const [name, counts] of Object.entries(events || {})) {
    const color = EVT_COLORS[name];
    if (!color) continue;
    (counts || []).forEach((c, i) => {
      if (c > 0) out += `<line class="evt" stroke="${color}"
        x1="${xs(i)}" y1="${H - 6}" x2="${xs(i)}" y2="${H - 1}"/>`;
    });
  }
  return out + "</svg>";
}

function last(arr) {
  if (!arr) return null;
  for (let i = arr.length - 1; i >= 0; i--)
    if (arr[i] != null) return arr[i];
  return null;
}

function lanesFor(doc, events, breach) {
  let html = '<div class="lanes">';
  for (const [key, label] of SERIES) {
    const vals = (doc.rates || {})[key];
    if (!vals) continue;
    html += `<div class="lane"><div class="label"><span>${esc(label)}</span>
      <b>${fmt(last(vals))}</b></div>${spark(vals, events, breach)}</div>`;
  }
  for (const [key, label] of GAUGES) {
    const vals = (doc.gauges || {})[key];
    if (!vals) continue;
    html += `<div class="lane"><div class="label"><span>${esc(label)}</span>
      <b>${fmt(last(vals))}</b></div>${spark(vals, null, null)}</div>`;
  }
  return html + "</div>";
}

function poolTable(pools) {
  const roles = Object.keys(pools || {});
  if (!roles.length) return "";
  let html = `<table class="pools"><tr><th>pool</th><th>engines</th>
    <th>busy frac</th><th>prefill frac</th><th>decode frac</th>
    <th>tok/s</th></tr>`;
  for (const r of roles) {
    const p = pools[r];
    html += `<tr><td>${esc(r)}</td><td>${p.engines}</td>
      <td>${fmt(last(p.busy_frac))}</td>
      <td>${fmt(last(p.prefill_busy_frac))}</td>
      <td>${fmt(last(p.decode_busy_frac))}</td>
      <td>${fmt(last(p.tok_s))}</td></tr>`;
  }
  html += "</table>";
  html += `<div class="hint">pool sizing: compare the prefill pool's
    <i>prefill frac</i> against the decode pool's <i>decode frac</i>
    (busy frac counts live decode rows, so a prefill-only pool reads 0
    there by construction) &mdash; prefill pinned near 1.0 while decode
    idles says shift an engine prefill-ward (docs/OBSERVABILITY.md).</div>`;
  return html;
}

function breachMask(doc, slo) {
  // mark buckets whose slo_breaches event count fired
  const ev = (doc.events || {}).slo_breaches || [];
  return ev.map(c => c > 0);
}

async function tick() {
  const win = document.getElementById("win").value;
  const step = document.getElementById("step").value;
  const status = document.getElementById("status");
  let doc;
  try {
    const r = await fetch(`/debug/timeline?window=${win}&step=${step}`,
                          { cache: "no-store" });
    if (!r.ok) throw new Error(`HTTP ${r.status}`);
    doc = await r.json();
  } catch (e) {
    status.textContent = `disconnected: ${e.message}`;
    status.className = "err";
    return;
  }
  status.className = "meta";
  status.textContent = `${doc.engines_reporting}/${doc.engines_total} engines`
    + ` reporting · ${new Date().toLocaleTimeString()}`;
  let html = "";
  if (doc.fleet) {
    const mask = breachMask(doc.fleet, doc.slo);
    html += `<div class="panel"><h2>fleet`
      + `<span class="sub">${doc.fleet.engines} engines</span></h2>`
      + lanesFor(doc.fleet, doc.fleet.events, mask)
      + poolTable(doc.fleet.pools)
      + `<div class="legend">events:`
      + Object.entries(EVT_COLORS).map(([k, c]) =>
          `<i style="background:${c}"></i>${k.replace("_", " ")}`).join("")
      + `</div></div>`;
  }
  for (const eng of doc.engines || []) {
    const mask = breachMask(eng, doc.slo);
    html += `<div class="panel"><h2>engine ${eng.engine}`
      + `<span class="sub">${esc(eng.role)} · ${eng.samples} samples`
      + ` · ${eng.dropped} dropped</span></h2>`
      + lanesFor(eng, eng.events, mask) + `</div>`;
  }
  if (!html) html = `<div class="panel">no recorders reporting yet
    &mdash; samples appear after the first interval.</div>`;
  document.getElementById("panels").innerHTML = html;
}

tick();
setInterval(tick, 2000);
document.getElementById("win").addEventListener("change", tick);
document.getElementById("step").addEventListener("change", tick);
</script>
</body>
</html>
"""
