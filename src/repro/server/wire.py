"""Minimal HTTP/1.1 + SSE wire handling on raw asyncio streams.

No dependency beyond the stdlib: the container policy forbids new
packages, and the subset of HTTP this server speaks (``Content-Length``
bodies in, fixed-length JSON or chunked SSE out) is small enough that
hand-rolling it is simpler than vendoring a framework.

Fixed-length responses honor HTTP/1.1 persistent connections
(``Connection: keep-alive``, the 1.1 default): per-request TCP setup
dominates small-prompt TTFB, so clients issuing many short completions
reuse one socket (``repro.server.client.ClientSession``). Streaming
(SSE) responses stay ``Connection: close`` — the client's only way to
abandon a stream mid-flight is dropping the connection, and that
disconnect-as-cancel signal must stay unambiguous.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Dict, Optional, Union
from urllib.parse import unquote

from repro.server.types import BadRequest

REASONS = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 408: "Request Timeout",
    413: "Payload Too Large", 429: "Too Many Requests",
    500: "Internal Server Error", 503: "Service Unavailable",
}

MAX_HEADER_BYTES = 16384
MAX_BODY_BYTES = 1 << 20


@dataclasses.dataclass
class HttpRequest:
    method: str
    path: str
    headers: Dict[str, str]            # keys lower-cased
    body: bytes
    version: str = "HTTP/1.1"
    query: str = ""                    # raw query string, no leading '?'

    def params(self) -> Dict[str, str]:
        """Query parameters (last value wins; bare keys map to '')."""
        out: Dict[str, str] = {}
        for part in self.query.split("&"):
            if not part:
                continue
            key, _, val = part.partition("=")
            out[unquote(key)] = unquote(val)
        return out

    @property
    def keep_alive(self) -> bool:
        """Persistent-connection semantics: 1.1 defaults to keep-alive
        unless the client says close; 1.0 requires an explicit opt-in."""
        conn = self.headers.get("connection", "").lower()
        if self.version == "HTTP/1.0":
            return "keep-alive" in conn
        return "close" not in conn


async def read_request(reader: asyncio.StreamReader) \
        -> Optional[HttpRequest]:
    """Parse one request. Returns ``None`` on a cleanly closed
    connection before any bytes; raises ``BadRequest`` on malformed
    input (the caller maps it to a 4xx response)."""
    try:
        line = await reader.readline()
    except (ConnectionError, asyncio.IncompleteReadError):
        return None
    if not line:
        return None
    parts = line.decode("latin1").strip().split()
    if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
        raise BadRequest("malformed request line")
    method, path, version = parts
    headers: Dict[str, str] = {}
    total = len(line)
    while True:
        h = await reader.readline()
        total += len(h)
        if total > MAX_HEADER_BYTES:
            raise BadRequest("headers too large")
        if h in (b"\r\n", b"\n", b""):
            break
        key, sep, val = h.decode("latin1").partition(":")
        if not sep:
            raise BadRequest("malformed header line")
        headers[key.strip().lower()] = val.strip()
    body = b""
    if "content-length" in headers:
        try:
            n = int(headers["content-length"])
        except ValueError:
            raise BadRequest("bad Content-Length")
        if n > MAX_BODY_BYTES:
            raise BadRequest("body too large")
        if n:
            try:
                body = await reader.readexactly(n)
            except asyncio.IncompleteReadError:
                return None
    elif headers.get("transfer-encoding"):
        raise BadRequest("chunked request bodies are not supported")
    path, _, query = path.partition("?")
    return HttpRequest(method, path, headers, body, version, query=query)


def response(status: int, body: Union[bytes, dict, str] = b"",
             content_type: str = "application/json",
             extra_headers: Dict[str, str] = None,
             keep_alive: bool = False) -> bytes:
    """Fixed-length response, ready to write. ``keep_alive`` leaves the
    connection open for the client's next request (the Content-Length
    framing makes that safe); default remains close."""
    if isinstance(body, dict):
        body = (json.dumps(body) + "\n").encode()
    elif isinstance(body, str):
        body = body.encode()
    head = [f"HTTP/1.1 {status} {REASONS.get(status, 'Unknown')}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(body)}",
            f"Connection: {'keep-alive' if keep_alive else 'close'}"]
    for k, v in (extra_headers or {}).items():
        head.append(f"{k}: {v}")
    return ("\r\n".join(head) + "\r\n\r\n").encode("latin1") + body


def error_response(status: int, message: str,
                   extra_headers: Dict[str, str] = None,
                   keep_alive: bool = False) -> bytes:
    return response(status, {"error": message},
                    extra_headers=extra_headers, keep_alive=keep_alive)


def sse_header(extra_headers: Dict[str, str] = None) -> bytes:
    """Chunked SSE response head (``Connection: close`` — see module
    docstring) with optional extra headers (``X-Repro-Trace-Id``)."""
    head = ["HTTP/1.1 200 OK",
            "Content-Type: text/event-stream",
            "Cache-Control: no-cache",
            "Connection: close",
            "Transfer-Encoding: chunked"]
    for k, v in (extra_headers or {}).items():
        head.append(f"{k}: {v}")
    return ("\r\n".join(head) + "\r\n\r\n").encode("latin1")


SSE_HEADER = sse_header()

SSE_DONE_SENTINEL = "[DONE]"


def chunked(data: bytes) -> bytes:
    """One HTTP/1.1 chunked-transfer frame."""
    return f"{len(data):x}\r\n".encode("latin1") + data + b"\r\n"


CHUNKED_EOF = b"0\r\n\r\n"


def sse_event(payload: Union[dict, str]) -> bytes:
    """One SSE ``data:`` event, already wrapped in a chunked frame."""
    data = payload if isinstance(payload, str) else json.dumps(payload)
    return chunked(f"data: {data}\n\n".encode())


async def read_chunked(reader: asyncio.StreamReader):
    """Async generator over the data of a chunked response body
    (client side; used by the loopback client and the load harness)."""
    while True:
        size_line = await reader.readline()
        if not size_line:
            return
        n = int(size_line.strip() or b"0", 16)
        if n == 0:
            await reader.readline()      # trailing CRLF
            return
        data = await reader.readexactly(n)
        await reader.readexactly(2)      # chunk CRLF
        yield data
