"""Asyncio HTTP/1.1 front end over ``EngineLoop``.

Routes:
    POST /v1/completions   JSON body (see ``ServerRequest.from_json``);
                           ``stream:false`` → one JSON object,
                           ``stream:true``  → SSE ``data:`` events at
                           block boundaries, then a final summary event
                           and a ``[DONE]`` sentinel.
    GET  /healthz          liveness + drain state + queue depth.
    GET  /metrics          Prometheus text format from ``ServeMetrics``.
    GET  /debug/vars       live per-engine state as JSON: queue depths,
                           in-flight gangs, steal/compile/audit counters
                           (operator inspection without scraping the
                           Prometheus text).
    GET  /debug/flight     trigger a flight-recorder dump (trace ring
                           buffers + metrics + scheduler state); 503
                           when no ``--flight-dir`` is configured.
    GET  /debug/timeline   windowed time-series JSON per engine plus
                           fleet/pool aggregates (repro.obs.series);
                           ``?window=`` seconds of history at
                           ``?step=``-second buckets.
    GET  /console          self-contained fleet ops console (static
                           HTML, zero external deps) polling
                           /debug/timeline for live sparklines.

Request lifecycle guarantees:
* admission is bounded — a full queue answers ``429`` with
  ``Retry-After`` instead of building unbounded backlog;
* a streaming client that disconnects (EOF on its socket, or a failed
  write) cancels its request: the decode slot is freed at the next
  block boundary and concurrent requests are untouched (non-streaming
  requests run to completion — EOF after a full request is a legal
  half-close, not proof the client is gone);
* ``timeout_s`` deadlines return the partial completion with
  ``finish_reason="deadline"``;
* shutdown drains: the listener closes first, in-flight requests run
  to completion (bounded by ``timeout_s``), then the decode thread
  stops.
"""
from __future__ import annotations

import asyncio
import json
import math
import time
from typing import Optional

from repro.obs.log import get_logger
from repro.obs.metrics import Histogram, device_memory_stats
from repro.server import wire
from repro.server.loop import EngineLoop, Ticket
from repro.server.types import (AdmissionRejected, BadRequest,
                                ServerRequest, finish_reason)

log = get_logger(__name__)


class HttpFrontend:
    """Accepts either a single ``EngineLoop`` or an ``EngineRouter``
    over several (one per device/mesh) — the router exposes the same
    submit/cancel surface, so all routes below are engine-count
    agnostic; only /healthz and /metrics fan in across engines."""

    def __init__(self, engine_loop, host: str = "127.0.0.1",
                 port: int = 8000, request_timeout_s: float = 10.0,
                 tracer=None, flight=None, watchdog=None):
        self.loop = engine_loop                       # loop OR router
        self.engines = getattr(engine_loop, "engines",
                               None) or [engine_loop.engine]
        self.engine = self.engines[0]                 # 1-engine alias
        self.host = host
        self.port = port
        self.request_timeout_s = request_timeout_s   # header-read budget
        self.tracer = tracer
        # quality auditing (repro.obs.audit): the FlightRecorder backs
        # GET /debug/flight; the SLOWatchdog feeds repro_slo_* metrics
        # (both usually wired by _front / launch.serve)
        self.flight = flight
        self.watchdog = watchdog
        self._server: Optional[asyncio.AbstractServer] = None
        self._conns: set = set()
        self._draining = False

    # ------------------------------------------------------ lifecycle

    async def start(self) -> "HttpFrontend":
        self._server = await asyncio.start_server(
            self._client, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        if self.tracer is not None:
            self.tracer.name_thread("asyncio")       # pid 0 = front end
        if not self.loop.running:
            self.loop.start()
        return self

    async def serve_forever(self) -> None:
        async with self._server:
            await self._server.serve_forever()

    async def shutdown(self, drain: bool = True,
                       timeout_s: float = 30.0) -> None:
        """Graceful drain: stop accepting, let in-flight requests
        finish, then stop the decode thread. ``drain=False`` cancels
        everything instead."""
        self._draining = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if drain:
            deadline = asyncio.get_running_loop().time() + timeout_s
            while (self.loop.inflight or self._conns) \
                    and asyncio.get_running_loop().time() < deadline:
                await asyncio.sleep(0.02)
        await asyncio.to_thread(self.loop.close, drain, timeout_s)
        for task in list(self._conns):
            task.cancel()

    # ------------------------------------------------------ connection

    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        """Serve requests off one socket until the client (or a
        streaming response, or drain) ends the connection — HTTP/1.1
        keep-alive, so small-prompt clients don't pay TCP setup per
        request. An idle keep-alive socket that times out waiting for
        the *next* request is closed silently (only the first request
        earns a 408: before it, timing out means a slow client, not an
        idle one)."""
        task = asyncio.current_task()
        self._conns.add(task)
        first = True
        try:
            while True:
                try:
                    req = await asyncio.wait_for(
                        wire.read_request(reader),
                        timeout=self.request_timeout_s)
                except asyncio.TimeoutError:
                    if first:
                        writer.write(wire.error_response(
                            408, "request timeout"))
                    return
                except BadRequest as e:
                    writer.write(wire.error_response(400, e.message))
                    return
                if req is None:
                    return
                # drain closes after the in-flight response; a fresh
                # accept during drain still gets its 503 below
                keep = req.keep_alive and not self._draining
                if not await self._route(req, reader, writer, keep):
                    return
                first = False
        except (ConnectionResetError, BrokenPipeError):
            pass
        except asyncio.CancelledError:
            raise
        except Exception:
            log.exception("connection handler failed")
            try:
                writer.write(wire.error_response(500, "internal error"))
            except Exception:
                pass
        finally:
            self._conns.discard(task)
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _route(self, req: wire.HttpRequest, reader, writer,
                     keep: bool) -> bool:
        """Handle one request; returns whether the connection survives
        (False after a streaming response, whose end-of-body is the
        connection close itself)."""
        if req.path == "/healthz":
            if req.method != "GET":
                writer.write(wire.error_response(405, "use GET",
                                                 keep_alive=keep))
            else:
                writer.write(wire.response(200, self._health(),
                                           keep_alive=keep))
        elif req.path == "/metrics":
            if req.method != "GET":
                writer.write(wire.error_response(405, "use GET",
                                                 keep_alive=keep))
            else:
                writer.write(wire.response(
                    200, self._metrics_text(),
                    content_type="text/plain; version=0.0.4",
                    keep_alive=keep))
        elif req.path == "/debug/vars":
            if req.method != "GET":
                writer.write(wire.error_response(405, "use GET",
                                                 keep_alive=keep))
            else:
                writer.write(wire.response(200, self._debug_vars(),
                                           keep_alive=keep))
        elif req.path == "/debug/flight":
            if req.method != "GET":
                writer.write(wire.error_response(405, "use GET",
                                                 keep_alive=keep))
            elif self.flight is None:
                writer.write(wire.error_response(
                    503, "no flight recorder (start with --flight-dir)",
                    keep_alive=keep))
            else:
                path = await asyncio.to_thread(
                    self.flight.dump, "manual", True)
                writer.write(wire.response(
                    200, {"path": path, "dumps": self.flight.dumps,
                          "suppressed": self.flight.suppressed},
                    keep_alive=keep))
        elif req.path == "/debug/timeline":
            if req.method != "GET":
                writer.write(wire.error_response(405, "use GET",
                                                 keep_alive=keep))
            else:
                writer.write(wire.response(200, self._timeline(req),
                                           keep_alive=keep))
        elif req.path == "/console":
            if req.method != "GET":
                writer.write(wire.error_response(405, "use GET",
                                                 keep_alive=keep))
            else:
                from repro.server.console import CONSOLE_HTML
                writer.write(wire.response(
                    200, CONSOLE_HTML,
                    content_type="text/html; charset=utf-8",
                    extra_headers={"Cache-Control": "no-cache"},
                    keep_alive=keep))
        elif req.path == "/v1/completions":
            if req.method != "POST":
                writer.write(wire.error_response(405, "use POST",
                                                 keep_alive=keep))
            else:
                keep = await self._completions(req, reader, writer, keep)
        else:
            writer.write(wire.error_response(404, f"no route {req.path}",
                                             keep_alive=keep))
        await writer.drain()
        return keep

    # ------------------------------------------------------ completions

    async def _completions(self, req: wire.HttpRequest,
                           reader, writer, keep: bool) -> bool:
        """Returns whether the connection can serve another request."""
        accept_ns = time.perf_counter_ns()
        if self._draining:
            writer.write(wire.error_response(
                503, "server is draining", {"Retry-After": "5"}))
            return False
        try:
            body = json.loads(req.body.decode("utf-8") or "{}")
        except (json.JSONDecodeError, UnicodeDecodeError):
            writer.write(wire.error_response(400, "body is not valid JSON",
                                             keep_alive=keep))
            return keep
        try:
            sreq = ServerRequest.from_json(body)
        except BadRequest as e:
            writer.write(wire.error_response(400, e.message,
                                             keep_alive=keep))
            return keep
        aioloop = asyncio.get_running_loop()
        events: asyncio.Queue = asyncio.Queue()

        def deliver(event):           # called from the decode thread
            aioloop.call_soon_threadsafe(events.put_nowait, event)

        try:
            ticket = self.loop.submit(sreq, deliver)
        except AdmissionRejected as e:
            writer.write(wire.error_response(
                429, e.message,
                {"Retry-After": str(int(math.ceil(e.retry_after_s)))},
                keep_alive=keep))
            return keep
        if self.tracer is not None and ticket.trace_id:
            # outermost span of the request tree: socket accept ->
            # final response byte written (or disconnect drain)
            ticket.accept_ns = accept_ns
            self.tracer.async_begin(ticket.trace_id, "http",
                                    t_ns=accept_ns, path=req.path,
                                    stream=sreq.stream)
        if sreq.stream:
            await self._stream_response(ticket, events, reader, writer)
            return False       # chunked SSE ends with the connection
        await self._json_response(ticket, events, writer, keep)
        return keep

    async def _wait_disconnect(self, reader) -> None:
        """Resolves on EOF from the client. Only *streaming* responses
        treat this as a disconnect-cancel signal: mid-SSE, the client's
        sole way to give up is dropping the connection, and freeing the
        decode slot at the next block boundary is the whole point. A
        non-streaming client may legally half-close after sending its
        full request (shutdown(SHUT_WR)) while still reading — EOF
        there does NOT mean gone, so JSON responses always run to
        completion and are written regardless (a dead peer just makes
        the write fail, which the connection handler swallows)."""
        while True:
            try:
                data = await reader.read(4096)
            except (ConnectionError, asyncio.IncompleteReadError):
                return
            if not data:
                return

    def _end_http(self, ticket: Ticket, **args) -> None:
        """Close the request's outermost ("http") span."""
        if self.tracer is not None and ticket.trace_id:
            self.tracer.async_end(ticket.trace_id, "http", **args)

    def _end_http_on_done(self, ticket: Ticket,
                          events: asyncio.Queue, **args) -> None:
        """Disconnect path: the client is gone but the engine keeps the
        row until the next block boundary — the "request" span closes
        then, from the decode thread. Park a task on the (now
        client-less) event queue so "http" closes strictly after it and
        the span tree stays well-formed."""
        if self.tracer is None or not ticket.trace_id:
            return

        async def _wait():
            try:
                await self._await_done(events)
            finally:
                self._end_http(ticket, **args)

        task = asyncio.get_running_loop().create_task(_wait())
        self._conns.add(task)            # shutdown() waits for these
        task.add_done_callback(self._conns.discard)

    async def _json_response(self, ticket: Ticket, events,
                             writer, keep: bool = False) -> None:
        comp = await self._await_done(events)
        headers = {"X-Repro-Trace-Id": ticket.trace_id} \
            if ticket.trace_id else None
        try:
            writer.write(wire.response(
                200, self._completion_json(comp, ticket),
                extra_headers=headers, keep_alive=keep))
            await writer.drain()
        finally:
            # the completion is in hand, so "request" already closed —
            # end "http" even when the final write finds the peer gone
            self._end_http(ticket, status=200)

    @staticmethod
    async def _await_done(events: asyncio.Queue):
        while True:
            kind, payload = await events.get()
            if kind == "done":
                return payload

    async def _stream_response(self, ticket: Ticket, events, reader,
                               writer) -> None:
        writer.write(wire.sse_header(
            {"X-Repro-Trace-Id": ticket.trace_id}
            if ticket.trace_id else None))
        disconnect = asyncio.create_task(self._wait_disconnect(reader))
        nxt = None
        try:
            await writer.drain()
            while True:
                nxt = asyncio.create_task(events.get())
                done, _ = await asyncio.wait(
                    {disconnect, nxt},
                    return_when=asyncio.FIRST_COMPLETED)
                if nxt not in done:
                    self.loop.cancel(ticket, "disconnect")
                    self._end_http_on_done(ticket, events,
                                           disconnect=True)
                    return
                kind, payload = nxt.result()
                if kind == "chunk":
                    writer.write(wire.sse_event({
                        "uid": payload.uid, "block": payload.block_idx,
                        "text": payload.text,
                        "finished": payload.finished}))
                else:                        # ("done", Completion)
                    writer.write(wire.sse_event(
                        self._completion_json(payload, ticket)))
                    writer.write(wire.sse_event(wire.SSE_DONE_SENTINEL))
                    writer.write(wire.CHUNKED_EOF)
                    await writer.drain()
                    self._end_http(ticket, status=200)
                    return
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            self.loop.cancel(ticket, "disconnect")
            self._end_http_on_done(ticket, events, disconnect=True)
        finally:
            disconnect.cancel()
            if nxt is not None:
                nxt.cancel()

    def _completion_json(self, comp, ticket: Ticket) -> dict:
        doc = {
            "uid": comp.uid, "text": comp.text,
            "n_tokens": comp.n_tokens, "n_blocks": comp.n_blocks,
            "max_tokens": comp.max_tokens,
            "finish_reason": finish_reason(comp, ticket.cancel_reason),
            "cancelled": comp.cancelled,
            "latency_s": comp.latency_s, "ttfb_s": comp.ttfb_s,
            "queue_s": comp.queue_s, "nfe": comp.nfe,
            "cache_hit_tokens": comp.cache_hit_tokens,
        }
        if ticket.trace_id:
            doc["trace_id"] = ticket.trace_id
        if ticket.req.trace and self.tracer is not None \
                and ticket.trace_id:
            # opt-in span echo: everything recorded for this request so
            # far (the "http" span itself closes after this response is
            # written, so it is absent by construction)
            doc["trace"] = {
                "trace_id": ticket.trace_id,
                "events": self.tracer.request_events(ticket.trace_id)}
        return doc

    # ------------------------------------------------------ health/metrics

    def _health(self) -> dict:
        scheds = [e.scheduler for e in self.engines]
        return {"status": "draining" if self._draining else "ok",
                "engines": len(self.engines),
                "inflight": self.loop.inflight,
                "queue_depth": sum(e.metrics.queue_depth
                                   for e in self.engines),
                "live_rows": sum(s.live_rows for s in scheds),
                "idle": all(s.idle for s in scheds)}

    def _debug_vars(self) -> dict:
        """Live engine state for operators: one row per EngineLoop
        (queue depths, in-flight gangs, steal/compile/audit counters).
        Cross-thread reads — one tick stale at worst, never torn."""
        loops = getattr(self.loop, "loops", None) or [self.loop]
        doc = {"status": "draining" if self._draining else "ok",
               "engines": [lp.debug_vars() for lp in loops]}
        if self.watchdog is not None:
            doc["slo"] = self.watchdog.current()
        if self.flight is not None:
            doc["flight"] = {"dumps": self.flight.dumps,
                             "suppressed": self.flight.suppressed,
                             "dir": self.flight.flight_dir}
        if self.tracer is not None:
            doc["trace_drops"] = self.tracer.dropped
        return doc

    def _timeline(self, req: wire.HttpRequest = None) -> dict:
        """Windowed rate series per engine + fleet/pool aggregates
        (repro.obs.series). The recorder rings are written by the
        decode threads and snapshotted here without a lock — same
        GIL-atomic deque contract as the tracer."""
        from repro.obs.series import timeline_doc
        params = req.params() if req is not None else {}

        def num(key, default, lo, hi):
            try:
                return min(max(float(params.get(key, default)), lo), hi)
            except (TypeError, ValueError):
                return default

        window = num("window", 120.0, 1.0, 3600.0)
        step = num("step", 5.0, 0.1, window)
        loops = getattr(self.loop, "loops", None) or [self.loop]
        return timeline_doc(loops, window_s=window, step_s=step,
                            watchdog=self.watchdog)

    def _metrics_text(self) -> str:
        """Prometheus text. Top-level series aggregate over every
        engine (sums; occupancy wall-time-weighted; quantiles pooled
        over the raw per-request records, since percentiles don't
        average). Per-engine breakdowns live under a separate
        ``repro_engine_*`` family with an ``engine`` label — same
        family as the aggregate would double-count on scrape."""
        from repro.serving.metrics import percentile
        snaps = [e.metrics.snapshot() for e in self.engines]

        def tot(key):
            return sum(s[key] for s in snaps)

        wall = max(sum(s["wall_time_s"] for s in snaps), 1e-9)
        occ = sum(s["mean_occupancy"] * s["wall_time_s"]
                  for s in snaps) / wall
        # engines decode concurrently: fleet tok/s is the sum of each
        # engine's tokens over its own scheduler wall time
        tput = sum(s["throughput_tok_s"] for s in snaps)
        out = []

        def emit(name, value, mtype, help_text):
            out.append(f"# HELP {name} {help_text}")
            out.append(f"# TYPE {name} {mtype}")
            out.append(f"{name} {value}")

        emit("repro_requests_total", tot("requests"), "counter",
             "Completed requests (including cancelled).")
        emit("repro_tokens_total", tot("tokens"), "counter",
             "Generated tokens across completed requests.")
        emit("repro_nfe_total", tot("total_nfe"), "counter",
             "Model forward evaluations.")
        emit("repro_admission_rejects_total", tot("admission_rejects"),
             "counter", "Requests rejected with 429 (queue full).")
        emit("repro_cancelled_total", tot("cancelled"), "counter",
             "Requests cancelled (explicit, disconnect, or deadline).")
        emit("repro_deadline_misses_total", tot("deadline_misses"),
             "counter", "Cancelled requests whose cause was timeout_s.")
        emit("repro_gang_merges_total", tot("gang_merges"), "counter",
             "Cross-gang straggler merges at block boundaries.")
        emit("repro_prefix_cache_hits_total", tot("prefix_cache_hits"),
             "counter", "Requests whose prefill reused cached prompt KV.")
        emit("repro_prefix_cache_hit_tokens_total",
             tot("prefix_cache_hit_tokens"), "counter",
             "Prompt tokens served from the cross-request prefix cache.")
        emit("repro_prefix_cache_evictions_total",
             tot("prefix_cache_evictions"), "counter",
             "Prefix-cache chunks evicted (LRU under the byte budget).")
        emit("repro_prefix_cache_bytes", tot("prefix_cache_bytes"),
             "gauge", "Resident prefix-cache chunk KV bytes.")
        emit("repro_prefix_cache_chunks", tot("prefix_cache_nodes"),
             "gauge", "Resident prefix-cache chunks (radix-tree nodes).")
        emit("repro_queue_depth", tot("queue_depth"), "gauge",
             "Requests queued (front end + scheduler), not in a slot.")
        emit("repro_inflight", self.loop.inflight, "gauge",
             "Requests admitted and not yet finished.")
        emit("repro_engines", len(self.engines), "gauge",
             "Engine loops behind this front end.")
        emit("repro_mean_occupancy", f"{occ:.6f}",
             "gauge", "Mean decode-slot occupancy (wall-time weighted).")
        emit("repro_throughput_tok_per_s", f"{tput:.6f}", "gauge",
             "Generated tokens per second of scheduler wall time.")
        # compile ledger (repro.obs.CompileWatch) + host budget
        emit("repro_compile_misses_total", tot("compile_misses"),
             "counter", "New jit variants compiled across engines.")
        emit("repro_compile_hits_total", tot("compile_hits"), "counter",
             "Jit-dispatching calls fully served by compiled variants.")
        emit("repro_compile_seconds_total",
             f"{tot('compile_seconds'):.6f}", "counter",
             "Wall seconds attributed to variant-building calls.")
        emit("repro_post_warm_compiles_total", tot("post_warm_compiles"),
             "counter", "Variants compiled after pre-warm declared the "
             "engine warm (should stay 0).")
        emit("repro_prewarmed_engines", tot("prewarmed"), "gauge",
             "Engines whose startup pre-warm completed.")
        emit("repro_host_threads_per_engine",
             snaps[0]["host_threads"], "gauge",
             "Budgeted XLA:CPU intra-op threads per engine (0 = "
             "unbudgeted).")
        emit("repro_steals_total", tot("steals_in"), "counter",
             "Requests migrated between engines by block-boundary work "
             "stealing.")
        # engine busy time split by phase: prefill passes (prompt KV
        # priming, cached-chunk replays) vs decode_block walls — the
        # two never overlap on one engine, so the split partitions the
        # decode thread's model time and makes pool sizing visible
        emit("repro_prefill_busy_seconds_total",
             f"{tot('prefill_busy_s'):.6f}", "counter",
             "Wall seconds spent in prefill passes across engines.")
        emit("repro_decode_busy_seconds_total",
             f"{tot('decode_busy_s'):.6f}", "counter",
             "Wall seconds spent in decode_block calls across engines.")
        emit("repro_handoffs_total", tot("handoffs_in"), "counter",
             "Requests handed off prefill pool -> decode pool through "
             "the shared radix store.")
        loops = getattr(self.loop, "loops", None) or [self.loop]
        roles = {}
        for lp in loops:
            role = getattr(lp, "role", "both")
            roles[role] = roles.get(role, 0) + 1
        out.append("# HELP repro_pool_engines Engine loops per pool "
                   "role (prefill-only vs decode-capable).")
        out.append("# TYPE repro_pool_engines gauge")
        for role, n in sorted(roles.items()):
            out.append(f'repro_pool_engines{{role="{role}"}} {n}')
        from repro.obs.compile import persistent_cache_counters
        pc = persistent_cache_counters()
        emit("repro_persistent_cache_hits_total", pc["hits"], "counter",
             "Jax persistent compilation cache hits (process-wide).")
        emit("repro_persistent_cache_misses_total", pc["misses"],
             "counter", "Jax persistent compilation cache misses "
             "(process-wide).")
        for metric, key in (("repro_latency_seconds", "latency"),
                            ("repro_ttfb_quantile_seconds", "ttfb")):
            vals = [getattr(r, f"{key}_s")
                    for e in self.engines for r in e.metrics.requests]
            out.append(f"# HELP {metric} Request {key} quantiles "
                       "(pooled across engines).")
            out.append(f"# TYPE {metric} summary")
            for q, pct in (("0.5", 50), ("0.99", 99)):
                out.append(f'{metric}{{quantile="{q}"}} '
                           f"{percentile(vals, pct):.6f}")
        # bucketed histograms (TTFB, queue wait, block wall, NFE/token):
        # one engine emits the bare family; a fleet emits one labeled
        # series per engine — PromQL sums across labels by ``le``, and
        # a pre-pooled unlabeled duplicate would double-count on scrape
        if len(self.engines) == 1:
            for h in self.engines[0].metrics.histograms:
                out.extend(h.prometheus())
        else:
            per_engine = zip(*(e.metrics.histograms
                               for e in self.engines))
            for series in per_engine:
                for i, h in enumerate(series):
                    lines = h.prometheus(f'engine="{i}"')
                    # HELP/TYPE once per family, not once per engine
                    out.extend(lines if i == 0 else lines[2:])
        # per-block decode dynamics (repro.obs.telemetry) rollup
        tel = [e.telemetry.totals() for e in self.engines]

        def ttel(key):
            return sum(t[key] for t in tel)

        steps, caps = ttel("steps"), ttel("steps_cap")
        emit("repro_decode_blocks_total", ttel("blocks"), "counter",
             "decode_block calls across engines.")
        emit("repro_decode_steps_total", steps, "counter",
             "Device diffusion steps actually run.")
        emit("repro_decode_steps_cap_total", caps, "counter",
             "Tau-schedule maximum steps for the same blocks.")
        emit("repro_decode_steps_saved_ratio",
             f"{1.0 - steps / caps if caps else 0.0:.6f}", "gauge",
             "Fraction of scheduled steps skipped by early exit.")
        emit("repro_decode_straggler_fill_total", ttel("straggler_fill"),
             "counter", "Tokens force-committed at schedule end.")
        emit("repro_decode_early_exits_total", ttel("early_exits"),
             "counter", "Rows that hit the early-exit test.")
        conf = [0] * len(tel[0]["conf_hist"]) if tel else []
        for t in tel:
            for i, c in enumerate(t["conf_hist"]):
                conf[i] += c
        out.append("# HELP repro_decode_confidence_total Committed-token"
                   " confidence histogram (equal buckets over [0,1]).")
        out.append("# TYPE repro_decode_confidence_total counter")
        for i, c in enumerate(conf):
            lo, hi = i / len(conf), (i + 1) / len(conf)
            out.append(f'repro_decode_confidence_total'
                       f'{{bucket="{lo:.1f}-{hi:.1f}"}} {c}')
        # accelerator memory (absent on CPU backends)
        mem = device_memory_stats()
        for key in ("bytes_in_use", "peak_bytes_in_use", "bytes_limit"):
            rows = [(dev, st[key]) for dev, st in sorted(mem.items())
                    if key in st]
            if rows:
                out.append(f"# HELP repro_device_{key} Device memory "
                           f"({key}) as reported by the runtime.")
                out.append(f"# TYPE repro_device_{key} gauge")
                for dev, v in rows:
                    out.append(f'repro_device_{key}{{device="{dev}"}} '
                               f"{int(v)}")
        if self.tracer is not None:
            emit("repro_trace_drops_total", self.tracer.dropped,
                 "counter", "Trace events evicted from full rings.")
        # shadow-audit counters (repro.obs.audit) — emitted whenever any
        # engine carries an auditor
        auditors = [e.auditor for e in self.engines
                    if getattr(e, "auditor", None) is not None]
        if auditors:
            stats = [a.stats() for a in auditors]

            def atot(key):
                return sum(s[key] for s in stats)

            emit("repro_audit_sampled_total", atot("sampled"), "counter",
                 "Completions sampled for shadow re-decode.")
            emit("repro_audit_completed_total", atot("completed"),
                 "counter", "Shadow audits finished (all lanes).")
            emit("repro_audit_dropped_total", atot("dropped"), "counter",
                 "Audit jobs dropped at the bounded backlog.")
            emit("repro_audit_errors_total", atot("errors"), "counter",
                 "Audit attempts that failed internally (logged and "
                 "dropped).")
            emit("repro_audit_backlog", atot("backlog"), "gauge",
                 "Audit jobs queued or in flight.")
            emit("repro_audit_regret_total", atot("regret"), "counter",
                 "Early-exited requests whose shadow audit diverged "
                 "(the EOS that truncated the schedule was wrong).")
            out.append("# HELP repro_audit_divergences_total Token "
                       "divergences found by shadow audits, by source "
                       "(dkv-structural is the documented non-batch-"
                       "invariant contract, not a defect).")
            out.append("# TYPE repro_audit_divergences_total counter")
            from repro.obs.audit import SOURCES
            for src in SOURCES:
                n = sum(s["divergences"].get(src, 0) for s in stats)
                out.append("repro_audit_divergences_total"
                           f'{{source="{src}"}} {n}')
            n_b = len(stats[0]["conf_agree"])
            for name, key, help_text in (
                    ("repro_audit_conf_agree_total", "conf_agree",
                     "Audited tokens agreeing with the oracle, by "
                     "commit-confidence bucket (Eq. 4 calibration)."),
                    ("repro_audit_conf_tokens_total", "conf_total",
                     "Audited tokens by commit-confidence bucket.")):
                out.append(f"# HELP {name} {help_text}")
                out.append(f"# TYPE {name} counter")
                for i in range(n_b):
                    lo, hi = i / n_b, (i + 1) / n_b
                    n = sum(s[key][i] for s in stats)
                    out.append(f'{name}{{bucket="{lo:.1f}-{hi:.1f}"}} {n}')
        # SLO watchdog gauges/counters (repro_slo_*)
        if self.watchdog is not None and self.watchdog.enabled:
            slo = self.watchdog.current()
            for fam, rows, mtype, help_text in (
                    ("repro_slo_target", slo["targets"], "gauge",
                     "Configured SLO target per objective."),
                    ("repro_slo_value", slo["values"], "gauge",
                     "Rolling-window observed value per objective."),
                    ("repro_slo_breached", slo["breached"], "gauge",
                     "1 while the objective is currently out of SLO."),
                    ("repro_slo_breaches_total", slo["breaches_total"],
                     "counter", "Breach onsets per objective.")):
                if not rows:
                    continue
                out.append(f"# HELP {fam} {help_text}")
                out.append(f"# TYPE {fam} {mtype}")
                for obj, v in sorted(rows.items()):
                    out.append(f'{fam}{{objective="{obj}"}} {v}')
        if self.flight is not None:
            emit("repro_flight_dumps_total", self.flight.dumps, "counter",
                 "Flight-recorder dumps written.")
            emit("repro_flight_suppressed_total", self.flight.suppressed,
                 "counter", "Flight dumps suppressed by debounce/cap.")
        # time-series recorder (repro.obs.series) — emitted whenever any
        # loop carries a MetricsRecorder
        loops_all = getattr(self.loop, "loops", None) or [self.loop]
        recorders = [lp.recorder for lp in loops_all
                     if getattr(lp, "recorder", None) is not None]
        if recorders:
            rstats = [r.stats() for r in recorders]
            emit("repro_series_samples_total",
                 sum(s["samples"] for s in rstats), "counter",
                 "Time-series samples taken across engine recorders.")
            emit("repro_series_dropped_total",
                 sum(s["dropped"] for s in rstats), "counter",
                 "Samples evicted from full recorder rings.")
            emit("repro_series_errors_total",
                 sum(s["errors"] for s in rstats), "counter",
                 "Recorder samples that failed internally (logged and "
                 "dropped).")
            emit("repro_series_ring_bytes",
                 sum(s["ring_bytes"] for s in rstats), "gauge",
                 "Estimated bytes resident in recorder rings.")
            emit("repro_series_log_lines_total",
                 max(s["log_lines"] for s in rstats), "counter",
                 "JSONL lines written to --metrics-log (shared sink).")
        if len(self.engines) > 1:
            for name, key, mtype, help_text, fmt in (
                    ("requests_total", "requests", "counter",
                     "Completed requests per engine.", "{}"),
                    ("tokens_total", "tokens", "counter",
                     "Generated tokens per engine.", "{}"),
                    ("gang_merges_total", "gang_merges", "counter",
                     "Cross-gang merges per engine.", "{}"),
                    ("cache_hits_total", "prefix_cache_hits", "counter",
                     "Prefix-cache request hits per engine.", "{}"),
                    ("cache_hit_tokens_total", "prefix_cache_hit_tokens",
                     "counter", "Prefix-cache tokens reused per engine.",
                     "{}"),
                    ("cache_evictions_total", "prefix_cache_evictions",
                     "counter", "Prefix-cache evictions per engine.", "{}"),
                    ("cache_bytes", "prefix_cache_bytes", "gauge",
                     "Resident prefix-cache bytes per engine.", "{}"),
                    ("throughput_tok_per_s", "throughput_tok_s", "gauge",
                     "Tokens/s per engine.", "{:.6f}"),
                    ("mean_occupancy", "mean_occupancy", "gauge",
                     "Decode-slot occupancy per engine.", "{:.6f}"),
                    ("busy_seconds_total", "busy_time_s", "counter",
                     "Wall seconds with >=1 live decode row per engine.",
                     "{:.6f}"),
                    ("queue_wait_seconds_total", "queue_wait_s",
                     "counter", "Summed submit-to-admission wait per "
                     "engine.", "{:.6f}"),
                    ("steals_in_total", "steals_in", "counter",
                     "Requests adopted via work stealing per engine.",
                     "{}"),
                    ("steals_out_total", "steals_out", "counter",
                     "Requests given up via work stealing per engine.",
                     "{}"),
                    ("prefill_busy_seconds_total", "prefill_busy_s",
                     "counter", "Wall seconds in prefill passes per "
                     "engine.", "{:.6f}"),
                    ("decode_busy_seconds_total", "decode_busy_s",
                     "counter", "Wall seconds in decode_block calls per "
                     "engine.", "{:.6f}"),
                    ("handoffs_in_total", "handoffs_in", "counter",
                     "Requests adopted from the prefill pool per "
                     "engine.", "{}"),
                    ("handoffs_out_total", "handoffs_out", "counter",
                     "Primed requests handed to the decode pool per "
                     "engine.", "{}"),
                    ("compile_misses_total", "compile_misses", "counter",
                     "Jit variants compiled per engine.", "{}"),
                    ("post_warm_compiles_total", "post_warm_compiles",
                     "counter", "Post-pre-warm compiles per engine "
                     "(should stay 0).", "{}"),
                    ("host_threads", "host_threads", "gauge",
                     "Budgeted intra-op threads per engine.", "{}")):
                out.append(f"# HELP repro_engine_{name} {help_text}")
                out.append(f"# TYPE repro_engine_{name} {mtype}")
                for i, s in enumerate(snaps):
                    out.append(f'repro_engine_{name}{{engine="{i}"}} '
                               + fmt.format(s[key]))
            out.append("# HELP repro_engine_live_rows Live decode rows "
                       "per engine.")
            out.append("# TYPE repro_engine_live_rows gauge")
            for i, e in enumerate(self.engines):
                out.append(f'repro_engine_live_rows{{engine="{i}"}} '
                           f"{e.scheduler.live_rows}")
        return "\n".join(out) + "\n"


def _flight_state(loops, watchdog=None):
    """State-provider closure body for the flight recorder: everything
    a post-mortem needs, JSON-safe."""
    engines = []
    for lp in loops:
        e = lp.engine
        row = {"metrics": e.metrics.snapshot(),
               "telemetry": e.telemetry.totals()}
        if e.auditor is not None:
            row["audit"] = e.auditor.stats()
        engines.append(row)
    state = {"engines": engines,
             "schedulers": [lp.engine.scheduler.debug_state()
                            for lp in loops],
             "loops": [lp.debug_vars() for lp in loops]}
    if watchdog is not None:
        state["slo"] = watchdog.current()
    if any(getattr(lp, "recorder", None) is not None for lp in loops):
        # the breach window's time series rides along in the dump
        # (timeline.json) so a post-mortem sees the minutes *before*
        # the trigger, not just the instant of it
        from repro.obs.series import timeline_doc
        state["timeline"] = timeline_doc(loops, watchdog=watchdog)
    return state


def _front(engines, max_pending: int, tracer=None, steal: bool = True,
           audit=None, watchdog=None, flight=None, roles=None,
           metrics_interval_s: float = 0.5, metrics_log=None):
    """One EngineLoop per engine; >1 engine routes through
    ``EngineRouter`` (least-loaded by live rows, block-boundary work
    stealing unless ``steal=False``). ``tracer`` claims a named track
    group per engine. ``audit`` (an ``AuditConfig``) attaches a
    ``ShadowAuditor`` per engine; ``watchdog``/``flight`` wire SLO
    observation and crash/breach dumps into every loop. ``roles`` (one
    entry per engine, ``"prefill"``/``"decode"``/``None``) builds a
    disaggregated fleet — the router partitions pools by loop role.
    Every loop gets a ``MetricsRecorder`` (``metrics_interval_s`` <= 0
    disables); ``metrics_log`` additionally persists each sample as a
    JSONL line through one shared sink."""
    engines = engines if isinstance(engines, (list, tuple)) else [engines]
    loops = [EngineLoop(e, max_pending=max_pending, tracer=tracer,
                        index=i, role=roles[i] if roles else None)
             for i, e in enumerate(engines)]
    if audit is not None:
        from repro.obs.audit import ShadowAuditor
        for e in engines:
            e.attach_auditor(ShadowAuditor(e, audit, tracer=tracer,
                                           flight=flight))
    sink = None
    if metrics_log and metrics_interval_s > 0:
        from repro.obs.series import JsonlSink
        sink = JsonlSink(metrics_log)
    for lp in loops:
        lp.watchdog = watchdog
        lp.flight = flight
        if metrics_interval_s > 0:
            from repro.obs.series import MetricsRecorder
            lp.recorder = MetricsRecorder(
                lp.engine, index=lp.index, role=lp.role,
                interval_s=metrics_interval_s, sink=sink,
                watchdog=watchdog, loop=lp)
    if flight is not None and flight.state_provider is None:
        flight.state_provider = lambda: _flight_state(loops, watchdog)
    if len(loops) == 1:
        return loops[0]
    from repro.server.router import EngineRouter
    return EngineRouter(loops, steal=steal)


async def serve(engine, host: str = "127.0.0.1", port: int = 8000,
                max_pending: int = 64, tracer=None, steal: bool = True,
                audit=None, watchdog=None, flight=None, roles=None,
                metrics_interval_s: float = 0.5,
                metrics_log=None) -> None:
    """Run the HTTP front end until cancelled, then drain gracefully.
    ``engine`` may be one ``ContinuousEngine`` or a list (one per
    device/mesh; requests are routed least-loaded and rebalanced by
    work stealing unless ``steal=False``). ``audit``/``watchdog``/
    ``flight`` enable the repro.obs.audit layer; ``roles`` builds
    disaggregated prefill/decode pools (see ``_front``);
    ``metrics_interval_s``/``metrics_log`` configure the per-engine
    time-series recorders behind /debug/timeline and /console."""
    if watchdog is not None and flight is not None \
            and watchdog.flight is None:
        watchdog.flight = flight
    frontend = HttpFrontend(
        _front(engine, max_pending, tracer, steal, audit=audit,
               watchdog=watchdog, flight=flight, roles=roles,
               metrics_interval_s=metrics_interval_s,
               metrics_log=metrics_log),
        host=host, port=port, tracer=tracer, flight=flight,
        watchdog=watchdog)
    await frontend.start()
    log.info("repro.server listening on http://%s:%s (POST "
             "/v1/completions, GET /healthz, GET /metrics, GET "
             "/debug/vars, GET /debug/flight, GET /debug/timeline, "
             "GET /console; engines=%d)",
             frontend.host, frontend.port, len(frontend.engines))
    try:
        await frontend.serve_forever()
    except asyncio.CancelledError:
        pass
    finally:
        await frontend.shutdown(drain=True)


def run(engine, host: str = "127.0.0.1", port: int = 8000,
        max_pending: int = 64, tracer=None, steal: bool = True,
        audit=None, watchdog=None, flight=None, roles=None,
        metrics_interval_s: float = 0.5, metrics_log=None) -> None:
    """Blocking entry point used by ``repro.launch.serve --http``."""
    try:
        asyncio.run(serve(engine, host, port, max_pending, tracer=tracer,
                          steal=steal, audit=audit, watchdog=watchdog,
                          flight=flight, roles=roles,
                          metrics_interval_s=metrics_interval_s,
                          metrics_log=metrics_log))
    except KeyboardInterrupt:
        pass
