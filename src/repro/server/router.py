"""Multi-engine routing: N ``EngineLoop``s (one per device/mesh)
behind one front end.

``EngineRouter`` presents the same any-thread surface as a single
``EngineLoop`` (``submit`` / ``cancel`` / ``start`` / ``close`` /
``inflight``), so ``HttpFrontend`` drives either interchangeably. Each
loop owns one ``ContinuousEngine`` — typically bound to its own
``DecodeExecutor`` submesh, so the engines decode on disjoint devices
and the router is the only place where they meet.

Placement policy: **cache affinity, then least-loaded**. Each engine
owns a placement-bound cross-request prefix KV store (``repro.cache``)
— warming is per-engine, so routing a request to the engine whose
store holds the longest matching prompt prefix converts its prefill
from O(prompt) to O(novel tail). The router asks every engine for its
match length (a pure radix-tree walk, no pin) and prefers the deepest
hit; ties — including the everything-cold case, and any engine with
caching off — fall back to least loaded, where *load* weights a
decoding row at 1 and a still-queued (prefill-pending) row at
``PREFILL_PENDING_WEIGHT`` — a queued request has not claimed a slot
or any block-time yet, so counting it like a live gang row skews the
pick toward engines that merely have deep (cheap) queues. A request is
pinned to one engine at submit time (gang batching is per-scheduler,
so migrating later would restart the request). Reads of another
thread's scheduler/store state are racy by construction — these are
*heuristics*, and a one-tick stale read costs at most a slightly
uneven split or a missed hit.

Admission: the picked loop may reject (its bounded budget is full);
the router then tries the remaining loops in load order and only
re-raises when *every* engine rejected — one hot engine must not turn
away traffic the others could serve.

Placement-at-admission is no longer final: with ``steal=True`` (the
default) an idle loop asks ``pick_victim`` for the most-backlogged
sibling and steals waiting/paused requests from it at block boundaries
(see ``EngineLoop``), so a load split frozen by a bad heuristic read
self-corrects instead of persisting for the requests' lifetime.

Disaggregated pools: when the fleet mixes ``role="prefill"`` loops
(``ContinuousEngine(prefill_only=True)`` publishing chunk KV into ONE
shared radix store) with decode-capable loops, the router becomes
role-aware. A request whose chunk-aligned prompt prefix is not yet in
the shared store routes to the prefill pool (primed there, then handed
off to a decode loop via ``pick_decode_loop``); a fully-cached request
bypasses the prefill pool entirely. The other pool is kept as an
admission spill target only — a decode engine can always prime for
itself, and a prefill engine's handoff path can always finish a
request, so a full preferred pool degrades to the co-located behavior
instead of a 429. Stealing never crosses roles: ``pick_victim`` fences
on ``thief.role`` (a prefill engine must not adopt decode rows it can
never finish, and decode engines stealing prefill-pending work would
re-create the interference disaggregation removes).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import numpy as np

from repro.obs.log import get_logger
from repro.server.loop import EngineLoop, Ticket
from repro.server.types import AdmissionRejected, ServerRequest

log = get_logger(__name__)

# relative cost of a prefill-pending (queued, slotless) row vs a live
# decoding row in every load/backlog estimate below
PREFILL_PENDING_WEIGHT = 0.25


class EngineRouter:
    def __init__(self, loops: List[EngineLoop], steal: bool = True):
        assert loops, "EngineRouter needs at least one EngineLoop"
        self.loops = list(loops)
        self.prefill_pool = [lp for lp in self.loops
                             if lp.role == "prefill"]
        self.decode_pool = [lp for lp in self.loops
                            if lp.role != "prefill"]
        self.disaggregated = bool(self.prefill_pool) \
            and bool(self.decode_pool)
        if self.prefill_pool and not self.decode_pool:
            raise ValueError("a fleet of only prefill engines can never "
                             "complete a request")
        self.steal = steal and len(self.loops) > 1
        for lp in self.loops:
            lp.router = self
            # stealing is fenced to same-role siblings, so a loop only
            # asks when its own pool has a potential victim
            pool = (self.prefill_pool if lp.role == "prefill"
                    else self.decode_pool)
            lp.steal = self.steal and len(pool) > 1

    # ---------------------------------------------------- loop surface

    @property
    def engines(self):
        """The per-loop ``ContinuousEngine``s (metrics/health fan-in)."""
        return [lp.engine for lp in self.loops]

    @property
    def engine(self):
        """Single-engine compatibility alias (first engine)."""
        return self.loops[0].engine

    @property
    def inflight(self) -> int:
        return sum(lp.inflight for lp in self.loops)

    @property
    def running(self) -> bool:
        return all(lp.running for lp in self.loops)

    def start(self) -> "EngineRouter":
        for lp in self.loops:
            if not lp.running:
                lp.start()
        return self

    def close(self, drain: bool = True, timeout_s: float = 30.0) -> bool:
        # signal every loop before joining any: the drains overlap
        # instead of serializing one engine's tail behind another's —
        # and the joins share ONE deadline, so a hung engine can't
        # stretch the caller's bound to N * timeout_s. Prefill loops
        # are joined first: their drains end in handoffs the decode
        # pool must still be alive to adopt.
        for lp in self.loops:
            lp.request_stop(drain)
        deadline = time.monotonic() + timeout_s
        ok = True
        for lp in self.prefill_pool + self.decode_pool:
            ok = lp.join(max(0.0, deadline - time.monotonic())) and ok
        return ok

    # ---------------------------------------------------- routing

    def _loop_load(self, lp: EngineLoop) -> float:
        """Weighted engine load: decoding rows (live in a gang) and
        parked mid-decode rows count 1; rows that are merely queued
        (front-end pending + scheduler waiting — no slot, no KV, no
        block-time yet) count ``PREFILL_PENDING_WEIGHT``."""
        sched = lp.engine.scheduler
        queued = len(lp._pending) + len(sched.waiting)
        return (sched.live_rows + len(sched.paused)
                + PREFILL_PENDING_WEIGHT * queued)

    def _by_load(self, loops: List[EngineLoop]) -> List[EngineLoop]:
        return [lp for _, lp in
                sorted(((self._loop_load(lp), lp) for lp in loops),
                       key=lambda it: (it[0], it[1].inflight, it[1].index))]

    def _needs_prefill(self, req: ServerRequest) -> bool:
        """True iff the request's chunk-aligned prompt prefix is not
        fully resident in the shared store — i.e. a prefill-pool pass
        would publish chunks a decode engine could then reuse. Prompts
        shorter than one chunk have no publishable prefix (the decode
        engine computes the remainder either way), so they bypass."""
        eng = self.decode_pool[0].engine
        store = getattr(eng, "prefix_cache", None)
        if store is None:
            return False
        try:
            toks = (eng.tok.encode(req.prompt)
                    if isinstance(req.prompt, str)
                    else np.asarray(req.prompt, np.int32))
        except Exception:          # malformed prompt: let submit raise
            return False
        C = store.chunk_tokens
        aligned = (len(toks) // C) * C
        return aligned > 0 and store.match_len(toks) < aligned

    def _load_order(self, req: ServerRequest = None) -> List[EngineLoop]:
        if self.disaggregated and req is not None:
            # role-aware: pick the pool, least-loaded within it; the
            # other pool rides along as an admission spill target (see
            # module docstring). No per-engine hit probe — the store is
            # shared, so affinity is meaningless within a pool.
            first, second = ((self.prefill_pool, self.decode_pool)
                             if self._needs_prefill(req)
                             else (self.decode_pool, self.prefill_pool))
            return self._by_load(first) + self._by_load(second)
        hits = [0] * len(self.loops)
        probe = (req is not None and len(self.loops) > 1
                 and any(getattr(lp.engine, "prefix_cache", None) is not None
                         for lp in self.loops))
        if probe:
            try:
                # tokenize once, probe every store with the ids —
                # engines share one tokenizer family by construction
                toks = self.loops[0].engine.tok.encode(req.prompt)
                for i, lp in enumerate(self.loops):
                    hits[i] = lp.engine.expected_prefix_hit(toks)
            except Exception:         # affinity is best-effort, never fatal
                log.exception("prefix-hit probe failed")

        def load(item):
            i, lp = item
            return (-hits[i], self._loop_load(lp), lp.inflight, i)
        return [lp for _, lp in
                sorted(enumerate(self.loops), key=lambda it: load(it))]

    def submit(self, req: ServerRequest,
               deliver: Callable[[tuple], None]) -> Ticket:
        order = self._load_order(req)
        last_reject = None
        for lp in order:
            try:
                # count_reject=False: a spill that a peer serves is not
                # a 429 — the counter moves only when everyone rejects
                ticket = lp.submit(req, deliver, count_reject=False)
            except AdmissionRejected as e:
                last_reject = e
                continue
            ticket.loop = lp        # cancel() routes back to the owner
            return ticket
        order[-1].count_admission_reject()
        raise last_reject

    def cancel(self, ticket: Ticket, reason: str = "cancelled") -> None:
        (ticket.loop or self.loops[0]).cancel(ticket, reason)

    # ---------------------------------------------------- stealing

    def pick_victim(self, thief: EngineLoop):
        """Most-backlogged loop in the *thief's own pool* (steal never
        crosses roles), where backlog is work beyond what the victim's
        own free slots will absorb next tick (front-end pending +
        scheduler waiting + parked rows − free slots). Victims are
        ranked by the weighted form — parked mid-decode rows at 1,
        merely-queued rows at ``PREFILL_PENDING_WEIGHT`` — so a deep
        but cheap queue no longer outbids parked rows that are actually
        starving. Reads of other threads' state are racy heuristics,
        same contract as ``_load_order``; the steal handshake itself is
        command-queue-serialized on the victim's decode thread. Returns
        ``(loop, backlog)`` or ``(None, 0)``."""
        best, best_backlog = None, 0
        best_score = float("-inf")
        for lp in self.loops:
            if lp is thief or not lp.running or lp.role != thief.role:
                continue
            sched = lp.engine.scheduler
            free = max(0, sched.max_slots - sched.slots_used)
            queued = len(lp._pending) + len(sched.waiting)
            parked = len(sched.paused)
            backlog = queued + parked - free
            if backlog <= 0:
                continue
            score = parked + PREFILL_PENDING_WEIGHT * queued - free
            if score > best_score:
                best, best_backlog, best_score = lp, backlog, score
        return best, best_backlog

    # ---------------------------------------------------- handoff

    def pick_decode_loop(self, exclude: Optional[EngineLoop] = None) \
            -> Optional[EngineLoop]:
        """Least-loaded running decode-capable loop — the prefill pool
        calls this to place each primed row. ``None`` when the decode
        pool is gone (caller fails the request rather than strand it)."""
        alive = [lp for lp in self.decode_pool
                 if lp is not exclude and lp.running]
        order = self._by_load(alive)
        return order[0] if order else None

    def pick_reroute_target(self, failed: EngineLoop) \
            -> Optional[EngineLoop]:
        """Healthy destination for work shed off a crashed engine:
        least-loaded same-role sibling first (it serves the same
        traffic shape), decode-capable loops otherwise (they can both
        prime and decode, so they can absorb anything)."""
        same = [lp for lp in self.loops
                if lp is not failed and lp.running
                and lp.role == failed.role]
        order = self._by_load(same)
        if order:
            return order[0]
        return self.pick_decode_loop(exclude=failed)
