"""Multi-engine routing: N ``EngineLoop``s (one per device/mesh)
behind one front end.

``EngineRouter`` presents the same any-thread surface as a single
``EngineLoop`` (``submit`` / ``cancel`` / ``start`` / ``close`` /
``inflight``), so ``HttpFrontend`` drives either interchangeably. Each
loop owns one ``ContinuousEngine`` — typically bound to its own
``DecodeExecutor`` submesh, so the engines decode on disjoint devices
and the router is the only place where they meet.

Placement policy: **cache affinity, then least-loaded**. Each engine
owns a placement-bound cross-request prefix KV store (``repro.cache``)
— warming is per-engine, so routing a request to the engine whose
store holds the longest matching prompt prefix converts its prefill
from O(prompt) to O(novel tail). The router asks every engine for its
match length (a pure radix-tree walk, no pin) and prefers the deepest
hit; ties — including the everything-cold case, and any engine with
caching off — fall back to fewest live decode rows, then total
in-flight count, then index. A request is pinned to one engine at
submit time (gang batching is per-scheduler, so migrating later would
restart the request). Reads of another thread's scheduler/store state
are racy by construction — these are *heuristics*, and a one-tick
stale read costs at most a slightly uneven split or a missed hit.

Admission: the picked loop may reject (its bounded budget is full);
the router then tries the remaining loops in load order and only
re-raises when *every* engine rejected — one hot engine must not turn
away traffic the others could serve.

Placement-at-admission is no longer final: with ``steal=True`` (the
default) an idle loop asks ``pick_victim`` for the most-backlogged
sibling and steals waiting/paused requests from it at block boundaries
(see ``EngineLoop``), so a load split frozen by a bad heuristic read
self-corrects instead of persisting for the requests' lifetime.
"""
from __future__ import annotations

import time
from typing import Callable, List

from repro.obs.log import get_logger
from repro.server.loop import EngineLoop, Ticket
from repro.server.types import AdmissionRejected, ServerRequest

log = get_logger(__name__)


class EngineRouter:
    def __init__(self, loops: List[EngineLoop], steal: bool = True):
        assert loops, "EngineRouter needs at least one EngineLoop"
        self.loops = list(loops)
        self.steal = steal and len(self.loops) > 1
        for lp in self.loops:
            lp.router = self
            lp.steal = self.steal

    # ---------------------------------------------------- loop surface

    @property
    def engines(self):
        """The per-loop ``ContinuousEngine``s (metrics/health fan-in)."""
        return [lp.engine for lp in self.loops]

    @property
    def engine(self):
        """Single-engine compatibility alias (first engine)."""
        return self.loops[0].engine

    @property
    def inflight(self) -> int:
        return sum(lp.inflight for lp in self.loops)

    @property
    def running(self) -> bool:
        return all(lp.running for lp in self.loops)

    def start(self) -> "EngineRouter":
        for lp in self.loops:
            if not lp.running:
                lp.start()
        return self

    def close(self, drain: bool = True, timeout_s: float = 30.0) -> bool:
        # signal every loop before joining any: the drains overlap
        # instead of serializing one engine's tail behind another's —
        # and the joins share ONE deadline, so a hung engine can't
        # stretch the caller's bound to N * timeout_s
        for lp in self.loops:
            lp.request_stop(drain)
        deadline = time.monotonic() + timeout_s
        ok = True
        for lp in self.loops:
            ok = lp.join(max(0.0, deadline - time.monotonic())) and ok
        return ok

    # ---------------------------------------------------- routing

    def _load_order(self, req: ServerRequest = None) -> List[EngineLoop]:
        hits = [0] * len(self.loops)
        probe = (req is not None and len(self.loops) > 1
                 and any(getattr(lp.engine, "prefix_cache", None) is not None
                         for lp in self.loops))
        if probe:
            try:
                # tokenize once, probe every store with the ids —
                # engines share one tokenizer family by construction
                toks = self.loops[0].engine.tok.encode(req.prompt)
                for i, lp in enumerate(self.loops):
                    hits[i] = lp.engine.expected_prefix_hit(toks)
            except Exception:         # affinity is best-effort, never fatal
                log.exception("prefix-hit probe failed")

        def load(item):
            i, lp = item
            return (-hits[i], lp.engine.scheduler.live_rows, lp.inflight, i)
        return [lp for _, lp in
                sorted(enumerate(self.loops), key=lambda it: load(it))]

    def submit(self, req: ServerRequest,
               deliver: Callable[[tuple], None]) -> Ticket:
        order = self._load_order(req)
        last_reject = None
        for lp in order:
            try:
                # count_reject=False: a spill that a peer serves is not
                # a 429 — the counter moves only when everyone rejects
                ticket = lp.submit(req, deliver, count_reject=False)
            except AdmissionRejected as e:
                last_reject = e
                continue
            ticket.loop = lp        # cancel() routes back to the owner
            return ticket
        order[-1].count_admission_reject()
        raise last_reject

    def cancel(self, ticket: Ticket, reason: str = "cancelled") -> None:
        (ticket.loop or self.loops[0]).cancel(ticket, reason)

    # ---------------------------------------------------- stealing

    def pick_victim(self, thief: EngineLoop):
        """Most-backlogged loop other than ``thief``, where backlog is
        work beyond what the victim's own free slots will absorb next
        tick (front-end pending + scheduler waiting + parked rows −
        free slots). Reads of other threads' state are racy heuristics,
        same contract as ``_load_order``; the steal handshake itself is
        command-queue-serialized on the victim's decode thread. Returns
        ``(loop, backlog)`` or ``(None, 0)``."""
        best, best_backlog = None, 0
        for lp in self.loops:
            if lp is thief or not lp.running:
                continue
            sched = lp.engine.scheduler
            free = max(0, sched.max_slots - sched.slots_used)
            backlog = (len(lp._pending) + len(sched.waiting)
                       + len(sched.paused) - free)
            if backlog > best_backlog:
                best, best_backlog = lp, backlog
        return best, best_backlog
