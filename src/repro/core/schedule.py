"""Temporal component of Streaming-dLLM: confidence scores, the dynamic
threshold (Eq. 10), and the token selection rule S(.) (Eq. 9).

All functions are jit-safe and operate on the *current block* region.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def confidence_and_tokens(logits: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 4: c_i = max softmax(z_i); x_hat_i = argmax softmax(z_i).

    logits: (..., V) float32 -> (conf (...,), tokens (...,) int32).
    Computed via logsumexp (never materializes the softmax) — mirrors the
    fused Pallas kernel in kernels/confidence.py.
    """
    m = jnp.max(logits, axis=-1)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    conf = jnp.exp(m - lse)
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return conf, toks


def chunked_head_reduce(hidden: jnp.ndarray, head: jnp.ndarray, reduce_fn, *,
                        mask_id: int = -1, logit_softcap: float = 0.0,
                        row_chunk: int = 1024
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-head scaffold: project row chunks of the final hidden
    states through the LM head (softcap + [MASK] ban applied per chunk)
    and hand each chunk's 2-D logits to ``reduce_fn`` -> (conf, tok),
    so the full ``(..., V)`` logits never exist as one array. Shared by
    the reference reducer below and the Pallas route in ``kernels.ops``
    — the wrapper semantics must stay identical between them.

    hidden: (..., d); head: (d, V).
    """
    shape = hidden.shape[:-1]
    h2 = hidden.reshape(-1, hidden.shape[-1])
    confs, toks = [], []
    for s in range(0, h2.shape[0], row_chunk):
        hc = h2[s:s + row_chunk]
        logits = (hc @ head.astype(hc.dtype)).astype(jnp.float32)
        if logit_softcap:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        if mask_id >= 0:
            logits = logits.at[:, mask_id].set(-1e30)
        c, t = reduce_fn(logits)
        confs.append(c)
        toks.append(t)
    conf = confs[0] if len(confs) == 1 else jnp.concatenate(confs)
    tok = toks[0] if len(toks) == 1 else jnp.concatenate(toks)
    return conf.reshape(shape), tok.reshape(shape)


def head_confidence_and_tokens(hidden: jnp.ndarray, head: jnp.ndarray, *,
                               mask_id: int = -1, logit_softcap: float = 0.0,
                               row_chunk: int = 1024
                               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-head path (reference reducer): row chunking leaves each
    row's reduction untouched, so per-row results match
    ``confidence_and_tokens`` over the monolithic logits."""
    return chunked_head_reduce(hidden, head, confidence_and_tokens,
                               mask_id=mask_id, logit_softcap=logit_softcap,
                               row_chunk=row_chunk)


def dynamic_threshold(tau0: float, alpha: float, r_mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. 10: tau(t) = tau0 * (1 - alpha * (1 - r_mask)).

    r_mask in [0, 1]: fraction of still-masked tokens in the current
    block. Early (r_mask ~ 1) -> tau ~ tau0 (strict); late -> relaxed.
    """
    return tau0 * (1.0 - alpha * (1.0 - r_mask))


def select_tokens(conf: jnp.ndarray, is_masked: jnp.ndarray,
                  tau: jnp.ndarray) -> jnp.ndarray:
    """Eq. 9 selection rule. conf/is_masked: (B, K); tau: scalar or (B,).

    Returns commit mask (B, K): masked positions with conf >= tau; if a
    row has none, its single most-confident masked position (guarantees
    progress). Rows with no masked positions commit nothing.
    """
    tau = jnp.broadcast_to(jnp.asarray(tau, conf.dtype), conf.shape[:1])
    mconf = jnp.where(is_masked, conf, -jnp.inf)
    above = is_masked & (conf >= tau[:, None])
    any_above = jnp.any(above, axis=1)
    any_masked = jnp.any(is_masked, axis=1)
    best = jnp.argmax(mconf, axis=1)
    fallback = jax.nn.one_hot(best, conf.shape[1], dtype=jnp.bool_)
    fallback = fallback & any_masked[:, None] & ~any_above[:, None]
    return above | fallback


def fixed_rate_select(conf: jnp.ndarray, is_masked: jnp.ndarray,
                      n_commit: int) -> jnp.ndarray:
    """Vanilla baseline schedule: commit the n_commit most-confident
    masked tokens per step (standard low-confidence remasking order)."""
    mconf = jnp.where(is_masked, conf, -jnp.inf)
    k = min(n_commit, conf.shape[1])
    _, idx = jax.lax.top_k(mconf, k)
    commit = jnp.zeros_like(is_masked).at[
        jnp.arange(conf.shape[0])[:, None], idx].set(True)
    return commit & is_masked
