"""Batched serving engine front end.

Two modes over one API:

``mode="continuous"`` (default) — delegates to the continuous-batching
subsystem (``repro.serving``): block-granular scheduling, slot
backfill on EOS early exit, shared prefix-KV pool, streaming chunks.

``mode="batch"`` — the legacy synchronous path: requests are grouped by
(prompt_len, gen_len) shape bucket, the largest group is decoded to
completion, stragglers pin the batch. Kept as the baseline the serving
benchmark compares against.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

from repro.core.decoder import (DecodeConfig, DiffusionDecoder,
                                round_up_blocks)
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: str
    max_tokens: int = 64
    prompt_tokens: Optional[np.ndarray] = None   # encoded once at submit


@dataclasses.dataclass
class Completion:
    uid: int
    text: str
    tokens: np.ndarray
    latency_s: float
    nfe: int


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, dcfg: DecodeConfig,
                 max_batch: int = 32, mode: str = "continuous"):
        assert mode in ("batch", "continuous"), mode
        self.cfg = cfg
        self.dcfg = dcfg
        self.mode = mode
        self.tok = ByteTokenizer(cfg.vocab_size)
        self.max_batch = max_batch
        self._decoders: Dict[int, DiffusionDecoder] = {}
        self._params = params
        self._queue: List[Request] = []
        self._uid = 0
        self.stats = defaultdict(float)
        self._continuous = None
        if mode == "continuous":
            from repro.serving import ContinuousEngine
            self._continuous = ContinuousEngine(
                cfg, params, dcfg, max_slots=max_batch, tokenizer=self.tok)
            self.stats = self._continuous.stats   # one shared counter dict

    def submit(self, prompt: str, max_tokens: int = 64) -> int:
        if self._continuous is not None:
            return self._continuous.submit(prompt, max_tokens)
        self._uid += 1
        self._queue.append(Request(self._uid, prompt, max_tokens,
                                   self.tok.encode(prompt)))
        return self._uid

    def _decoder(self, gen_len: int) -> DiffusionDecoder:
        if gen_len not in self._decoders:
            d = dataclasses.replace(self.dcfg, gen_len=gen_len)
            self._decoders[gen_len] = DiffusionDecoder(self.cfg,
                                                       self._params, d)
        return self._decoders[gen_len]

    def step(self) -> List[Completion]:
        """Serve one scheduling round. Continuous mode: one block for
        every live gang. Batch mode: group queued requests by
        (prompt_len, gen_len) and decode the largest group to
        completion."""
        if self._continuous is not None:
            return [Completion(c.uid, c.text, c.tokens, c.latency_s, c.nfe)
                    for c in self._continuous.step()]
        if not self._queue:
            return []
        groups = defaultdict(list)
        for r in self._queue:
            gl = round_up_blocks(r.max_tokens, self.dcfg.block_size)
            groups[(len(r.prompt_tokens), gl)].append(r)
        key = max(groups, key=lambda k: len(groups[k]))
        batch = groups[key][: self.max_batch]
        taken = {id(r) for r in batch}
        self._queue = [r for r in self._queue if id(r) not in taken]
        prompts = np.stack([r.prompt_tokens for r in batch])
        t0 = time.perf_counter()
        res = self._decoder(key[1]).generate(prompts.astype(np.int32))
        dt = time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["requests"] += len(batch)
        self.stats["tokens"] += res.tokens_generated
        self.stats["time_s"] += dt
        return [Completion(r.uid, self.tok.decode(res.tokens[i]),
                           res.tokens[i], dt, res.nfe)
                for i, r in enumerate(batch)]

    def run_to_completion(self) -> List[Completion]:
        if self._continuous is not None:
            return [Completion(c.uid, c.text, c.tokens, c.latency_s, c.nfe)
                    for c in self._continuous.run_to_completion()]
        out: List[Completion] = []
        while self._queue:
            out.extend(self.step())
        return out

    @property
    def throughput(self) -> float:
        if self._continuous is not None:
            return self._continuous.throughput
        return self.stats["tokens"] / max(self.stats["time_s"], 1e-9)
