"""Batched serving engine: a minimal vLLM-style front end over the
diffusion decoder. Requests are queued, grouped by prompt length into
batches, decoded with Streaming-dLLM, and returned with per-request
stats. Prompt-length bucketing keeps the compiled step shapes stable.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional

import numpy as np

from repro.core.decoder import DecodeConfig, DiffusionDecoder
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: str
    max_tokens: int = 64


@dataclasses.dataclass
class Completion:
    uid: int
    text: str
    tokens: np.ndarray
    latency_s: float
    nfe: int


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, dcfg: DecodeConfig,
                 max_batch: int = 32):
        self.cfg = cfg
        self.dcfg = dcfg
        self.tok = ByteTokenizer(cfg.vocab_size)
        self.max_batch = max_batch
        self._decoders: Dict[int, DiffusionDecoder] = {}
        self._params = params
        self._queue: List[Request] = []
        self._uid = 0
        self.stats = defaultdict(float)

    def submit(self, prompt: str, max_tokens: int = 64) -> int:
        self._uid += 1
        self._queue.append(Request(self._uid, prompt, max_tokens))
        return self._uid

    def _decoder(self, gen_len: int) -> DiffusionDecoder:
        if gen_len not in self._decoders:
            d = dataclasses.replace(self.dcfg, gen_len=gen_len)
            self._decoders[gen_len] = DiffusionDecoder(self.cfg,
                                                       self._params, d)
        return self._decoders[gen_len]

    def step(self) -> List[Completion]:
        """Serve one batch: group queued requests by (prompt_len,
        gen_len) and decode the largest group."""
        if not self._queue:
            return []
        groups = defaultdict(list)
        for r in self._queue:
            gl = -(-r.max_tokens // self.dcfg.block_size) * self.dcfg.block_size
            groups[(len(self.tok.encode(r.prompt)), gl)].append(r)
        key = max(groups, key=lambda k: len(groups[k]))
        batch = groups[key][: self.max_batch]
        for r in batch:
            self._queue.remove(r)
        prompts = np.stack([self.tok.encode(r.prompt) for r in batch])
        t0 = time.perf_counter()
        res = self._decoder(key[1]).generate(prompts.astype(np.int32))
        dt = time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["requests"] += len(batch)
        self.stats["tokens"] += res.tokens_generated
        self.stats["time_s"] += dt
        return [Completion(r.uid, self.tok.decode(res.tokens[i]),
                           res.tokens[i], dt, res.nfe)
                for i, r in enumerate(batch)]

    def run_to_completion(self) -> List[Completion]:
        out: List[Completion] = []
        while self._queue:
            out.extend(self.step())
        return out

    @property
    def throughput(self) -> float:
        return self.stats["tokens"] / max(self.stats["time_s"], 1e-9)
