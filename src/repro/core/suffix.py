"""Spatial component of Streaming-dLLM: attenuation-guided suffix
modeling (Eq. 7-8).

When decoding block ``c`` of a generation of ``L`` tokens starting at
``gen_start`` (= prompt length), the model's query region is

    [ current block (K tokens) | suffix window (w_c tokens) | trailing ]

where ``w_c = min(w, remaining_suffix)`` and the trailing slot carries
the *final* position id ``gen_start + L - 1`` (the paper's termination /
length cue, Table 6) — included only when the window does not already
reach the end. All positions are explicit so RoPE keeps the logical
ordering (paper: "maintaining the logical ordering of tokens via RoPE
position IDs").

These are host-side index computations (ints), so each block's query
shape is exact; the compiled steady-state shape used by the production
``serve_step`` is K + w + 1.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class QueryRegion:
    block_idx: int
    block_start: int          # absolute position of the block's first token
    block_size: int
    suffix_start: int
    suffix_len: int           # w_c
    trailing_pos: int         # -1 if absent
    positions: np.ndarray     # (Sq,) absolute position ids

    @property
    def query_len(self) -> int:
        return self.positions.shape[0]


def suffix_query_region(*, gen_start: int, gen_len: int, block_size: int,
                        block_idx: int, window: int) -> QueryRegion:
    """window: suffix tokens retained (paper's w, in tokens). window < 0
    means "no pruning" (full suffix — the Fast-dLLM/vanilla layout)."""
    n_blocks = gen_len // block_size
    assert 0 <= block_idx < n_blocks
    bs = gen_start + block_idx * block_size
    suffix_start = bs + block_size
    end = gen_start + gen_len
    remaining = end - suffix_start
    w = remaining if window < 0 else min(window, remaining)
    trailing = -1
    if w < remaining:
        trailing = end - 1
    pos = list(range(bs, bs + block_size)) + list(range(suffix_start,
                                                        suffix_start + w))
    if trailing >= 0:
        pos.append(trailing)
    return QueryRegion(block_idx, bs, block_size, suffix_start, w, trailing,
                       np.asarray(pos, np.int32))


def steady_state_query_len(block_size: int, window: int) -> int:
    """Static query length for the compiled production serve_step."""
    return block_size + max(window, 0) + 1
