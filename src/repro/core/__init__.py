from repro.core.decoder import (METHODS, DecodeConfig, DiffusionDecoder,
                                GenerateResult)
from repro.core.engine import Completion, Request, ServingEngine
from repro.core.schedule import (confidence_and_tokens, dynamic_threshold,
                                 fixed_rate_select, select_tokens)
from repro.core.suffix import (QueryRegion, steady_state_query_len,
                               suffix_query_region)

__all__ = ["METHODS", "DecodeConfig", "DiffusionDecoder", "GenerateResult",
           "Completion", "Request", "ServingEngine",
           "confidence_and_tokens", "dynamic_threshold", "fixed_rate_select",
           "select_tokens", "QueryRegion", "steady_state_query_len",
           "suffix_query_region"]
