"""Block-wise diffusion decoding — Streaming-dLLM and all paper baselines.

Five methods (paper Tables 1/2/8):

  vanilla   — no cache; full-sequence forward each denoise step; fixed
              schedule (top-`K/M` most-confident masked tokens per step).
  dkv       — delayed KV cache (Ma et al. 2025): a token's K/V is frozen
              into a position-indexed cache one step after it decodes;
              masked tokens recompute theirs each step. Vanilla schedule.
  prefix    — Fast-dLLM's prefix cache: prompt + finished blocks cached;
              the block + FULL suffix recomputed each step. Vanilla
              schedule.
  fast      — Fast-dLLM: prefix cache + fixed-threshold tau0 parallel
              commit (argmax fallback guarantees progress).
  streaming — OURS: prefix cache + attenuation-guided suffix pruning
              (window w + trailing position token) + dynamic threshold
              tau(t) (Eq. 10) + EOS early exit.

Two execution paths for the per-block denoise loop:

  fused (default) — one jitted, device-resident loop per block: a
      ``lax.while_loop`` carries the token buffer / commit mask / step
      counter on device, with the mask-token ban, confidence, the
      dynamic threshold tau(t), token selection, the straggler finalize
      and EOS early exit all inside the compiled function. The host
      syncs exactly once per block. For the parallel methods the block
      confidence comes from a fused hidden-states -> (confidence, token)
      head path (``apply_model(skip_head=True)`` + row-chunked
      projection), so block logits never materialize as one
      ``(B, K, V)`` array.
  host — the legacy loop: Python drives every denoise step, fetching
      per-step results to numpy and re-uploading the token buffer. Kept
      as the validation oracle (``tests/test_fused_decode.py`` asserts
      token identity) and as the baseline ``benchmarks/bench_decode.py``
      measures against.

Query shapes are exact per block, so the jit cache holds at most
#distinct-(block, batch)-shapes entries in either path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core.suffix import suffix_query_region
from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.model import apply_model, cache_take_rows, init_cache
from repro.obs.telemetry import CONF_BUCKETS, BlockStats

METHODS = ("vanilla", "dkv", "prefix", "fast", "streaming")


def round_up_blocks(max_tokens: int, block_size: int) -> int:
    """Generation-length bucket for a request: next block multiple.
    Both serving modes MUST bucket identically (continuous/batch token
    identity depends on it), so this is the single definition."""
    return -(-max_tokens // block_size) * block_size


def eos_truncate(gen: np.ndarray, eos_id: int):
    """Canonical EOS policy for a generated row: the first EOS ends the
    output and the tail is EOS-filled. Returns ``(tokens, n_generated)``
    — the single definition shared by ``row_output`` and the serving
    scheduler's completion builder."""
    eos_pos = np.where(gen == eos_id)[0]
    n = int(eos_pos[0]) if len(eos_pos) else len(gen)
    if len(eos_pos):
        gen = gen.copy()
        gen[eos_pos[0]:] = eos_id
    return gen, n


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    method: str = "streaming"
    gen_len: int = 256
    block_size: int = 32
    steps_per_block: int = 0       # 0 -> block_size (one token per step)
    tau0: float = 0.9              # base confidence threshold
    alpha: float = 0.3             # Eq. 10 adaptation strength
    window: int = 96               # suffix tokens kept (streaming); -1=full
    trailing_position: bool = True
    early_exit: bool = True
    use_kernels: bool = False      # route attention/confidence to Pallas
    fused: bool = True             # device-resident denoise loop (one host
                                   # sync per block); False = legacy host
                                   # loop (per-step transfers)
    # Beyond-paper (EXPERIMENTS.md §Perf HC1): freeze the pruned-suffix
    # KV at the block-refresh step and reuse it across the block's
    # denoise iterations (DualCache-inspired). Steps then query only the
    # K block tokens instead of K + w + 1 — ~4x less step compute at the
    # paper's config. The suffix KV is one refresh stale within a block
    # (same approximation class as the prefix cache itself).
    frozen_suffix: bool = False
    # Cross-request prefix KV reuse (repro.cache): the prompt KV is
    # computed once at prefill by chunk-causal passes (chunk i attends
    # to chunks 0..i only, bidirectional within the chunk) so each
    # chunk's KV is content-addressable and shareable across requests;
    # block refreshes then rewrite only the generated region and attend
    # to the frozen prompt KV. The prompt no longer sees the masked
    # region (same approximation class as Fast-dLLM's prefix cache);
    # cached vs cold prefill stays bit-identical by construction.
    prefix_cache: bool = False
    cache_chunk: int = 16          # prompt chunk size for repro.cache

    def __post_init__(self):
        assert self.method in METHODS, self.method
        assert self.gen_len % self.block_size == 0
        assert self.cache_chunk > 0
        # the frozen-suffix refresh writes position-indexed over the
        # whole buffer with nothing cached-valid; combining it with a
        # frozen prompt region needs a third refresh variant — out of
        # scope (EXPERIMENTS.md §Prefix caching)
        assert not (self.prefix_cache and self.frozen_suffix), \
            "prefix_cache and frozen_suffix are mutually exclusive"

    @property
    def effective_window(self) -> int:
        if self.method == "streaming":
            return self.window
        return -1                   # baselines see the full suffix

    @property
    def parallel(self) -> bool:
        return self.method in ("fast", "streaming")


@dataclasses.dataclass
class DecodeState:
    """Resumable decode progress for a batch of rows that all sit at the
    same block boundary. Produced by ``DiffusionDecoder.prefill`` and
    advanced one diffusion block at a time by ``decode_block`` — the
    host-side contract the continuous-batching scheduler
    (``repro.serving``) is built on: between any two blocks the
    scheduler may harvest finished rows, compact the batch, or
    interleave other requests' states on the same compiled step fns."""
    x: np.ndarray                     # (B, T) tokens; mask id where open
    committed: np.ndarray             # (B, T) bool
    done: np.ndarray                  # (B,) early-exited rows
    prompt_len: int
    n_blocks: int
    block_idx: int = 0                # next block to decode
    cache: Any = None
    valid_mask: Optional[np.ndarray] = None    # dkv only: (B, T) bool
    cached_mask: Optional[np.ndarray] = None   # dkv only: (B, T) bool
    prefix_hit_tokens: Optional[np.ndarray] = None  # prefix_cache: (B,)
    nfe: int = 0
    q_tokens: int = 0
    kv_tokens: int = 0
    steps_per_block: list = dataclasses.field(default_factory=list)
    early_exits: int = 0
    host_syncs: int = 0               # blocking device->host fetch points
    logit_syncs: int = 0              # of those, full (B, K, V) logit copies
    prefill_time: float = 0.0
    decode_time: float = 0.0
    # per-block dynamics (repro.obs.telemetry.BlockStats): appended by
    # every decode_block call — harvested from the SAME host sync that
    # returns the block's tokens, so telemetry never adds a sync. The
    # serving scheduler drains this list after each call; standalone
    # decoder users read it off the finished state.
    block_stats: list = dataclasses.field(default_factory=list)

    @property
    def batch(self) -> int:
        return self.x.shape[0]

    @property
    def total_len(self) -> int:
        return self.x.shape[1]

    @property
    def finished(self) -> bool:
        return self.block_idx >= self.n_blocks or bool(self.done.all())

    def row_finished(self, b: int) -> bool:
        return bool(self.done[b]) or self.block_idx >= self.n_blocks


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray             # (B, gen_len) committed tokens
    nfe: int                       # model forward evaluations
    steps_per_block: list
    wall_time: float
    query_tokens_processed: int    # sum of query lengths over all NFEs
    kv_tokens_attended: int        # sum of (kv length * query len) proxy
    tokens_generated: int          # non-EOS tokens (paper's TPS metric)
    early_exits: int
    prefill_time: float = 0.0
    host_syncs: int = 0
    logit_syncs: int = 0

    @property
    def tokens_per_nfe(self) -> float:
        return self.tokens_generated / max(self.nfe, 1)


class DiffusionDecoder:
    """Block diffusion decoder: host scheduler over compiled step fns
    (legacy) or one compiled device-resident loop per block (fused)."""

    def __init__(self, cfg: ModelConfig, params, dcfg: DecodeConfig,
                 mesh=None, data_axes=("data",), executor=None,
                 prompt_cache=None):
        self.cfg = cfg
        self.dcfg = dcfg
        self.executor = executor
        # cross-request chunk store (repro.cache.PrefixKVCache). May be
        # None even in prefix_cache mode: the chunk-aligned prefill then
        # still runs (and the tail refresh still reuses the prompt KV
        # within the request) but nothing is shared across requests.
        self.prompt_cache = prompt_cache
        if dcfg.prefix_cache:
            from repro.models.config import ATTN, ATTN_LOCAL
            assert all(s.mixer in (ATTN, ATTN_LOCAL) for s in cfg.layout), \
                ("prefix_cache needs an attention-only layout (recurrent "
                 "states have no chunkable time axis)")
            if prompt_cache is not None:
                assert prompt_cache.chunk_tokens == dcfg.cache_chunk, \
                    (prompt_cache.chunk_tokens, dcfg.cache_chunk)
        if executor is not None:
            # the placement layer owns the placed params and the mesh;
            # a decoder bound to an executor never touches raw params
            self.params = executor.params
            self.mesh = executor.mesh
            self.data_axes = executor.data_axes
        else:
            self.params = params
            self.mesh = mesh
            self.data_axes = data_axes
        self._fns: Dict[Any, Any] = {}

    # ----------------------------------------------- placement boundary

    def _put_batch(self, arr):
        """Host -> device for a gang-shaped array (dim 0 = batch):
        data-axis sharded via the executor, plain upload without one."""
        if self.executor is None:
            return jnp.asarray(arr)
        return self.executor.put_batch(arr)

    def _alloc_cache(self, batch: int, total_len: int):
        if self.executor is None:
            return init_cache(self.cfg, batch, total_len)
        return self.executor.init_cache(batch, total_len)

    # ------------------------------------------------------ shared pieces

    def _head(self, p):
        return p["embed"].T if self.cfg.tie_embeddings else p["lm_head"]

    def _conf_from_hidden(self, p, h_blk):
        """Fused head path (parallel methods): hidden (B, K, d) ->
        (conf (B, K), toks (B, K)) without a monolithic (B, K, V)
        logits array. Kernel route when use_kernels."""
        cfg = self.cfg
        if self.dcfg.use_kernels:
            return kops.head_confidence_argmax(
                h_blk, self._head(p), mask_id=cfg.mask_token_id,
                logit_softcap=cfg.logit_softcap)
        return sched.head_confidence_and_tokens(
            h_blk, self._head(p), mask_id=cfg.mask_token_id,
            logit_softcap=cfg.logit_softcap)

    def _conf_from_logits(self, blk_logits):
        """Full-vocab path (fixed-schedule methods): ban [MASK], Eq. 4."""
        blk = blk_logits.astype(jnp.float32)
        blk = blk.at[..., self.cfg.mask_token_id].set(-1e30)
        return sched.confidence_and_tokens(blk)

    # ------------------------------------------------------ jitted steps

    def _encode_fn(self):
        if "encode" not in self._fns:
            uk = self.dcfg.use_kernels
            self._fns["encode"] = jax.jit(
                lambda p, toks, pos: apply_model(
                    self.cfg, p, tokens=toks, positions=pos,
                    use_kernels=uk).logits)
        return self._fns["encode"]

    def _prefill_fn(self):
        if "prefill" not in self._fns:
            uk = self.dcfg.use_kernels

            def f(p, toks, pos, cache):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="encode", cache=cache, use_kernels=uk)
                c = out.cache
                if self.executor is not None:
                    # keep pooled buffers sharding-canonical (see the
                    # matching constraint in the fused fn)
                    c = self.executor.constrain_cache(
                        c, toks.shape[0], toks.shape[1])
                return c, out.kv_valid
            self._fns["prefill"] = jax.jit(f)
        return self._fns["prefill"]

    def _refresh_fn(self):
        """Block-start step (paper §3.3): one pass over
        [prefix || current block || (pruned) suffix] that BOTH produces
        the block logits and refreshes the prefix KV cache. Computing the
        prefix KV in the presence of the masked region matches the
        training distribution — a prompt-only prefill does not (it
        measurably degrades small models; see tests/test_decoder.py)."""
        if "refresh" not in self._fns:
            uk = self.dcfg.use_kernels

            def f(p, toks, pos, cache, *, upto):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="encode", cache=cache,
                                  cache_upto=upto, use_kernels=uk)
                return out.logits, out.cache
            self._fns["refresh"] = jax.jit(f, static_argnames=("upto",))
        return self._fns["refresh"]

    def _refresh_ct_fn(self):
        """Parallel-method refresh: same pass, but skip_head + the fused
        head path so only (conf, toks) for the block leave the jit."""
        if "refresh_ct" not in self._fns:
            uk, K = self.dcfg.use_kernels, self.dcfg.block_size

            def f(p, toks, pos, cache, *, upto):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="encode", cache=cache,
                                  cache_upto=upto, skip_head=True,
                                  use_kernels=uk)
                c, t = self._conf_from_hidden(p, out.logits[:, upto:upto + K])
                return c, t, out.cache
            self._fns["refresh_ct"] = jax.jit(f, static_argnames=("upto",))
        return self._fns["refresh_ct"]

    def _step_fn(self):
        if "step" not in self._fns:
            uk = self.dcfg.use_kernels

            def f(p, toks, pos, cache, kv_valid):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="step", cache=cache, kv_valid=kv_valid,
                                  mesh=self.mesh, data_axes=self.data_axes,
                                  use_kernels=uk)
                return out.logits
            self._fns["step"] = jax.jit(f)
        return self._fns["step"]

    def _step_ct_fn(self):
        if "step_ct" not in self._fns:
            uk, K = self.dcfg.use_kernels, self.dcfg.block_size

            def f(p, toks, pos, cache, kv_valid):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="step", cache=cache, kv_valid=kv_valid,
                                  mesh=self.mesh, data_axes=self.data_axes,
                                  skip_head=True, use_kernels=uk)
                return self._conf_from_hidden(p, out.logits[:, :K])
            self._fns["step_ct"] = jax.jit(f)
        return self._fns["step_ct"]

    def _chunk_prefill_fn(self):
        """Prefix-cache prefill pass: one prompt chunk attending to
        [cached prompt prefix || self] (chunk-causal across chunks,
        bidirectional within). The chunk offset arrives as the dynamic
        ``kv_valid`` array, so ONE compiled variant serves every chunk
        of every prompt at a given (batch, chunk) shape. skip_head: the
        prefill only needs KV, never logits."""
        if "chunk_prefill" not in self._fns:
            uk = self.dcfg.use_kernels

            def f(p, toks, pos, cache, kv_valid):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache,
                                  kv_valid=kv_valid, skip_head=True,
                                  use_kernels=uk)
                c = out.cache
                if self.executor is not None:
                    # keep pooled buffers sharding-canonical (see the
                    # matching constraint in the fused fn)
                    c = self.executor.constrain_cache(
                        c, toks.shape[0], toks.shape[1])
                return c
            self._fns["chunk_prefill"] = jax.jit(f)
        return self._fns["chunk_prefill"]

    def _tail_refresh_fn(self):
        """Prefix-cache block refresh (fixed-schedule methods): a pass
        over [generated prefix || query region] ONLY — the prompt KV
        was computed at prefill (possibly assembled from the
        cross-request store) and is attended via ``kv_valid`` instead
        of being recomputed every block."""
        if "tail_refresh" not in self._fns:
            uk = self.dcfg.use_kernels

            def f(p, toks, pos, cache, kv0):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache, kv_valid=kv0,
                                  use_kernels=uk)
                return out.logits, out.cache
            self._fns["tail_refresh"] = jax.jit(f)
        return self._fns["tail_refresh"]

    def _tail_refresh_ct_fn(self):
        """Parallel-method tail refresh: same pass, fused head path so
        only (conf, toks) for the block leave the jit. ``upto`` is the
        in-pass offset of the current block (= generated prefix len)."""
        if "tail_refresh_ct" not in self._fns:
            uk, K = self.dcfg.use_kernels, self.dcfg.block_size

            def f(p, toks, pos, cache, kv0, *, upto):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache, kv_valid=kv0,
                                  skip_head=True, use_kernels=uk)
                c, t = self._conf_from_hidden(p, out.logits[:, upto:upto + K])
                return c, t, out.cache
            self._fns["tail_refresh_ct"] = jax.jit(
                f, static_argnames=("upto",))
        return self._fns["tail_refresh_ct"]

    def _append_fn(self):
        if "append" not in self._fns:
            uk = self.dcfg.use_kernels

            def f(p, toks, pos, cache, kv_valid):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache,
                                  kv_valid=kv_valid, use_kernels=uk)
                return out.cache, out.kv_valid
            self._fns["append"] = jax.jit(f)
        return self._fns["append"]

    def _frozen_refresh_ct_fn(self):
        """HC1 (frozen suffix, parallel methods only): block-start pass
        over [prefix || query] that writes ALL KV position-indexed into
        a T-sized buffer — including the pruned-suffix and trailing mask
        tokens — so steps can attend to frozen suffix KV and query only
        the block."""
        if "frozen_refresh_ct" not in self._fns:
            uk, K = self.dcfg.use_kernels, self.dcfg.block_size

            def f(p, toks, pos, cache, *, upto):
                B = toks.shape[0]
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache,
                                  kv_valid=jnp.zeros((B,), jnp.int32),
                                  append_at=pos,
                                  cache_positions=None, cache_upto=upto,
                                  skip_head=True, use_kernels=uk)
                c, t = self._conf_from_hidden(p, out.logits[:, upto:upto + K])
                return c, t, out.cache
            self._fns["frozen_refresh_ct"] = jax.jit(
                f, static_argnames=("upto",))
        return self._fns["frozen_refresh_ct"]

    def _dkv_step_fn(self):
        if "dkv" not in self._fns:
            uk = self.dcfg.use_kernels

            def f(p, toks, pos, cache, valid_mask, mix):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache,
                                  kv_valid=valid_mask, append_at=pos,
                                  self_kv_mix=mix, use_kernels=uk)
                return out.logits, out.cache
            self._fns["dkv"] = jax.jit(f)
        return self._fns["dkv"]

    # ------------------------------------------------------ resumable API

    @property
    def batch_invariant(self) -> bool:
        """True when per-row outputs are bit-identical regardless of how
        rows are batched — the property the serving scheduler relies on
        to compact/backfill batches without changing generations. Holds
        for every method except dkv, whose step-level KV freezing
        accumulates ulp-level drift across appends under batch
        reshaping (empirically verified in tests/test_serving.py)."""
        return self.dcfg.method != "dkv"

    @property
    def cache_carries_state(self) -> bool:
        """True when the KV buffer holds state a block refresh does NOT
        rewrite — dkv's position-indexed cache, or the prefix-cached
        prompt region. Compaction/merge must then *gather* cache rows;
        any other method adopts whatever right-shaped pool buffer it is
        handed, because the next refresh rewrites it wholesale."""
        return self.dcfg.method == "dkv" or (
            self.dcfg.prefix_cache and self.dcfg.method != "vanilla")

    def jit_cache_size(self) -> int:
        """Total compiled-variant count across this decoder's step fns —
        the serving benchmark asserts it stays bounded by shape buckets
        (no per-request recompilation after warmup)."""
        total = 0
        for f in self._fns.values():
            size = getattr(f, "_cache_size", None)
            if callable(size):
                total += size()
        return total

    def prefill(self, prompt: np.ndarray,
                cache: Any = None) -> DecodeState:
        """Admit a batch of prompts: allocate (or adopt a pooled) KV
        buffer and, for dkv, run the full-sequence prefill pass. The
        returned state sits at block 0 ready for ``decode_block``."""
        cfg, d = self.cfg, self.dcfg
        B, P = prompt.shape
        L, K = d.gen_len, d.block_size
        T = P + L
        x = np.full((B, T), cfg.mask_token_id, np.int32)
        x[:, :P] = prompt
        committed = np.zeros((B, T), bool)
        committed[:, :P] = True
        state = DecodeState(x=x, committed=committed,
                            done=np.zeros((B,), bool), prompt_len=P,
                            n_blocks=L // K)
        if d.method == "vanilla":
            return state
        if cache is not None:
            # a pooled buffer from the wrong shape bucket would only
            # surface later as a cryptic XLA shape error inside the
            # refresh fn — check the batch/length dims up front
            tail = jax.tree.leaves(cache["tail"])
            scan = jax.tree.leaves(cache["scan"])
            if tail:
                assert tail[0].shape[0] == B, (tail[0].shape, B)
                if tail[0].ndim == 4:      # attention KV: (B, T, H, D)
                    assert tail[0].shape[1] == T, (tail[0].shape, T)
            elif scan:                     # scan-stacked: (reps, B, ...)
                assert scan[0].shape[1] == B, (scan[0].shape, B)
                if scan[0].ndim == 5:
                    assert scan[0].shape[2] == T, (scan[0].shape, T)
            state.cache = cache
        else:
            state.cache = self._alloc_cache(B, T)
        if d.prefix_cache:
            # chunk-aligned prompt prefill: assemble the longest
            # cross-request cached prefix, compute only the novel tail.
            # dkv rides the same path — its position-indexed masks mark
            # the prompt valid/frozen exactly as the full-sequence
            # prefill would, but the masked-region pass is skipped
            # (those KV entries were never valid anyway).
            self.prime_prompt_kv(state)
            if d.method == "dkv":
                state.valid_mask = np.zeros((B, T), bool)
                state.valid_mask[:, :P] = True
                state.cached_mask = state.valid_mask.copy()
            return state
        if d.method == "dkv":
            # dKV prefill: one full-sequence pass (prompt + masks),
            # position-indexed cache; only the prompt KV is valid.
            tp0 = time.perf_counter()
            pos = self._put_batch(
                np.broadcast_to(np.arange(T, dtype=np.int32)[None], (B, T)))
            state.cache, _ = self._prefill_fn()(self.params,
                                                self._put_batch(x), pos,
                                                state.cache)
            jax.block_until_ready(jax.tree.leaves(state.cache)[0])
            state.prefill_time = time.perf_counter() - tp0
            state.nfe += 1
            state.host_syncs += 1
            state.q_tokens += B * T
            state.kv_tokens += B * T * T
            state.valid_mask = np.zeros((B, T), bool)
            state.valid_mask[:, :P] = True
            state.cached_mask = state.valid_mask.copy()
        return state

    def prime_prompt_kv(self, state: DecodeState) -> DecodeState:
        """Prefix-cache prompt prefill (the chunk-aligned path): look
        up the longest cached prefix per row, copy/assemble its KV into
        the gang cache, run the model only over the uncached chunks
        plus the unaligned remainder, and publish the freshly computed
        chunks back to the store. Also the re-prime hook for resumed
        (preempted) states, whose parked cache was dropped — their own
        chunks are usually still in the store, so resume costs O(tail).

        Exactness: an assembled chunk carries the bytes its original
        prefill pass wrote, and a computed chunk sees only [assembled
        prefix || its own tokens] — so cached and cold prefill are
        bit-identical by construction (tests/test_cache.py)."""
        d = self.dcfg
        assert d.prefix_cache and d.method != "vanilla"
        assert state.cache is not None
        from repro.cache import slicing
        B, P = state.batch, state.prompt_len
        C = d.cache_chunk
        n_chunks = P // C
        store = self.prompt_cache
        tp0 = time.perf_counter()
        hits: list = [[] for _ in range(B)]
        if store is not None and n_chunks:
            hits = [store.match(state.x[b, :P]) for b in range(B)]
        try:
            # the gang computes chunks from the common hit depth: rows
            # with deeper hits get those chunks recomputed in-batch
            # (bit-equal to their stored values — batch invariance),
            # rows at the min start there. The scheduler's hit-aware
            # admission grouping keeps gangs hit-homogeneous so the min
            # is rarely pessimistic.
            n_hit = min(len(h) for h in hits)
            if n_hit:
                state.cache = slicing.assemble_batch(
                    state.cache,
                    [[n.payload for n in hits[b][:n_hit]]
                     for b in range(B)])
            fn = self._chunk_prefill_fn()
            spans = [(c * C, (c + 1) * C) for c in range(n_hit, n_chunks)]
            if P > n_chunks * C:
                spans.append((n_chunks * C, P))   # unaligned remainder
            for t0, t1 in spans:
                pos = np.broadcast_to(
                    np.arange(t0, t1, dtype=np.int32)[None], (B, t1 - t0))
                state.cache = fn(self.params,
                                 self._put_batch(state.x[:, t0:t1]),
                                 self._put_batch(pos), state.cache,
                                 self._put_batch(np.full((B,), t0,
                                                         np.int32)))
                state.nfe += 1
                state.q_tokens += B * (t1 - t0)
                state.kv_tokens += B * (t1 - t0) * t1
            if spans:
                jax.block_until_ready(jax.tree.leaves(state.cache)[0])
                state.host_syncs += 1
            # publish the chunks this gang computed (above what each
            # row already had cached); rows repeating an earlier row's
            # prompt — pad lanes replicate row 0 — skip the extraction
            # entirely, the store would dedup their nodes anyway
            if store is not None:
                seen: set = set()
                for b in range(B):
                    key = state.x[b, :P].tobytes()
                    start = len(hits[b])
                    if n_chunks > start and key not in seen:
                        kvs = [slicing.extract_row(state.cache, b,
                                                   c * C, (c + 1) * C)
                               for c in range(start, n_chunks)]
                        store.insert(state.x[b, :P], start, kvs,
                                     parent_chain=hits[b])
                    seen.add(key)
        finally:
            # pins must die with this call even if a prefill pass
            # raises — a leaked pin makes its chunk unevictable forever
            if store is not None:
                for h in hits:
                    store.unpin(h)
        state.prefix_hit_tokens = np.full((B,), n_hit * C, np.int32)
        state.prefill_time += time.perf_counter() - tp0
        return state

    def take_rows(self, state: DecodeState, rows, cache: Any = None,
                  alloc_cache: bool = True) -> DecodeState:
        """Extract rows into a standalone state (batch compaction /
        preemption). For dkv the KV rows are gathered (its cache carries
        across blocks); every other method rewrites the cache at the
        next block refresh, so any right-shaped buffer — typically a
        reused one from the PrefixKVPool — serves as the new backing.
        ``alloc_cache=False`` defers the backing buffer entirely (a
        preempted state parked off-slot holds no KV memory); the caller
        must attach one before the next ``decode_block``."""
        rows = list(rows)
        d = self.dcfg
        sub = DecodeState(
            x=state.x[rows].copy(), committed=state.committed[rows].copy(),
            done=state.done[rows].copy(), prompt_len=state.prompt_len,
            n_blocks=state.n_blocks, block_idx=state.block_idx,
            steps_per_block=list(state.steps_per_block))
        if state.prefix_hit_tokens is not None:
            sub.prefix_hit_tokens = state.prefix_hit_tokens[rows].copy()
        if d.method == "dkv":
            # cache_take_rows *gathers* (XLA copies) — the sub-state
            # must never alias buffers of the gang it left: the gang's
            # next fused call may donate them, and a pooled buffer may
            # be handed to another gang while this state is parked
            sub.cache = cache_take_rows(state.cache, rows)
            sub.valid_mask = state.valid_mask[rows].copy()
            sub.cached_mask = state.cached_mask[rows].copy()
        elif self.cache_carries_state:
            # prefix_cache: the prompt KV region must travel with the
            # rows (the tail refresh never rewrites it). A parked state
            # (alloc_cache=False) drops it instead — prime_prompt_kv
            # re-primes on resume, usually from the store.
            if alloc_cache or cache is not None:
                sub.cache = cache_take_rows(state.cache, rows)
        elif d.method != "vanilla":
            if cache is not None:
                sub.cache = cache
            elif alloc_cache:
                sub.cache = self._alloc_cache(len(rows), state.total_len)
        return sub

    def merge_rows(self, parts, cache: Any = None) -> DecodeState:
        """Fuse rows from several states sitting at the SAME block
        boundary into one state (the scheduler's cross-gang straggler
        merge). ``parts`` is a list of ``(state, rows)``. Requires
        ``batch_invariant`` (per-row results don't depend on batching)
        and excludes dkv, whose cache carries across blocks; for every
        other cached method the next block refresh rewrites the cache,
        so any right-shaped buffer (``cache``) serves as backing."""
        assert self.batch_invariant and self.dcfg.method != "dkv"
        ref = parts[0][0]
        for st, _ in parts[1:]:
            assert (st.prompt_len, st.n_blocks, st.block_idx) == \
                (ref.prompt_len, ref.n_blocks, ref.block_idx), \
                "cross-gang merge requires identical (bucket, block) state"
        sub = DecodeState(
            x=np.concatenate([st.x[rows] for st, rows in parts]),
            committed=np.concatenate(
                [st.committed[rows] for st, rows in parts]),
            done=np.concatenate([st.done[rows] for st, rows in parts]),
            prompt_len=ref.prompt_len, n_blocks=ref.n_blocks,
            block_idx=ref.block_idx,
            # per-block step counts diverge across source gangs; keep
            # the elementwise max (metrics-only, like take_rows' copy)
            steps_per_block=[max(vals) for vals in zip(
                *(st.steps_per_block for st, _ in parts))]
            if ref.steps_per_block else [])
        if all(st.prefix_hit_tokens is not None for st, _ in parts):
            sub.prefix_hit_tokens = np.concatenate(
                [st.prefix_hit_tokens[rows] for st, rows in parts])
        if self.cache_carries_state:
            # prefix_cache: gather each part's rows (prompt KV travels)
            # and concatenate along the batch axis (1 for scan-stacked
            # groups, 0 for tail layers — see cache_take_rows)
            gathered = [cache_take_rows(st.cache, rows)
                        for st, rows in parts]
            sub.cache = {
                "scan": jax.tree.map(lambda *xs: jnp.concatenate(xs, 1),
                                     *[g["scan"] for g in gathered]),
                "tail": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                     *[g["tail"] for g in gathered]),
            }
        elif self.dcfg.method != "vanilla":
            sub.cache = cache if cache is not None \
                else self._alloc_cache(sub.batch, ref.total_len)
        return sub

    def row_output(self, state: DecodeState, b: int):
        """Finalized generation for one row: tokens after the prompt,
        truncated at the first EOS (identical to ``finalize`` row b).
        Returns (tokens (gen_len,), n_generated)."""
        return eos_truncate(state.x[b, state.prompt_len:].copy(),
                            self.cfg.eos_token_id)

    # ------------------------------------------------------ block step

    def decode_block(self, state: DecodeState) -> DecodeState:
        """Run the full denoise loop for ``state.block_idx`` and advance
        to the next block boundary (mutates and returns ``state``).
        No-op on a finished state."""
        if state.finished:
            return state
        if self.dcfg.fused:
            return self._decode_block_fused(state)
        return self._decode_block_host(state)

    def _query_region(self, state: DecodeState):
        d = self.dcfg
        region = suffix_query_region(
            gen_start=state.prompt_len, gen_len=d.gen_len,
            block_size=d.block_size, block_idx=state.block_idx,
            window=d.effective_window if d.trailing_position
            else max(d.effective_window, 0))
        qpos = region.positions                       # (Sq,)
        if not d.trailing_position and region.trailing_pos >= 0:
            qpos = qpos[:-1]
        return region, qpos

    # ------------------------------------------------- fused device loop

    def _fused_fn(self):
        """The device-resident per-block denoise loop: refresh (where the
        method has one) + a ``lax.while_loop`` over denoise steps +
        straggler finalize + EOS early exit, compiled as ONE function.
        Specialized per (method, shapes, bstart); the host calls it once
        per block and syncs once on its outputs."""
        if "fused" in self._fns:
            return self._fns["fused"]
        cfg, d = self.cfg, self.dcfg
        eos_id = cfg.eos_token_id   # the [MASK] ban lives in _conf_from_*
        K = d.block_size
        steps_cap = d.steps_per_block or K
        n_commit = max(1, K // steps_cap)
        uk = d.use_kernels
        parallel = d.parallel
        frozen = d.frozen_suffix and parallel

        def commit_tokens(x, committed, conf, toks, bstart):
            """Eq. 9/fixed-rate selection + token write for one step.
            Mirrors the host loop exactly (all rows participate; only
            the loop CONDITION excludes early-exited rows)."""
            B = x.shape[0]
            blk_committed = committed[:, bstart:bstart + K]
            blk_masked = ~blk_committed
            if parallel:
                if d.method == "streaming":
                    r_mask = jnp.mean(blk_masked.astype(jnp.float32), axis=1)
                    tau = sched.dynamic_threshold(d.tau0, d.alpha, r_mask)
                else:
                    tau = jnp.full((B,), d.tau0, jnp.float32)
                commit = sched.select_tokens(conf, blk_masked, tau)
            else:
                commit = sched.fixed_rate_select(conf, blk_masked, n_commit)
            new_blk = jnp.where(commit, toks, x[:, bstart:bstart + K])
            x = x.at[:, bstart:bstart + K].set(new_blk)
            committed = committed.at[:, bstart:bstart + K].set(
                blk_committed | commit)
            return x, committed, commit

        def f(p, x, committed, done, cache, qpos_b, valid_mask, cached_mask,
              *, bstart, pstart):
            B, T = x.shape
            prefix_len = bstart
            vsums = jnp.zeros((steps_cap,), jnp.int32)  # dkv kv-size trace
            # telemetry carries (repro.obs): commits per device step and
            # a confidence histogram of committed tokens — scatter-adds
            # inside the compiled loop, harvested with the block's other
            # outputs, so they cost zero extra host syncs. Only rows
            # live at block start count (done rows' lanes are padding).
            counts = jnp.zeros((steps_cap,), jnp.int32)
            hist = jnp.zeros((CONF_BUCKETS,), jnp.int32)
            # calibration accumulators (repro.obs.audit): per-lane
            # commit-time confidence, plus the last step's confidence
            # map so straggler fills record the value they were forced
            # at. Carried through the while_loop and returned with the
            # block's other outputs — same single host sync.
            cconf = jnp.zeros((B, K), jnp.float32)
            lconf = jnp.zeros((B, K), jnp.float32)
            live = ~done[:, None]

            def tally(counts, hist, step, commit, conf):
                act = commit & live
                counts = counts.at[step].add(
                    jnp.sum(act.astype(jnp.int32)))
                b_idx = jnp.clip((conf * CONF_BUCKETS).astype(jnp.int32),
                                 0, CONF_BUCKETS - 1)
                hist = hist.at[b_idx.ravel()].add(
                    act.ravel().astype(jnp.int32))
                return counts, hist

            def loop_open(committed, step):
                blk_masked = ~committed[:, bstart:bstart + K]
                return ((step < steps_cap)
                        & jnp.any(blk_masked & ~done[:, None]))

            if d.method == "vanilla":
                pos_T = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

                def cond(c):
                    committed, step = c[1], c[2]
                    return loop_open(committed, step)

                def body(c):
                    x, committed, step, _, counts, hist, cconf, _ = c
                    out = apply_model(cfg, p, tokens=x, positions=pos_T,
                                      use_kernels=uk)
                    conf, toks = self._conf_from_logits(
                        out.logits[:, bstart:bstart + K])
                    x, committed, commit = commit_tokens(
                        x, committed, conf, toks, bstart)
                    counts, hist = tally(counts, hist, step, commit, conf)
                    cconf = jnp.where(commit, conf, cconf)
                    return (x, committed, step + 1, toks, counts, hist,
                            cconf, conf)

                init = (x, committed, jnp.int32(0),
                        jnp.zeros((B, K), jnp.int32), counts, hist,
                        cconf, lconf)
                x, committed, steps, toks, counts, hist, cconf, lconf = \
                    jax.lax.while_loop(cond, body, init)

            elif d.method == "dkv":
                def cond(c):
                    _, committed, step = c[0], c[1], c[2]
                    return loop_open(committed, step)

                def body(c):
                    x, committed, step, _, cache, valid_mask, cached_mask, \
                        vsums, counts, hist, cconf, _ = c
                    q_toks = jnp.take_along_axis(x, qpos_b, axis=1)
                    mix = jnp.take_along_axis(cached_mask, qpos_b, axis=1)
                    out = apply_model(cfg, p, tokens=q_toks,
                                      positions=qpos_b, mode="append",
                                      cache=cache, kv_valid=valid_mask,
                                      append_at=qpos_b, self_kv_mix=mix,
                                      use_kernels=uk)
                    conf, toks = self._conf_from_logits(out.logits[:, :K])
                    # tokens committed earlier (whose fresh KV this step
                    # was decoded-input based) are now frozen
                    newly = committed & ~cached_mask
                    cached_mask = cached_mask | newly
                    valid_mask = valid_mask | newly
                    vsums = vsums.at[step].set(
                        jnp.sum(valid_mask.astype(jnp.int32)) // B)
                    x, committed, commit = commit_tokens(
                        x, committed, conf, toks, bstart)
                    counts, hist = tally(counts, hist, step, commit, conf)
                    cconf = jnp.where(commit, conf, cconf)
                    return (x, committed, step + 1, toks, out.cache,
                            valid_mask, cached_mask, vsums, counts, hist,
                            cconf, conf)

                init = (x, committed, jnp.int32(0),
                        jnp.zeros((B, K), jnp.int32), cache,
                        valid_mask, cached_mask, vsums, counts, hist,
                        cconf, lconf)
                (x, committed, steps, toks, cache, valid_mask, cached_mask,
                 vsums, counts, hist, cconf, lconf) = \
                    jax.lax.while_loop(cond, body, init)

            else:
                # prefix / fast / streaming: block-start refresh (paper
                # §3.3) outside the loop — it has a different query shape
                # and is the only step that writes the cache. With
                # prefix_cache the pass starts at the prompt boundary
                # (pstart): the prompt KV was computed at prefill and is
                # attended via kv_valid, never recomputed.
                pref_pos = jnp.broadcast_to(
                    jnp.arange(pstart if d.prefix_cache else 0, prefix_len,
                               dtype=jnp.int32)[None],
                    (B, prefix_len - (pstart if d.prefix_cache else 0)))
                full_pos = jnp.concatenate([pref_pos, qpos_b], axis=1)
                full_toks = jnp.take_along_axis(x, full_pos, axis=1)
                if d.prefix_cache:
                    out = apply_model(cfg, p, tokens=full_toks,
                                      positions=full_pos, mode="append",
                                      cache=cache,
                                      kv_valid=jnp.full((B,), pstart,
                                                        jnp.int32),
                                      skip_head=parallel, use_kernels=uk)
                    valid = jnp.full((B,), prefix_len, jnp.int32)
                elif frozen:
                    out = apply_model(cfg, p, tokens=full_toks,
                                      positions=full_pos, mode="append",
                                      cache=cache,
                                      kv_valid=jnp.zeros((B,), jnp.int32),
                                      append_at=full_pos,
                                      cache_upto=prefix_len, skip_head=True,
                                      use_kernels=uk)
                    valid = jnp.broadcast_to(
                        jnp.arange(T) < prefix_len, (B, T))
                    valid = valid.at[jnp.arange(B)[:, None],
                                     qpos_b[:, K:]].set(True)
                else:
                    out = apply_model(cfg, p, tokens=full_toks,
                                      positions=full_pos, mode="encode",
                                      cache=cache, cache_upto=prefix_len,
                                      skip_head=parallel, use_kernels=uk)
                    valid = jnp.full((B,), prefix_len, jnp.int32)
                cache = out.cache
                boff = prefix_len - pstart if d.prefix_cache else prefix_len
                blk_out = out.logits[:, boff:boff + K]
                if parallel:
                    conf, toks = self._conf_from_hidden(p, blk_out)
                else:
                    conf, toks = self._conf_from_logits(blk_out)
                x, committed, commit = commit_tokens(x, committed, conf,
                                                     toks, bstart)
                counts, hist = tally(counts, hist, 0, commit, conf)
                cconf = jnp.where(commit, conf, cconf)
                lconf = conf

                if frozen:
                    bpos = jnp.broadcast_to(
                        jnp.arange(bstart, bstart + K,
                                   dtype=jnp.int32)[None], (B, K))

                def cond(c):
                    committed, step = c[1], c[2]
                    return loop_open(committed, step)

                def body(c):
                    x, committed, step, _, counts, hist, cconf, _ = c
                    if frozen:
                        out = apply_model(cfg, p,
                                          tokens=x[:, bstart:bstart + K],
                                          positions=bpos, mode="step",
                                          cache=cache, kv_valid=valid,
                                          mesh=self.mesh,
                                          data_axes=self.data_axes,
                                          skip_head=True, use_kernels=uk)
                    else:
                        q_toks = jnp.take_along_axis(x, qpos_b, axis=1)
                        out = apply_model(cfg, p, tokens=q_toks,
                                          positions=qpos_b, mode="step",
                                          cache=cache, kv_valid=valid,
                                          mesh=self.mesh,
                                          data_axes=self.data_axes,
                                          skip_head=parallel,
                                          use_kernels=uk)
                    if parallel:
                        conf, toks = self._conf_from_hidden(
                            p, out.logits[:, :K])
                    else:
                        conf, toks = self._conf_from_logits(
                            out.logits[:, :K])
                    x, committed, commit = commit_tokens(
                        x, committed, conf, toks, bstart)
                    counts, hist = tally(counts, hist, step, commit, conf)
                    cconf = jnp.where(commit, conf, cconf)
                    return (x, committed, step + 1, toks, counts, hist,
                            cconf, conf)

                init = (x, committed, jnp.int32(1), toks, counts, hist,
                        cconf, lconf)
                x, committed, steps, toks, counts, hist, cconf, lconf = \
                    jax.lax.while_loop(cond, body, init)

            # straggler finalize (steps cap reached): commit the last
            # step's argmax — but never overwrite rows that early-exited
            # in a prior block (their tail is EOS-truncated territory)
            blk = x[:, bstart:bstart + K]
            blk_masked = ~committed[:, bstart:bstart + K]
            fill = blk_masked & ~done[:, None] & (steps > 0)
            fill_n = jnp.sum(fill.astype(jnp.int32))
            cconf = jnp.where(fill, lconf, cconf)
            blk = jnp.where(fill, toks, blk)
            x = x.at[:, bstart:bstart + K].set(blk)
            committed = committed.at[:, bstart:bstart + K].set(True)
            # Early exit (paper §3.3): a block that decoded an EOS makes
            # all *subsequent* blocks skippable for that row.
            if d.early_exit:
                hit = jnp.any(blk == eos_id, axis=1) & ~done
                n_hit = jnp.sum(hit.astype(jnp.int32))
                done = done | hit
            else:
                n_hit = jnp.int32(0)
            if self.executor is not None:
                # pin the output cache to the canonical placement so a
                # recycled pool buffer is sharding-identical to a fresh
                # one — without this, every (batch, block) shape traces
                # twice (fresh-path at pre-warm, recycled-path at serve)
                cache = self.executor.constrain_cache(
                    cache, x.shape[0], x.shape[1])
            return (x, committed, done, steps, n_hit, cache,
                    valid_mask, cached_mask, vsums, counts, hist, fill_n,
                    cconf)

        # The fused fn consumes and rewrites the whole cache for every
        # cached method, so its input buffer is dead on entry — donate
        # it where the backend honors donation (executor policy),
        # halving peak KV memory per gang. Never for vanilla (cache is
        # an empty pytree) and never for the host-oracle default path.
        donate = (4,) if (self.executor is not None
                          and self.executor.donate_cache
                          and d.method != "vanilla") else ()
        self._fns["fused"] = jax.jit(f, static_argnames=("bstart", "pstart"),
                                     donate_argnums=donate)
        return self._fns["fused"]

    def _decode_block_fused(self, state: DecodeState) -> DecodeState:
        d = self.dcfg
        t_block = time.perf_counter()
        B, P = state.batch, state.prompt_len
        K = d.block_size
        T = P + d.gen_len
        steps_cap = d.steps_per_block or K
        frozen = d.frozen_suffix and d.parallel

        region, qpos = self._query_region(state)
        Sq = len(qpos)
        qpos_b = np.broadcast_to(qpos[None], (B, Sq)).copy()
        bstart = region.block_start
        prefix_len = bstart

        live_rows = int((~state.done).sum())
        vm = None if state.valid_mask is None \
            else self._put_batch(state.valid_mask)
        cm = None if state.cached_mask is None \
            else self._put_batch(state.cached_mask)
        (x, committed, done, steps, n_hit, cache, vm, cm,
         vsums, counts, hist, fill_n, cconf) = self._fused_fn()(
            self.params, self._put_batch(state.x),
            self._put_batch(state.committed), self._put_batch(state.done),
            state.cache, self._put_batch(qpos_b),
            vm, cm, bstart=bstart,
            pstart=P if d.prefix_cache else 0)

        # the ONE host sync for this block (np.array: writable copies —
        # the scheduler and finalize mutate these buffers in place).
        # The telemetry outputs (counts/hist/fill_n) materialize with
        # the rest of this call's results — no extra sync.
        state.x = np.array(x)
        state.committed = np.array(committed)
        state.done = np.array(done)
        steps = int(steps)
        n_hit = int(n_hit)
        counts = np.asarray(counts)
        hist = np.asarray(hist)
        state.early_exits += n_hit
        state.host_syncs += 1
        state.cache = cache
        if vm is not None:
            state.valid_mask = np.array(vm)
            state.cached_mask = np.array(cm)

        state.steps_per_block.append(steps)
        state.nfe += steps
        if d.method == "vanilla":
            state.q_tokens += steps * B * T
            state.kv_tokens += steps * B * T * T
        elif d.method == "dkv":
            state.q_tokens += steps * B * Sq
            for vs in np.asarray(vsums)[:steps]:
                state.kv_tokens += B * Sq * (int(vs) + Sq)
        elif steps > 0:
            # cached mode: the refresh pass covers only the generated
            # prefix + query (the prompt is attended, not recomputed)
            ref_q = (prefix_len - P if d.prefix_cache else prefix_len) + Sq
            state.q_tokens += B * ref_q
            state.kv_tokens += B * ref_q * (prefix_len + Sq)
            if frozen:
                state.q_tokens += (steps - 1) * B * K
                state.kv_tokens += (steps - 1) * B * K * (prefix_len + Sq + K)
            else:
                state.q_tokens += (steps - 1) * B * Sq
                state.kv_tokens += (steps - 1) * B * Sq * (prefix_len + Sq)
        state.block_idx = region.block_idx + 1
        wall = time.perf_counter() - t_block
        state.block_stats.append(BlockStats(
            method=d.method, block_idx=region.block_idx, batch=B,
            live_rows=live_rows, steps=steps, steps_cap=steps_cap,
            committed_per_step=[int(v) for v in counts[:steps]],
            straggler_fill=int(fill_n),
            conf_hist=[int(v) for v in hist],
            window=Sq, early_exits=n_hit, wall_s=wall,
            commit_conf=np.asarray(cconf, np.float32)))
        state.decode_time += wall
        return state

    # --------------------------------------------------- legacy host loop

    def _decode_block_host(self, state: DecodeState) -> DecodeState:
        """The per-step host loop: every denoise step round-trips
        device->host (confidence/selection in numpy) and re-uploads the
        token buffer. Validation oracle for the fused loop."""
        cfg, d = self.cfg, self.dcfg
        t_block = time.perf_counter()
        B, P = state.batch, state.prompt_len
        L, K = d.gen_len, d.block_size
        T = P + L
        steps_cap = d.steps_per_block or K
        eos_id = cfg.eos_token_id
        frozen = d.frozen_suffix and d.parallel

        x, committed, done = state.x, state.committed, state.done
        cache = state.cache
        valid_mask, cached_mask = state.valid_mask, state.cached_mask
        valid = None
        nfe = q_tokens = kv_tokens = 0

        c = state.block_idx
        region, qpos = self._query_region(state)
        Sq = len(qpos)
        qpos_b = np.broadcast_to(qpos[None], (B, Sq)).copy()
        bstart, bend = region.block_start, region.block_start + K

        prefix_len = bstart
        step = 0
        toks = None
        # telemetry mirror of the fused loop's device-side tally
        live = ~done[:, None]
        live_rows = int((~done).sum())
        committed_per_step: list = []
        conf_hist = np.zeros((CONF_BUCKETS,), np.int64)
        # calibration mirror of the fused loop's cconf/lconf carry
        cconf = np.zeros((B, K), np.float32)
        last_conf = None
        while step < steps_cap:
            blk_masked = ~committed[:, bstart:bend]
            if not (blk_masked & ~done[:, None]).any():
                break
            step += 1
            nfe += 1

            conf_toks = None            # parallel methods: (conf, toks)
            if d.method == "vanilla":
                q_tokens += B * T
                logits = self._encode_fn()(
                    self.params, self._put_batch(x),
                    self._put_batch(np.broadcast_to(
                        np.arange(T, dtype=np.int32)[None], (B, T))))
                blk_logits = logits[:, bstart:bend]
                kv_tokens += B * T * T
            elif d.method == "dkv":
                q_tokens += B * Sq
                q_toks = self._put_batch(x[np.arange(B)[:, None], qpos_b])
                mix = self._put_batch(
                    cached_mask[np.arange(B)[:, None], qpos_b])
                logits, cache = self._dkv_step_fn()(
                    self.params, q_toks, self._put_batch(qpos_b), cache,
                    self._put_batch(valid_mask), mix)
                blk_logits = logits[:, :K]
                # tokens committed earlier (whose fresh KV this step
                # was decoded-input based) are now frozen
                newly_frozen = committed & ~cached_mask
                cached_mask |= newly_frozen
                valid_mask |= newly_frozen
                kv_tokens += B * Sq * (valid_mask.sum() // B + Sq)
            elif step == 1 and d.prefix_cache:
                # prefix-cache tail refresh: [generated prefix || query]
                # only; the prefill-computed prompt KV is attended via
                # kv_valid=P and never recomputed (see _tail_refresh_*)
                upto = prefix_len - P
                q_tokens += B * (upto + Sq)
                full_pos = np.concatenate(
                    [np.arange(P, prefix_len, dtype=np.int32), qpos])
                full_pos = np.broadcast_to(full_pos[None], (B, upto + Sq))
                full_toks = self._put_batch(
                    x[np.arange(B)[:, None], full_pos])
                kv0 = self._put_batch(np.full((B,), P, np.int32))
                if d.parallel:
                    cf, tk, cache = self._tail_refresh_ct_fn()(
                        self.params, full_toks, self._put_batch(full_pos),
                        cache, kv0, upto=upto)
                    conf_toks = (cf, tk)
                else:
                    logits, cache = self._tail_refresh_fn()(
                        self.params, full_toks, self._put_batch(full_pos),
                        cache, kv0)
                    blk_logits = logits[:, upto:upto + K]
                valid = jnp.full((B,), prefix_len, jnp.int32)
                kv_tokens += B * (upto + Sq) * (prefix_len + Sq)
            elif step == 1:
                # block-start refresh (paper §3.3): prefix + query
                # region in one encode; caches the prefix KV (and,
                # with frozen_suffix, the suffix/trailing KV too)
                q_tokens += B * (prefix_len + Sq)
                full_pos = np.concatenate(
                    [np.arange(prefix_len, dtype=np.int32), qpos])
                full_pos = np.broadcast_to(full_pos[None],
                                           (B, prefix_len + Sq))
                full_toks = self._put_batch(
                    x[np.arange(B)[:, None], full_pos])
                if frozen:
                    cf, tk, cache = self._frozen_refresh_ct_fn()(
                        self.params, full_toks, self._put_batch(full_pos),
                        cache, upto=prefix_len)
                    conf_toks = (cf, tk)
                    vb = np.zeros((B, T), bool)
                    vb[:, :prefix_len] = True
                    for pp in qpos[K:]:
                        vb[:, pp] = True
                    valid = self._put_batch(vb)
                elif d.parallel:
                    cf, tk, cache = self._refresh_ct_fn()(
                        self.params, full_toks, self._put_batch(full_pos),
                        cache, upto=prefix_len)
                    conf_toks = (cf, tk)
                    valid = jnp.full((B,), prefix_len, jnp.int32)
                else:
                    logits, cache = self._refresh_fn()(
                        self.params, full_toks, self._put_batch(full_pos),
                        cache, upto=prefix_len)
                    blk_logits = logits[:, prefix_len:prefix_len + K]
                    valid = jnp.full((B,), prefix_len, jnp.int32)
                kv_tokens += B * (prefix_len + Sq) ** 2
            elif frozen:
                q_tokens += B * K
                bpos = np.broadcast_to(
                    np.arange(bstart, bend, dtype=np.int32)[None], (B, K))
                conf_toks = self._step_ct_fn()(
                    self.params, self._put_batch(x[:, bstart:bend]),
                    self._put_batch(bpos), cache, valid)
                kv_tokens += B * K * (prefix_len + Sq + K)
            elif d.parallel:
                q_tokens += B * Sq
                q_toks = self._put_batch(x[np.arange(B)[:, None], qpos_b])
                conf_toks = self._step_ct_fn()(
                    self.params, q_toks, self._put_batch(qpos_b), cache,
                    valid)
                kv_tokens += B * Sq * (prefix_len + Sq)
            else:
                q_tokens += B * Sq
                q_toks = self._put_batch(x[np.arange(B)[:, None], qpos_b])
                logits = self._step_fn()(
                    self.params, q_toks, self._put_batch(qpos_b), cache,
                    valid)
                blk_logits = logits[:, :K]
                kv_tokens += B * Sq * (prefix_len + Sq)

            if conf_toks is not None:
                # parallel methods: only (B, K) conf + tokens cross the
                # host boundary (fused head path; no block logits)
                conf = np.asarray(conf_toks[0])
                toks = np.asarray(conf_toks[1])
                state.host_syncs += 1
            else:
                # fixed-schedule methods: the full (B, K, V) block
                # logits cross to the host every step — the transfer
                # the fused loop eliminates
                blk_np = np.array(blk_logits, np.float32)
                state.host_syncs += 1
                state.logit_syncs += 1
                blk_np[..., cfg.mask_token_id] = -1e30  # never emit [MASK]
                conf, toks = sched.confidence_and_tokens(blk_np)
                conf, toks = np.asarray(conf), np.asarray(toks)

            if d.parallel:
                if d.method == "streaming":
                    r_mask = blk_masked.mean(axis=1, dtype=np.float32)
                    tau = np.asarray(sched.dynamic_threshold(
                        d.tau0, d.alpha, jnp.asarray(r_mask)))
                else:
                    tau = np.full((B,), d.tau0, np.float32)
                commit = np.array(sched.select_tokens(
                    jnp.asarray(conf), jnp.asarray(blk_masked),
                    jnp.asarray(tau)))
            else:
                n_commit = max(1, K // steps_cap)
                commit = np.array(sched.fixed_rate_select(
                    jnp.asarray(conf), jnp.asarray(blk_masked), n_commit))
            sel = np.where(commit)
            x[sel[0], bstart + sel[1]] = toks[sel]
            cconf[sel] = conf[sel]
            last_conf = conf
            committed[:, bstart:bend] |= commit
            act = commit & live
            committed_per_step.append(int(act.sum()))
            b_idx = np.clip((conf * CONF_BUCKETS).astype(np.int32),
                            0, CONF_BUCKETS - 1)
            np.add.at(conf_hist, b_idx[act], 1)

        state.steps_per_block.append(step)

        # finalize block: commit any stragglers (steps cap reached) —
        # rows that early-exited in a prior block keep their tail
        blk_masked = ~committed[:, bstart:bend] & ~done[:, None]
        straggler_fill = int(blk_masked.sum()) if step > 0 else 0
        if blk_masked.any() and toks is not None:
            x[:, bstart:bend] = np.where(blk_masked, toks, x[:, bstart:bend])
            if last_conf is not None:
                cconf = np.where(blk_masked, last_conf, cconf)
        committed[:, bstart:bend] = True
        # Early exit (paper S3.3): a block that decoded an EOS makes
        # all *subsequent* blocks skippable for that row.
        hits_blk = 0
        if d.early_exit:
            hit = (x[:, bstart:bend] == eos_id).any(axis=1) & ~done
            hits_blk = int(hit.sum())
            if hits_blk:
                state.early_exits += hits_blk
                done |= hit

        state.cache = cache
        state.valid_mask = valid_mask
        state.cached_mask = cached_mask
        state.block_idx = c + 1
        state.nfe += nfe
        state.q_tokens += q_tokens
        state.kv_tokens += kv_tokens
        wall = time.perf_counter() - t_block
        state.block_stats.append(BlockStats(
            method=d.method, block_idx=c, batch=B, live_rows=live_rows,
            steps=step, steps_cap=steps_cap,
            committed_per_step=committed_per_step,
            straggler_fill=straggler_fill,
            conf_hist=[int(v) for v in conf_hist],
            window=Sq, early_exits=hits_blk, wall_s=wall,
            commit_conf=cconf))
        state.decode_time += wall
        return state

    # ------------------------------------------------------ main loop

    def finalize(self, state: DecodeState) -> GenerateResult:
        """Aggregate a finished (or early-stopped) state into the
        monolithic GenerateResult: rows truncated at their first EOS."""
        P, L = state.prompt_len, self.dcfg.gen_len
        eos_id = self.cfg.eos_token_id
        gen = state.x[:, P:].copy()
        # truncate each row at first EOS (tokens after EOS don't count)
        tokens_generated = 0
        for b in range(state.batch):
            eos_pos = np.where(gen[b] == eos_id)[0]
            n = eos_pos[0] if len(eos_pos) else L
            tokens_generated += int(n)
            if len(eos_pos):
                gen[b, eos_pos[0]:] = eos_id
        wall = state.prefill_time + state.decode_time
        return GenerateResult(gen, state.nfe, list(state.steps_per_block),
                              wall, state.q_tokens, state.kv_tokens,
                              tokens_generated, state.early_exits,
                              state.prefill_time, state.host_syncs,
                              state.logit_syncs)

    def generate(self, prompt: np.ndarray) -> GenerateResult:
        """Monolithic generation: prefill + every block to completion.
        This is the synchronous (mode="batch") serving path; the
        continuous scheduler in repro.serving drives the same
        prefill/decode_block pair directly and interleaves requests at
        block boundaries."""
        t0 = time.perf_counter()
        state = self.prefill(prompt)
        while not state.finished:
            self.decode_block(state)
        res = self.finalize(state)
        res.wall_time = time.perf_counter() - t0
        return res
