"""Block-wise diffusion decoding — Streaming-dLLM and all paper baselines.

Five methods (paper Tables 1/2/8):

  vanilla   — no cache; full-sequence forward each denoise step; fixed
              schedule (top-`K/M` most-confident masked tokens per step).
  dkv       — delayed KV cache (Ma et al. 2025): a token's K/V is frozen
              into a position-indexed cache one step after it decodes;
              masked tokens recompute theirs each step. Vanilla schedule.
  prefix    — Fast-dLLM's prefix cache: prompt + finished blocks cached;
              the block + FULL suffix recomputed each step. Vanilla
              schedule.
  fast      — Fast-dLLM: prefix cache + fixed-threshold tau0 parallel
              commit (argmax fallback guarantees progress).
  streaming — OURS: prefix cache + attenuation-guided suffix pruning
              (window w + trailing position token) + dynamic threshold
              tau(t) (Eq. 10) + EOS early exit.

The per-step compute is a single jitted function; Python drives blocks /
steps (vLLM-style host scheduler). Query shapes are exact per block, so
the jit cache holds at most #distinct-shapes entries.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched
from repro.core.suffix import suffix_query_region
from repro.models.config import ModelConfig
from repro.models.model import apply_model, init_cache

METHODS = ("vanilla", "dkv", "prefix", "fast", "streaming")


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    method: str = "streaming"
    gen_len: int = 256
    block_size: int = 32
    steps_per_block: int = 0       # 0 -> block_size (one token per step)
    tau0: float = 0.9              # base confidence threshold
    alpha: float = 0.3             # Eq. 10 adaptation strength
    window: int = 96               # suffix tokens kept (streaming); -1=full
    trailing_position: bool = True
    early_exit: bool = True
    use_kernels: bool = False      # route attention/confidence to Pallas
    # Beyond-paper (EXPERIMENTS.md §Perf HC1): freeze the pruned-suffix
    # KV at the block-refresh step and reuse it across the block's
    # denoise iterations (DualCache-inspired). Steps then query only the
    # K block tokens instead of K + w + 1 — ~4x less step compute at the
    # paper's config. The suffix KV is one refresh stale within a block
    # (same approximation class as the prefix cache itself).
    frozen_suffix: bool = False

    def __post_init__(self):
        assert self.method in METHODS, self.method
        assert self.gen_len % self.block_size == 0

    @property
    def effective_window(self) -> int:
        if self.method == "streaming":
            return self.window
        return -1                   # baselines see the full suffix

    @property
    def parallel(self) -> bool:
        return self.method in ("fast", "streaming")


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray             # (B, gen_len) committed tokens
    nfe: int                       # model forward evaluations
    steps_per_block: list
    wall_time: float
    query_tokens_processed: int    # sum of query lengths over all NFEs
    kv_tokens_attended: int        # sum of (kv length * query len) proxy
    tokens_generated: int          # non-EOS tokens (paper's TPS metric)
    early_exits: int
    prefill_time: float = 0.0

    @property
    def tokens_per_nfe(self) -> float:
        return self.tokens_generated / max(self.nfe, 1)


class DiffusionDecoder:
    """Host-driven block diffusion decoder over one compiled step fn."""

    def __init__(self, cfg: ModelConfig, params, dcfg: DecodeConfig,
                 mesh=None, data_axes=("data",)):
        self.cfg = cfg
        self.params = params
        self.dcfg = dcfg
        self.mesh = mesh
        self.data_axes = data_axes
        self._fns: Dict[Any, Any] = {}

    # ------------------------------------------------------ jitted steps

    def _encode_fn(self):
        if "encode" not in self._fns:
            self._fns["encode"] = jax.jit(
                lambda p, toks, pos: apply_model(
                    self.cfg, p, tokens=toks, positions=pos).logits)
        return self._fns["encode"]

    def _prefill_fn(self):
        if "prefill" not in self._fns:
            def f(p, toks, pos, cache):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="encode", cache=cache)
                return out.cache, out.kv_valid
            self._fns["prefill"] = jax.jit(f)
        return self._fns["prefill"]

    def _refresh_fn(self):
        """Block-start step (paper §3.3): one pass over
        [prefix || current block || (pruned) suffix] that BOTH produces
        the block logits and refreshes the prefix KV cache. Computing the
        prefix KV in the presence of the masked region matches the
        training distribution — a prompt-only prefill does not (it
        measurably degrades small models; see tests/test_decoder.py)."""
        if "refresh" not in self._fns:
            def f(p, toks, pos, cache, *, upto):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="encode", cache=cache,
                                  cache_upto=upto)
                return out.logits, out.cache
            self._fns["refresh"] = jax.jit(f, static_argnames=("upto",))
        return self._fns["refresh"]

    def _step_fn(self):
        key = "step"
        if key not in self._fns:
            def f(p, toks, pos, cache, kv_valid):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="step", cache=cache, kv_valid=kv_valid,
                                  mesh=self.mesh, data_axes=self.data_axes)
                return out.logits
            self._fns[key] = jax.jit(f)
        return self._fns[key]

    def _append_fn(self):
        if "append" not in self._fns:
            def f(p, toks, pos, cache, kv_valid):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache,
                                  kv_valid=kv_valid)
                return out.cache, out.kv_valid
            self._fns["append"] = jax.jit(f)
        return self._fns["append"]

    def _frozen_refresh_fn(self):
        """HC1 (frozen suffix): block-start pass over [prefix || query]
        that writes ALL KV position-indexed into a T-sized buffer —
        including the pruned-suffix and trailing mask tokens — so steps
        can attend to frozen suffix KV and query only the block."""
        if "frozen_refresh" not in self._fns:
            def f(p, toks, pos, cache, *, upto):
                B = toks.shape[0]
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache,
                                  kv_valid=jnp.zeros((B,), jnp.int32),
                                  append_at=pos,
                                  cache_positions=None, cache_upto=upto)
                return out.logits, out.cache
            self._fns["frozen_refresh"] = jax.jit(f, static_argnames=("upto",))
        return self._fns["frozen_refresh"]

    def _dkv_step_fn(self):
        if "dkv" not in self._fns:
            def f(p, toks, pos, cache, valid_mask, mix):
                out = apply_model(self.cfg, p, tokens=toks, positions=pos,
                                  mode="append", cache=cache,
                                  kv_valid=valid_mask, append_at=pos,
                                  self_kv_mix=mix)
                return out.logits, out.cache
            self._fns["dkv"] = jax.jit(f)
        return self._fns["dkv"]

    # ------------------------------------------------------ main loop

    def generate(self, prompt: np.ndarray) -> GenerateResult:
        cfg, d = self.cfg, self.dcfg
        B, P = prompt.shape
        L, K = d.gen_len, d.block_size
        T = P + L
        n_blocks = L // K
        steps_cap = d.steps_per_block or K
        mask_id, eos_id = cfg.mask_token_id, cfg.eos_token_id

        x = np.full((B, T), mask_id, np.int32)
        x[:, :P] = prompt
        committed = np.zeros((B, T), bool)
        committed[:, :P] = True
        done = np.zeros((B,), bool)

        nfe = 0
        q_tokens = 0
        kv_tokens = 0
        steps_hist = []
        early_exits = 0
        t0 = time.perf_counter()

        use_cache = d.method != "vanilla"
        frozen = d.frozen_suffix and d.method in ("fast", "streaming")
        cache = valid = valid_mask = cached_mask = None
        prefill_time = 0.0
        if use_cache:
            cache = init_cache(cfg, B, T)
            if d.method == "dkv":
                # dKV prefill: one full-sequence pass (prompt + masks),
                # position-indexed cache; only the prompt KV is valid.
                tp0 = time.perf_counter()
                pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
                cache, _ = self._prefill_fn()(self.params, jnp.asarray(x),
                                              pos, cache)
                jax.block_until_ready(jax.tree.leaves(cache)[0])
                prefill_time = time.perf_counter() - tp0
                nfe += 1
                q_tokens += B * T
                kv_tokens += B * T * T
                valid_mask = np.zeros((B, T), bool)
                valid_mask[:, :P] = True
                cached_mask = valid_mask.copy()

        for c in range(n_blocks):
            if done.all():
                break
            region = suffix_query_region(
                gen_start=P, gen_len=L, block_size=K, block_idx=c,
                window=d.effective_window if d.trailing_position
                else max(d.effective_window, 0))
            qpos = region.positions                       # (Sq,)
            if not d.trailing_position and region.trailing_pos >= 0:
                qpos = qpos[:-1]
            Sq = len(qpos)
            qpos_b = np.broadcast_to(qpos[None], (B, Sq)).copy()
            bstart, bend = region.block_start, region.block_start + K

            prefix_len = bstart
            step = 0
            toks = None
            while step < steps_cap:
                blk_masked = ~committed[:, bstart:bend]
                if not (blk_masked & ~done[:, None]).any():
                    break
                step += 1
                nfe += 1

                q_toks = jnp.asarray(x[np.arange(B)[:, None], qpos_b])
                if d.method == "vanilla":
                    q_tokens += B * T
                    logits = self._encode_fn()(
                        self.params, jnp.asarray(x),
                        jnp.broadcast_to(jnp.arange(T)[None], (B, T)))
                    blk_logits = logits[:, bstart:bend]
                    kv_tokens += B * T * T
                elif d.method == "dkv":
                    q_tokens += B * Sq
                    mix = jnp.asarray(
                        cached_mask[np.arange(B)[:, None], qpos_b])
                    logits, cache = self._dkv_step_fn()(
                        self.params, q_toks, jnp.asarray(qpos_b), cache,
                        jnp.asarray(valid_mask), mix)
                    blk_logits = logits[:, :K]
                    # tokens committed earlier (whose fresh KV this step
                    # was decoded-input based) are now frozen
                    newly_frozen = committed & ~cached_mask
                    cached_mask |= newly_frozen
                    valid_mask |= newly_frozen
                    kv_tokens += B * Sq * (valid_mask.sum() // B + Sq)
                elif step == 1:
                    # block-start refresh (paper §3.3): prefix + query
                    # region in one encode; caches the prefix KV (and,
                    # with frozen_suffix, the suffix/trailing KV too)
                    q_tokens += B * (prefix_len + Sq)
                    full_pos = np.concatenate(
                        [np.arange(prefix_len, dtype=np.int32), qpos])
                    full_pos = np.broadcast_to(full_pos[None],
                                               (B, prefix_len + Sq))
                    full_toks = jnp.asarray(
                        x[np.arange(B)[:, None], full_pos])
                    if frozen:
                        logits, cache = self._frozen_refresh_fn()(
                            self.params, full_toks, jnp.asarray(full_pos),
                            cache, upto=prefix_len)
                        vb = np.zeros((B, T), bool)
                        vb[:, :prefix_len] = True
                        for pp in qpos[K:]:
                            vb[:, pp] = True
                        valid = jnp.asarray(vb)
                    else:
                        logits, cache = self._refresh_fn()(
                            self.params, full_toks, jnp.asarray(full_pos),
                            cache, upto=prefix_len)
                        valid = jnp.full((B,), prefix_len, jnp.int32)
                    blk_logits = logits[:, prefix_len:prefix_len + K]
                    kv_tokens += B * (prefix_len + Sq) ** 2
                elif frozen:
                    q_tokens += B * K
                    bpos = np.broadcast_to(
                        np.arange(bstart, bend, dtype=np.int32)[None], (B, K))
                    logits = self._step_fn()(
                        self.params, jnp.asarray(x[:, bstart:bend]),
                        jnp.asarray(bpos), cache, valid)
                    blk_logits = logits[:, :K]
                    kv_tokens += B * K * (prefix_len + Sq + K)
                else:
                    q_tokens += B * Sq
                    logits = self._step_fn()(
                        self.params, q_toks, jnp.asarray(qpos_b), cache,
                        valid)
                    blk_logits = logits[:, :K]
                    kv_tokens += B * Sq * (prefix_len + Sq)

                blk_np = np.array(blk_logits, np.float32)
                blk_np[..., mask_id] = -1e30  # LLaDA: never emit [MASK]
                conf, toks = sched.confidence_and_tokens(blk_np)
                conf, toks = np.asarray(conf), np.asarray(toks)

                if d.parallel:
                    if d.method == "streaming":
                        r_mask = blk_masked.mean(axis=1)
                        tau = sched.dynamic_threshold(d.tau0, d.alpha, r_mask)
                    else:
                        tau = np.full((B,), d.tau0)
                    commit = np.array(sched.select_tokens(
                        jnp.asarray(conf), jnp.asarray(blk_masked),
                        jnp.asarray(tau)))
                else:
                    n_commit = max(1, K // steps_cap)
                    commit = np.array(sched.fixed_rate_select(
                        jnp.asarray(conf), jnp.asarray(blk_masked), n_commit))
                sel = np.where(commit)
                x[sel[0], bstart + sel[1]] = toks[sel]
                committed[:, bstart:bend] |= commit

            steps_hist.append(step)

            # finalize block: commit any stragglers (steps cap reached)
            blk_masked = ~committed[:, bstart:bend]
            if blk_masked.any() and toks is not None:
                x[:, bstart:bend] = np.where(blk_masked, toks, x[:, bstart:bend])
            committed[:, bstart:bend] = True
            # Early exit (paper §3.3): a block that decoded an EOS makes
            # all *subsequent* blocks skippable for that row.
            if d.early_exit:
                hit = (x[:, bstart:bend] == eos_id).any(axis=1) & ~done
                if hit.any():
                    early_exits += int(hit.sum())
                    done |= hit

        gen = x[:, P:].copy()
        # truncate each row at first EOS (tokens after EOS don't count)
        tokens_generated = 0
        for b in range(B):
            eos_pos = np.where(gen[b] == eos_id)[0]
            n = eos_pos[0] if len(eos_pos) else L
            tokens_generated += int(n)
            if len(eos_pos):
                gen[b, eos_pos[0]:] = eos_id
        wall = time.perf_counter() - t0
        return GenerateResult(gen, nfe, steps_hist, wall, q_tokens,
                              kv_tokens, tokens_generated, early_exits,
                              prefill_time)
