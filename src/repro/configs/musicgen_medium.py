"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048. The EnCodec codec
frontend is a stub: input_specs() provides precomputed conditioning
frame embeddings (frontend_embed_dim=768, e.g. T5 text conditioning +
melody frames); the decoder transformer over the 2048-way codebook is
fully implemented. MusicGen's MHA has n_kv == n_heads (no GQA); 24
heads !% 16 -> the TP plan zero-pads to 32/32 heads (DESIGN.md §5).
"""
from repro.configs.common import smoke_variant
from repro.models.config import GELU, LayerSpec, ModelConfig, register


@register("musicgen-medium")
def musicgen_medium() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", arch_type="audio", n_layers=48,
        d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144, vocab_size=2048,
        pattern=(LayerSpec("attn", GELU),),
        frontend_embed_dim=768, frontend_prefix_len=256)


@register("musicgen-medium-smoke")
def musicgen_medium_smoke() -> ModelConfig:
    return smoke_variant(musicgen_medium(), n_layers=2, n_kv_heads=4)
