"""Tiny CPU-trainable configs for examples / e2e benchmarks."""
from repro.models.config import LayerSpec, ModelConfig, register, MOE


@register("tiny")
def tiny() -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=768, vocab_size=320, block_size=8)


@register("tiny-moe")
def tiny_moe() -> ModelConfig:
    return ModelConfig(
        name="tiny-moe", arch_type="moe", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=0, vocab_size=320, block_size=8,
        pattern=(LayerSpec("attn", MOE),),
        n_experts=4, moe_top_k=2, moe_d_ff=256)


@register("tiny-100m")
def tiny_100m() -> ModelConfig:
    """~100M-param model for the end-to-end training example."""
    return ModelConfig(
        name="tiny-100m", arch_type="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=320, block_size=32)
