"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000. Nemotron's
squared-ReLU MLP approximated by GELU (no gate — matches the 2-matrix
layout; noted in DESIGN.md §7). 24 heads -> TP pads to 32q/16kv.
"""
from repro.configs.common import smoke_variant
from repro.models.config import GELU, LayerSpec, ModelConfig, register


@register("minitron-4b")
def minitron_4b() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", arch_type="dense", n_layers=32, d_model=3072,
        n_heads=24, n_kv_heads=8, d_ff=9216, vocab_size=256_000,
        head_dim=128, pattern=(LayerSpec("attn", GELU),),
        rope_theta=10_000.0)


@register("minitron-4b-smoke")
def minitron_4b_smoke() -> ModelConfig:
    return smoke_variant(minitron_4b(), n_layers=2)
