"""Assigned architecture configs (+ paper backbones + tiny CPU variants).

Each module registers the exact spec-sheet config under its public id
and a ``<id>-smoke`` reduced variant (<=2 pattern periods, d_model<=512,
<=4 experts) exercised by the per-arch CPU smoke tests. Full configs are
only ever lowered via ShapeDtypeStructs in launch/dryrun.py.
"""
from repro.configs import (dream_llada, gemma2_27b, kimi_k2, llava_next_34b,
                           minitron_4b, musicgen_medium, olmoe_1b_7b,
                           phi4_mini, qwen3_32b, recurrentgemma_9b, tiny,
                           xlstm_350m)

ASSIGNED = [
    "xlstm-350m", "musicgen-medium", "recurrentgemma-9b", "minitron-4b",
    "llava-next-34b", "olmoe-1b-7b", "kimi-k2-1t-a32b", "gemma2-27b",
    "phi4-mini-3.8b", "qwen3-32b",
]
