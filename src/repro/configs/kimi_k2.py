"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table)
[arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048, MoE 384e top-8,
vocab=163840. Per the assignment sheet all 61 layers are uniform MoE
with GQA attention (the production model's MLA + first-dense-layer +
shared expert are deviations noted in DESIGN.md §7). At mesh (16,16):
24 local experts/shard (expert parallel over ``data``), expert hidden
2048/16=128 over ``model``; params ~= 8 GB/chip bf16. train_4k keeps
AdamW moments in bf16 to fit 16 GB HBM (optimizer.state_dtype).
"""
from repro.configs.common import smoke_variant
from repro.models.config import MOE, LayerSpec, ModelConfig, register


@register("kimi-k2-1t-a32b")
def kimi_k2() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", arch_type="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, d_ff=0, vocab_size=163_840,
        head_dim=128, pattern=(LayerSpec("attn", MOE),),
        n_experts=384, moe_top_k=8, moe_d_ff=2048,
        rope_theta=50_000.0, remat=True)


@register("kimi-k2-1t-a32b-smoke")
def kimi_k2_smoke() -> ModelConfig:
    return smoke_variant(kimi_k2(), n_layers=2, remat=False)
