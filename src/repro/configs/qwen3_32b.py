"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, per-head
RMS qk-norm, head_dim=128.
"""
from repro.configs.common import smoke_variant
from repro.models.config import SWIGLU, LayerSpec, ModelConfig, register


@register("qwen3-32b")
def qwen3_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", arch_type="dense", n_layers=64, d_model=5120,
        n_heads=64, n_kv_heads=8, d_ff=25600, vocab_size=151_936,
        head_dim=128, pattern=(LayerSpec("attn", SWIGLU),),
        qk_norm=True, rope_theta=1_000_000.0)


@register("qwen3-32b-smoke")
def qwen3_32b_smoke() -> ModelConfig:
    return smoke_variant(qwen3_32b(), n_layers=2)
