"""The paper's own dLLM backbones (for fidelity runs / paper-config
FLOPs accounting): LLaDA-8B (Nie et al. 2025) and Dream-7B (Ye et al.
2025). Both are bidirectional-attention diffusion decoders; Dream is
Qwen2.5-initialized.
"""
from repro.configs.common import smoke_variant
from repro.models.config import SWIGLU, LayerSpec, ModelConfig, register


@register("llada-8b")
def llada_8b() -> ModelConfig:
    return ModelConfig(
        name="llada-8b", arch_type="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=12288, vocab_size=126_464,
        pattern=(LayerSpec("attn", SWIGLU),), block_size=32)


@register("dream-7b")
def dream_7b() -> ModelConfig:
    return ModelConfig(
        name="dream-7b", arch_type="dense", n_layers=28, d_model=3584,
        n_heads=28, n_kv_heads=4, d_ff=18944, vocab_size=152_064,
        head_dim=128, pattern=(LayerSpec("attn", SWIGLU),), block_size=32)


@register("llada-8b-smoke")
def llada_8b_smoke() -> ModelConfig:
    return smoke_variant(llada_8b(), n_layers=2, n_kv_heads=4)
