"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, tied
embeddings. (Phi-4-mini's partial RoPE is applied as full RoPE — noted
in DESIGN.md §7.) 24 heads -> TP pads to 32q/16kv.
"""
from repro.configs.common import smoke_variant
from repro.models.config import SWIGLU, LayerSpec, ModelConfig, register


@register("phi4-mini-3.8b")
def phi4_mini() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b", arch_type="dense", n_layers=32, d_model=3072,
        n_heads=24, n_kv_heads=8, d_ff=8192, vocab_size=200_064,
        head_dim=128, pattern=(LayerSpec("attn", SWIGLU),),
        rope_theta=10_000.0, tie_embeddings=True)


@register("phi4-mini-3.8b-smoke")
def phi4_mini_smoke() -> ModelConfig:
    return smoke_variant(phi4_mini(), n_layers=2)
