"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (kv=16) expert d_ff=1024, MoE 64e top-8,
vocab=50304, qk-norm. Every layer's FFN is MoE. Experts shard over the
``data`` axis (4 local experts/shard at data=16), per-expert hidden over
``model``; suffix pruning shrinks the decode-time dispatch all-to-all.
"""
from repro.configs.common import smoke_variant
from repro.models.config import MOE, LayerSpec, ModelConfig, register


@register("olmoe-1b-7b")
def olmoe_1b_7b() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", arch_type="moe", n_layers=16, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=0, vocab_size=50304,
        pattern=(LayerSpec("attn", MOE),), qk_norm=True,
        n_experts=64, moe_top_k=8, moe_d_ff=1024)


@register("olmoe-1b-7b-smoke")
def olmoe_1b_7b_smoke() -> ModelConfig:
    return smoke_variant(olmoe_1b_7b(), n_layers=2, n_kv_heads=4)
