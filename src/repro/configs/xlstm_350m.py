"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H d_ff=0 vocab=50304. xLSTM[7:1] layout: every 8th
block is sLSTM (the paper's best ratio); d_ff=0 — xLSTM blocks carry
their own up/down projections (factor-2 mLSTM, 4/3 sLSTM), no separate
FFN. Suffix pruning is implicit (block-causal, DESIGN.md §6); the
temporal component of Streaming-dLLM applies unchanged.
"""
from repro.configs.common import smoke_variant
from repro.models.config import (MLSTM, NONE, SLSTM, LayerSpec, ModelConfig,
                                 register)

_PATTERN = tuple([LayerSpec(MLSTM, NONE)] * 7 + [LayerSpec(SLSTM, NONE)])


@register("xlstm-350m")
def xlstm_350m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", arch_type="ssm", n_layers=24, d_model=1024,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
        head_dim=256, pattern=_PATTERN, reps=3)


@register("xlstm-350m-smoke")
def xlstm_350m_smoke() -> ModelConfig:
    return smoke_variant(xlstm_350m(), pattern=(LayerSpec(MLSTM, NONE),
                                                LayerSpec(SLSTM, NONE)),
                         n_layers=2, head_dim=64)
