"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000. Griffin layout:
(recurrent, recurrent, local-attn) x 12 + (recurrent, recurrent) tail.
Local attention window 2048. kv=1 is duplicated to 16 heads under TP
(exact for GQA, DESIGN.md §5). GeGLU approximated by SwiGLU (noted in
DESIGN.md §7). Suffix pruning is implicit for the RG-LRU layers and
explicit for the local-attention layers' query region.
"""
from repro.configs.common import smoke_variant
from repro.models.config import (ATTN_LOCAL, RGLRU, SWIGLU, LayerSpec,
                                 ModelConfig, register)

_R = LayerSpec(RGLRU, SWIGLU)
_A = LayerSpec(ATTN_LOCAL, SWIGLU)


@register("recurrentgemma-9b")
def recurrentgemma_9b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", arch_type="hybrid", n_layers=38,
        d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
        vocab_size=256_000, head_dim=256, lru_width=4096,
        pattern=(_R, _R, _A), reps=12, tail=(_R, _R),
        local_window=2048, tie_embeddings=True, embed_scale=True)


@register("recurrentgemma-9b-smoke")
def recurrentgemma_9b_smoke() -> ModelConfig:
    return smoke_variant(recurrentgemma_9b(), n_layers=3, tail=(),
                         n_kv_heads=1, head_dim=64, local_window=64)
