"""llava-next-34b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 (Yi-34B-style
backbone). The vision tower + projector are a stub: input_specs()
supplies precomputed anyres patch embeddings (frontend_embed_dim=1024,
up to 2880 patches = base 576 + 4 tiles x 576) prepended to the text
tokens; the language decoder that consumes them is fully implemented.
56 heads -> TP pads to 64q/16kv.
"""
from repro.configs.common import smoke_variant
from repro.models.config import SWIGLU, LayerSpec, ModelConfig, register


@register("llava-next-34b")
def llava_next_34b() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", arch_type="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20480, vocab_size=64_000,
        head_dim=128, pattern=(LayerSpec("attn", SWIGLU),),
        rope_theta=5_000_000.0,
        frontend_embed_dim=1024, frontend_prefix_len=2880)


@register("llava-next-34b-smoke")
def llava_next_34b_smoke() -> ModelConfig:
    return smoke_variant(llava_next_34b(), n_layers=2)
