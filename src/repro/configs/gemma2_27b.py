"""gemma2-27b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000. Alternating
(local window 4096, global) attention; attention softcap 50, final
logit softcap 30; query scale 1/sqrt(144) (query_pre_attn_scalar);
tied + scaled embeddings. The native local layers mean the long_500k
serve step only needs the global layers switched to windowed.
"""
from repro.configs.common import smoke_variant
from repro.models.config import (ATTN, ATTN_LOCAL, SWIGLU, LayerSpec,
                                 ModelConfig, register)


@register("gemma2-27b")
def gemma2_27b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", arch_type="dense", n_layers=46, d_model=4608,
        n_heads=32, n_kv_heads=16, d_ff=36864, vocab_size=256_000,
        head_dim=128, pattern=(LayerSpec(ATTN_LOCAL, SWIGLU),
                               LayerSpec(ATTN, SWIGLU)), reps=23,
        local_window=4096, attn_softcap=50.0, logit_softcap=30.0,
        attn_scale=1.0 / 12.0, tie_embeddings=True, embed_scale=True)


@register("gemma2-27b-smoke")
def gemma2_27b_smoke() -> ModelConfig:
    return smoke_variant(gemma2_27b(), n_layers=2, local_window=64,
                         attn_scale=None)
