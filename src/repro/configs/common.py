"""Shared helpers for architecture configs."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


def smoke_variant(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced same-family variant: <=2 pattern periods, d_model<=512,
    <=4 experts, small vocab. Used by the per-arch CPU smoke tests."""
    d = dict(
        name=cfg.name + "-smoke",
        n_layers=len(cfg.pattern),
        reps=0,  # recomputed from n_layers / pattern in __post_init__
        tail=(),
        d_model=min(cfg.d_model, 256),
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=1024,
        frontend_prefix_len=min(cfg.frontend_prefix_len, 16),
        tp=1,
        dtype="float32",
        param_dtype="float32",
        remat=False,
        mask_token_id=0,   # recompute from reduced vocab
        eos_token_id=1,
    )
    if cfg.n_experts:
        d.update(n_experts=4, moe_top_k=2, moe_d_ff=128)
    if cfg.lru_width:
        d.update(lru_width=256)
    d.update(over)
    return dataclasses.replace(cfg, **d)
