"""Recurrent mixers: xLSTM (mLSTM/sLSTM) and RecurrentGemma's RG-LRU.

All are causal scans — under block-wise diffusion they operate in the
block-causal regime (paper §4.4): the distant masked suffix is never
materialized, so the spatial component of Streaming-dLLM is implicit in
the topology, while the temporal component (dynamic confidence decoding)
still applies.

Each mixer exposes
    init_<name>(key, cfg, dtype) -> params
    apply_<name>(cfg, p, x, state=None, return_state=False)
with x: (B, S, d). ``state`` enables chunked/streaming processing (the
decode path: resume from the prefix state, process the current block).

Scans use jax.lax.scan over time. The RG-LRU additionally has an
associative-scan fast path (h_t = a_t h_{t-1} + b_t is linear) used when
``cfg.remat`` is False — one of the TPU-side perf levers recorded in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, rms_norm


# ------------------------------------------------------------- helpers

def _conv1d_init(key, width, channels, dtype):
    scale = 1.0 / math.sqrt(width)
    return (jax.random.normal(key, (width, channels), jnp.float32) * scale).astype(dtype)


def causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (W,C), state: (B,W-1,C)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return out, new_state


# ------------------------------------------------------------- RG-LRU

class RGLRUState(NamedTuple):
    h: jnp.ndarray          # (B, w)
    conv: jnp.ndarray       # (B, W-1, w)


def init_rglru(key, cfg, dtype) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    # Lambda init so a ~ U(0.9, 0.999)^c-ish (Griffin appendix)
    lam = jax.random.uniform(ks[0], (w,), jnp.float32, 0.4, 0.9)
    return {
        "w_in": _dense_init(ks[1], (d, w), d, dtype),
        "w_gate": _dense_init(ks[2], (d, w), d, dtype),
        "w_out": _dense_init(ks[3], (w, d), w, dtype),
        "conv": _conv1d_init(ks[4], cfg.rglru_conv_width, w, dtype),
        "w_a": _dense_init(ks[5], (w, w), w, dtype),
        "w_x": _dense_init(ks[6], (w, w), w, dtype),
        "lam": lam.astype(dtype),
    }


def _rglru_scan(a, b, h0, use_assoc=True):
    """h_t = a_t * h_{t-1} + b_t, time axis 1. a,b: (B,S,w)."""
    if use_assoc:
        # fold h0 into b_0
        b = b.at[:, 0].add(a[:, 0] * h0)
        aa, bb = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], r[0] * l[1] + r[1]),
            (a, b), axis=1)
        return bb, bb[:, -1]
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    hT, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hT


def apply_rglru(cfg, p, x, state: Optional[RGLRUState] = None,
                return_state: bool = False):
    B, S, d = x.shape
    w = p["w_in"].shape[1]
    if state is None:
        state = RGLRUState(jnp.zeros((B, w), jnp.float32),
                           jnp.zeros((B, cfg.rglru_conv_width - 1, w), x.dtype))
    u = x @ p["w_in"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    u, conv_state = causal_conv1d(u, p["conv"], state.conv)
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_x"].astype(jnp.float32))
    c = 8.0
    log_a = -c * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r   # (B,S,w)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    hs, hT = _rglru_scan(a, b, state.h, use_assoc=not cfg.remat)
    y = (hs.astype(x.dtype) * gate) @ p["w_out"]
    if return_state:
        return y, RGLRUState(hT, conv_state)
    return y


# ------------------------------------------------------------- mLSTM

class MLSTMState(NamedTuple):
    C: jnp.ndarray      # (B, H, dk, dv)
    n: jnp.ndarray      # (B, H, dk)
    m: jnp.ndarray      # (B, H)
    conv: jnp.ndarray   # (B, W-1, 2d)


def init_mlstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    di = 2 * d                      # up-projection factor 2 (xLSTM block)
    dk = di // H // 2               # qk dim per head
    dv = di // H                    # value dim per head
    ks = jax.random.split(key, 9)
    return {
        "w_up": _dense_init(ks[0], (d, di), d, dtype),
        "w_z": _dense_init(ks[1], (d, di), d, dtype),
        "conv": _conv1d_init(ks[2], 4, di, dtype),
        "wq": _dense_init(ks[3], (di, H, dk), di, dtype),
        "wk": _dense_init(ks[4], (di, H, dk), di, dtype),
        "wv": _dense_init(ks[5], (di, H, dv), di, dtype),
        "w_i": _dense_init(ks[6], (di, H), di, dtype),
        "w_f": _dense_init(ks[7], (di, H), di, dtype),
        "gn": jnp.zeros((di,), dtype),          # per-channel group-norm scale
        "w_down": _dense_init(ks[8], (di, d), di, dtype),
    }


def apply_mlstm(cfg, p, x, state: Optional[MLSTMState] = None,
                return_state: bool = False):
    B, S, d = x.shape
    H = cfg.n_heads
    di = p["w_up"].shape[1]
    dk, dv = p["wq"].shape[2], p["wv"].shape[2]
    if state is None:
        state = MLSTMState(
            jnp.zeros((B, H, dk, dv), jnp.float32),
            jnp.zeros((B, H, dk), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32),
            jnp.zeros((B, 3, di), x.dtype))
    u = x @ p["w_up"]
    z = x @ p["w_z"]
    c, conv_state = causal_conv1d(u, p["conv"], state.conv)
    c = jax.nn.silu(c)
    q = jnp.einsum("bsd,dhk->bshk", c, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", c, p["wk"]).astype(jnp.float32) / math.sqrt(dk)
    v = jnp.einsum("bsd,dhk->bshk", c, p["wv"]).astype(jnp.float32)
    it = (c @ p["w_i"]).astype(jnp.float32)          # (B,S,H) log input gate
    ft = (c @ p["w_f"]).astype(jnp.float32)          # (B,S,H) log forget gate pre-act

    def step(carry, t):
        C, n, m = carry
        f_log = jax.nn.log_sigmoid(ft[:, t])         # (B,H)
        m_new = jnp.maximum(f_log + m, it[:, t])
        i_p = jnp.exp(it[:, t] - m_new)
        f_p = jnp.exp(f_log + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            k[:, t, :, :, None] * v[:, t, :, None, :])
        n = f_p[..., None] * n + i_p[..., None] * k[:, t]
        num = jnp.einsum("bhkv,bhk->bhv", C, q[:, t])
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q[:, t]))
        h = num / jnp.maximum(den, 1.0)[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(step, (state.C, state.n, state.m),
                                 jnp.arange(S))
    hs = hs.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)  # H*dv == di
    hs = rms_norm(hs, p["gn"], cfg.norm_eps)                  # group-norm-ish
    y = (hs + c) * jax.nn.silu(z)
    y = y @ p["w_down"]
    if return_state:
        return y, MLSTMState(C, n, m, conv_state)
    return y


# ------------------------------------------------------------- sLSTM

class SLSTMState(NamedTuple):
    h: jnp.ndarray   # (B, d)
    c: jnp.ndarray   # (B, d)
    n: jnp.ndarray   # (B, d)
    m: jnp.ndarray   # (B, d)


def init_slstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 9)
    p = {"gn": jnp.zeros((d,), dtype)}
    for i, g in enumerate(["z", "i", "f", "o"]):
        p[f"w_{g}"] = _dense_init(ks[i], (d, d), d, dtype)
        # block-diagonal recurrent matrix, stored per head (H, hd, hd)
        p[f"r_{g}"] = _dense_init(ks[4 + i], (H, hd, hd), hd, dtype)
    p["w_ffn_up"] = _dense_init(ks[8], (d, int(d * 4 / 3)), d, dtype)
    p["w_ffn_down"] = _dense_init(
        jax.random.fold_in(ks[8], 1), (int(d * 4 / 3), d), int(d * 4 / 3), dtype)
    return p


def apply_slstm(cfg, p, x, state: Optional[SLSTMState] = None,
                return_state: bool = False):
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    if state is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = SLSTMState(z, z, z, jnp.full((B, d), -1e30, jnp.float32))
    pre = {g: (x @ p[f"w_{g}"]).astype(jnp.float32) for g in "zifo"}

    def rmat(hprev, g):
        hh = hprev.reshape(B, H, hd)
        return jnp.einsum("bhk,hkj->bhj", hh,
                          p[f"r_{g}"].astype(jnp.float32)).reshape(B, d)

    def step(carry, t):
        h, c, n, m = carry
        zt = jnp.tanh(pre["z"][:, t] + rmat(h, "z"))
        it = pre["i"][:, t] + rmat(h, "i")
        ft = jax.nn.log_sigmoid(pre["f"][:, t] + rmat(h, "f"))
        ot = jax.nn.sigmoid(pre["o"][:, t] + rmat(h, "o"))
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c = f_p * c + i_p * zt
        n = f_p * n + i_p
        h = ot * (c / jnp.maximum(n, 1.0))
        return (h, c, n, m_new), h

    (h, c, n, m), hs = jax.lax.scan(step, tuple(state), jnp.arange(S))
    hs = hs.swapaxes(0, 1).astype(x.dtype)
    hs = rms_norm(hs, p["gn"], cfg.norm_eps)
    y = jax.nn.gelu(hs @ p["w_ffn_up"]) @ p["w_ffn_down"]
    if return_state:
        return y, SLSTMState(h, c, n, m)
    return y
