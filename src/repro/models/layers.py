"""Core neural layers: RMSNorm, RoPE, GQA attention (reference path),
SwiGLU/GELU FFNs.

All layers are pure functions over pytree params. Attention supports the
diffusion access pattern: a (possibly short) query region attending over
``[cached prefix KV || self KV]`` bidirectionally, with optional sliding
window, qk-norm, and logit softcap. Position ids are explicit everywhere
because suffix pruning produces non-contiguous positions (Eq. 7 in the
paper keeps the trailing position id).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.heads import HeadPlan

NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                      # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- init

def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(in_axis_size)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_attention(key, cfg, plan: HeadPlan, dtype) -> dict:
    """Weights at *padded* head counts; padded q heads are zero."""
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    # Place real q heads group-contiguously: group g occupies
    # [0 : q_per_kv_real] within each padded group (rest zero).
    p_real = plan.n_q // plan.n_kv
    real_q = _dense_init(ks[0], (d, plan.n_kv, p_real, hd), d, dtype)
    real_o = _dense_init(ks[1], (plan.n_kv, p_real, hd, d), plan.n_q * hd, dtype)
    n_groups = plan.n_kv + plan.kv_zero_groups
    pp = plan.pad_q // n_groups
    wq = jnp.zeros((d, n_groups, pp, hd), dtype).at[:, :plan.n_kv, :p_real].set(real_q)
    wo = jnp.zeros((n_groups, pp, hd, d), dtype).at[:plan.n_kv, :p_real].set(real_o)
    wq = wq.reshape(d, plan.pad_q, hd)
    wo = wo.reshape(plan.pad_q, hd, d)

    wk_real = _dense_init(ks[2], (d, plan.n_kv, hd), d, dtype)
    wv_real = _dense_init(ks[3], (d, plan.n_kv, hd), d, dtype)
    if plan.kv_zero_groups:
        z = jnp.zeros((d, plan.kv_zero_groups, hd), dtype)
        wk_real = jnp.concatenate([wk_real, z], axis=1)
        wv_real = jnp.concatenate([wv_real, z], axis=1)
    wk = jnp.repeat(wk_real, plan.kv_dup, axis=1)
    wv = jnp.repeat(wv_real, plan.kv_dup, axis=1)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_ffn(key, cfg, kind: str, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"w_gate": _dense_init(ks[0], (d, f), d, dtype),
                "w_up": _dense_init(ks[1], (d, f), d, dtype),
                "w_down": _dense_init(ks[2], (f, d), f, dtype)}
    return {"w_up": _dense_init(ks[0], (d, f), d, dtype),
            "w_down": _dense_init(ks[1], (f, d), f, dtype)}


# ---------------------------------------------------------------- attention

# Above this many score elements per (B*H) the reference path chunks the
# query axis (lax.map) so peak memory is O(chunk x Skv), matching the
# flash-style Pallas kernel it stands in for (EXPERIMENTS.md §Perf #3).
# REPRO_DISABLE_CHUNKING=1 (exact-flops dry-runs) turns chunking off:
# XLA cost analysis counts a lax.map body once, so chunked attention
# under-reports flops by the chunk count.
_SCORE_BUDGET = 32 * 1024 * 1024


def _score_budget():
    import os
    if os.environ.get("REPRO_DISABLE_CHUNKING") == "1":
        return 1 << 60
    return _SCORE_BUDGET


def _attend_chunk(q, k, v, q_pos, kv_pos, kv_mask, *, scale, attn_softcap,
                  window):
    """One query chunk. q: (B,Sq,H,D); kv_mask: (B,Skv) bool or None."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    # K/V stay in storage dtype (bf16 on TPU); dots accumulate in f32 via
    # preferred_element_type — no f32 copy of the (500k-token) cache.
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, Sq, Hkv, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    if attn_softcap:
        scores = softcap(scores, attn_softcap)
    mask = None
    if window:
        dist = jnp.abs(q_pos[:, :, None].astype(jnp.int32)
                       - kv_pos[:, None, :].astype(jnp.int32))  # (B,Sq,Skv)
        mask = dist <= window
    if kv_mask is not None:
        vmask = jnp.broadcast_to(kv_mask[:, None, :], (B, Sq, k.shape[1]))
        mask = vmask if mask is None else (mask & vmask)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attend_ref(q, k, v, *, scale, attn_softcap=0.0, window=0,
               q_pos=None, kv_pos=None, kv_valid=None, kv_mask=None):
    """Reference bidirectional attention (the Pallas-kernel oracle path).

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D). H % Hkv == 0 (GQA).
    window > 0 masks |q_pos - kv_pos| > window (bidirectional local).
    kv_valid: (B,) used length; kv_mask: (B, Skv) explicit validity.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if kv_valid is not None and kv_mask is None:
        idx = jnp.arange(Skv)[None, :]
        kv_mask = idx < jnp.asarray(kv_valid).reshape(-1, 1)
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(Skv)[None], (B, Skv))
    kw = dict(scale=scale, attn_softcap=attn_softcap, window=window)

    chunk = max(128, _score_budget() // max(Skv, 1))
    if Sq <= chunk:
        return _attend_chunk(q, k, v, q_pos, kv_pos, kv_mask, **kw)
    n = -(-Sq // chunk)
    pad = n * chunk - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)))
    qs = q.reshape(B, n, chunk, H, D).swapaxes(0, 1)
    ps = q_pos.reshape(B, n, chunk).swapaxes(0, 1)
    out = jax.lax.map(
        lambda c: _attend_chunk(c[0], k, v, c[1], kv_pos, kv_mask, **kw),
        (qs, ps))
    out = out.swapaxes(0, 1).reshape(B, n * chunk, H, D)
    return out[:, :Sq]


def apply_attention(cfg, p, x, *, q_pos, kv_pos=None, kv_cache=None,
                    kv_valid=None, window=0, return_kv=False,
                    self_kv_override=None, use_kernels=False):
    """GQA attention over [kv_cache || self].

    x: (B, Sq, d). kv_cache: optional (k, v) each (B, P, Hkv, D) with
    positions implicit in kv_pos (length P + Sq when cache present,
    else Sq). ``use_kernels`` routes the attend to the Pallas
    flash-style kernel (``kernels.ops.block_attention``) instead of the
    chunked reference path — same GQA mapping, softcap, window, and KV
    validity semantics.
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if kv_pos is None:
        kv_pos = q_pos
    self_kv_pos = kv_pos[:, -x.shape[1]:]
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, self_kv_pos, cfg.rope_theta)
    if self_kv_override is not None:
        # dKV-Cache: frozen (cached) K/V replace the fresh ones for
        # already-decoded positions within the query region.
        mix, gk, gv = self_kv_override
        m = mix[:, :, None, None]
        k = jnp.where(m, gk.astype(k.dtype), k)
        v = jnp.where(m, gv.astype(v.dtype), v)
    new_kv = (k, v)
    kv_mask = None
    if kv_cache is not None:
        ck, cv = kv_cache
        B, Sq_self = x.shape[0], x.shape[1]
        P = ck.shape[1]
        k = jnp.concatenate([ck.astype(k.dtype), k], axis=1)
        v = jnp.concatenate([cv.astype(v.dtype), v], axis=1)
        if kv_valid is not None:
            # Validity applies to the cache region; self region always
            # valid. kv_valid is either a (B,) used-length or a (B, P)
            # bool mask (position-indexed caches, e.g. the dKV baseline).
            if kv_valid.ndim == 2:
                pad = jnp.ones((B, Sq_self), jnp.bool_)
                kv_mask = jnp.concatenate([kv_valid, pad], axis=1)
            else:
                idx = jnp.arange(P + Sq_self)[None, :]
                kv_mask = (idx < kv_valid.reshape(-1, 1)) | (idx >= P)
    scale = cfg.attn_scale or (1.0 / math.sqrt(cfg.head_dim))
    if use_kernels:
        from repro.kernels import ops as kops
        km = kv_mask if kv_mask is not None \
            else jnp.ones((x.shape[0], k.shape[1]), jnp.bool_)
        out = kops.block_attention(
            q, k, v, q_pos, kv_pos, km, scale=scale,
            softcap=cfg.attn_softcap, window=window).astype(q.dtype)
    else:
        out = attend_ref(q, k, v, scale=scale, attn_softcap=cfg.attn_softcap,
                         window=window, q_pos=q_pos, kv_pos=kv_pos,
                         kv_mask=kv_mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return (out, new_kv) if return_kv else out


# ---------------------------------------------------------------- ffn

def apply_ffn(p, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]
