"""GQA head-padding for tensor parallelism.

Megatron-style TP requires head counts divisible by the TP degree.
Several assigned architectures have 24/56/4 heads with tp=16. We pad to
the smallest semantically-equivalent layout:

  * q heads are zero-padded (zero q/o weights -> the padded heads emit
    exactly zero through the output projection; softmax over zero scores
    is uniform and harmless).
  * kv heads are duplicated (exact for GQA: splitting a group's queries
    among identical kv copies is a no-op) and/or zero-group padded.

``plan_heads`` returns the padded layout; ``models.layers`` builds
weights at the padded sizes with the real sub-block initialized.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HeadPlan:
    n_q: int            # nominal query heads
    n_kv: int           # nominal kv heads
    pad_q: int          # padded query heads (divisible by tp)
    pad_kv: int         # padded kv heads (divisible by tp or == nominal)
    kv_dup: int         # duplication factor applied to each kv head
    kv_zero_groups: int  # zero-padded kv groups appended
    tp: int

    @property
    def group(self) -> int:
        """Padded q heads per padded kv head."""
        return self.pad_q // self.pad_kv


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def plan_heads(n_q: int, n_kv: int, tp: int) -> HeadPlan:
    assert n_q % n_kv == 0, (n_q, n_kv)
    if tp <= 1 or (n_q % tp == 0 and n_kv % tp == 0):
        return HeadPlan(n_q, n_kv, n_q, n_kv, 1, 0, tp)
    p = n_q // n_kv
    if tp % n_kv == 0:
        g_pad, dup = n_kv, tp // n_kv
    elif n_kv % tp == 0:
        g_pad, dup = n_kv, 1
    else:
        g_pad, dup = _ceil_to(n_kv, tp), 1  # append zero groups
    # pad q-per-group so q splits evenly among duplicated kv heads and tp
    pp = p
    while pp % dup != 0 or (g_pad * pp) % tp != 0:
        pp += 1
    return HeadPlan(n_q, n_kv, g_pad * pp, g_pad * dup, dup, g_pad - n_kv, tp)
