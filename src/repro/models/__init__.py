from repro.models.config import (ModelConfig, LayerSpec, get_config,
                                 list_configs, register)
from repro.models.model import (ModelOutput, apply_model, init_cache,
                                init_params)

__all__ = ["ModelConfig", "LayerSpec", "get_config", "list_configs",
           "register", "ModelOutput", "apply_model", "init_cache",
           "init_params"]
