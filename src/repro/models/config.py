"""Model configuration system.

A ModelConfig fully describes a backbone: dimensions, the per-layer
layout (mixer kind + ffn kind), and numeric options (rope, qk-norm,
softcaps, local windows, MoE routing). Configs are plain dataclasses so
they can be constructed programmatically (reduced smoke variants) and
registered by name for the launcher (``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

# Mixer kinds.
ATTN = "attn"              # global bidirectional/causal attention
ATTN_LOCAL = "attn_local"  # sliding-window attention
MLSTM = "mlstm"            # xLSTM matrix-memory LSTM
SLSTM = "slstm"            # xLSTM scalar-memory LSTM
RGLRU = "rglru"            # RecurrentGemma RG-LRU recurrent block

# FFN kinds.
SWIGLU = "swiglu"
GELU = "gelu"
MOE = "moe"
NONE = "none"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = ATTN
    ffn: str = SWIGLU


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads

    # Layer layout: `pattern` repeated `reps` times followed by `tail`.
    # pattern * reps + tail must have length n_layers.
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    reps: int = 0                      # 0 -> n_layers // len(pattern)
    tail: Tuple[LayerSpec, ...] = ()

    # Attention options.
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_softcap: float = 0.0          # 0 disables (gemma2: 50.0)
    logit_softcap: float = 0.0         # final logits (gemma2: 30.0)
    local_window: int = 4096           # for ATTN_LOCAL layers
    attn_scale: Optional[float] = None  # None -> 1/sqrt(head_dim)

    # MoE options.
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                  # per-expert hidden dim
    moe_capacity_factor: float = 1.25
    moe_impl: str = "auto"             # dense | ep | auto
    moe_dispatch_chunk: int = 8192     # tokens per EP dispatch chunk
    moe_2d_dispatch: bool = False      # shard a2a payload over model axis
                                       # (EXPERIMENTS.md §Perf HC3b)

    # Recurrent options.
    rglru_conv_width: int = 4
    lru_width: int = 0                 # 0 -> d_model

    # Embedding / head.
    tie_embeddings: bool = False
    embed_scale: bool = False          # gemma-style sqrt(d_model) scaling
    norm_eps: float = 1e-6

    # Diffusion decoding defaults (paper Table 12: block_size=32).
    block_size: int = 32
    mask_token_id: int = 0             # set per-config; defaults filled below
    eos_token_id: int = 1

    # Modality frontend stub: if >0, inputs may be precomputed embeddings
    # with this feature dim (audio frames / vision patches).
    frontend_embed_dim: int = 0
    frontend_prefix_len: int = 0       # patches/frames prepended at prefill

    # Distribution.
    tp: int = 1                        # tensor-parallel degree (model axis)
    seq_parallel: bool = False         # Megatron-style sequence parallelism:
                                       # residual stream sharded (B, S/model, d)
                                       # between blocks -> psums become
                                       # reduce-scatter + all-gather pairs
                                       # (EXPERIMENTS.md §Perf HC2)
    scan_unroll: int = 1               # lax.scan unroll factor (dry-run
                                       # flops accounting uses full unroll)
    dtype: str = "float32"             # compute dtype
    param_dtype: str = "float32"
    remat: bool = False                # activation checkpointing per layer

    # Long-context policy: force ATTN -> ATTN_LOCAL at serve time
    # (sub-quadratic variant for long_500k on dense archs).
    force_local_attention: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.reps == 0:
            object.__setattr__(self, "reps",
                               (self.n_layers - len(self.tail)) // len(self.pattern))
        assert self.reps * len(self.pattern) + len(self.tail) == self.n_layers, (
            self.name, self.reps, len(self.pattern), len(self.tail), self.n_layers)
        if self.mask_token_id == 0:
            # reserve the last two vocab ids: [MASK] and EOS
            object.__setattr__(self, "mask_token_id", self.vocab_size - 1)
            object.__setattr__(self, "eos_token_id", self.vocab_size - 2)

    # ---- derived ----
    @property
    def layout(self) -> Tuple[LayerSpec, ...]:
        return tuple(self.pattern) * self.reps + tuple(self.tail)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def effective_layout(self, serve_long: bool = False) -> Tuple[LayerSpec, ...]:
        if not (serve_long or self.force_local_attention):
            return self.layout
        return tuple(
            LayerSpec(ATTN_LOCAL, s.ffn) if s.mixer == ATTN else s
            for s in self.layout
        )

    def param_count(self) -> int:
        """Approximate parameter count (nominal, un-padded heads)."""
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for spec in self.layout:
            if spec.mixer in (ATTN, ATTN_LOCAL):
                n += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            elif spec.mixer == MLSTM:
                n += 2 * d * 2 * d + 2 * d * d  # up x2, down (factor-2 block)
            elif spec.mixer == SLSTM:
                n += 4 * d * d + 4 * d * (d // max(self.n_heads, 1))
            elif spec.mixer == RGLRU:
                w = self.lru_width or d
                n += 2 * d * w + w * d + 3 * w
            if spec.ffn in (SWIGLU, GELU):
                mult = 3 if spec.ffn == SWIGLU else 2
                n += mult * d * self.d_ff
            elif spec.ffn == MOE:
                n += self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        n += sum(2 * d for _ in self.layout)  # norms
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: top-k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for s in self.layout if s.ffn == MOE)
        all_exp = moe_layers * self.n_experts * 3 * self.d_model * self.moe_d_ff
        act_exp = moe_layers * self.moe_top_k * 3 * self.d_model * self.moe_d_ff
        return full - all_exp + act_exp


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides) -> ModelConfig:
    if name not in _REGISTRY:
        # import configs package lazily so registration side effects run
        import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_configs() -> Sequence[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
