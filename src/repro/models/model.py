"""Layout-driven transformer backbone.

One model definition serves all 10 assigned architectures: a config's
``pattern × reps + tail`` layout selects per-layer mixers (global/local
attention, mLSTM, sLSTM, RG-LRU) and FFNs (SwiGLU/GELU/MoE/none).
Repeated pattern groups are executed with ``lax.scan`` over stacked
params so the HLO stays compact for the 512-device dry-run compiles.

Three execution modes:
  encode  — full pass over (B, S); optionally emits a KV cache/state
            (the prefill step).
  step    — one diffusion denoise iteration: a query region (current
            block + pruned suffix + trailing token) attends over
            [cache buffer || self]; cache unchanged.
  append  — like step, but commits the query tokens' KV (or recurrent
            state) into the cache (block finalization).

Caches are fixed-size buffers with a ``kv_valid`` (B,) used-length so a
whole generation runs under a single compiled step function.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import recurrent as rec
from repro.models.config import (ATTN, ATTN_LOCAL, GELU, MLSTM, MOE, NONE,
                                 RGLRU, SLSTM, SWIGLU, LayerSpec, ModelConfig)
from repro.models.heads import plan_heads
from repro.models.layers import (_dense_init, apply_attention, apply_ffn,
                                 init_attention, init_ffn, rms_norm, softcap)
from repro.models.moe import apply_moe, init_moe


class ModelOutput(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray
    cache: Any
    kv_valid: Any


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ------------------------------------------------------------- init

def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p: dict = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if spec.mixer in (ATTN, ATTN_LOCAL):
        plan = plan_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
        p["mixer"] = init_attention(ks[0], cfg, plan, dtype)
    elif spec.mixer == MLSTM:
        p["mixer"] = rec.init_mlstm(ks[0], cfg, dtype)
    elif spec.mixer == SLSTM:
        p["mixer"] = rec.init_slstm(ks[0], cfg, dtype)
    elif spec.mixer == RGLRU:
        p["mixer"] = rec.init_rglru(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != NONE:
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = init_moe(ks[1], cfg, dtype) if spec.ffn == MOE \
            else init_ffn(ks[1], cfg, spec.ffn, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = _dtype(cfg.param_dtype)
    k_embed, k_head, k_front, k_layers = jax.random.split(key, 4)
    params: dict = {
        "embed": _dense_init(k_embed, (cfg.vocab_size, cfg.d_model),
                             cfg.d_model, dtype),
        "out_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                        cfg.d_model, dtype)
    if cfg.frontend_embed_dim:
        params["frontend_proj"] = _dense_init(
            k_front, (cfg.frontend_embed_dim, cfg.d_model),
            cfg.frontend_embed_dim, dtype)

    n_pos = len(cfg.pattern)
    keys = jax.random.split(k_layers, cfg.reps * n_pos + len(cfg.tail))
    scan_params = []
    for i, spec in enumerate(cfg.pattern):
        ks = jnp.stack([keys[r * n_pos + i] for r in range(cfg.reps)])
        scan_params.append(jax.vmap(lambda k: init_layer(k, cfg, spec, dtype))(ks))
    params["scan"] = tuple(scan_params)
    params["tail"] = tuple(
        init_layer(keys[cfg.reps * n_pos + j], cfg, spec, dtype)
        for j, spec in enumerate(cfg.tail))
    return params


# ------------------------------------------------------------- caches

def _layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                 dtype):
    if spec.mixer in (ATTN, ATTN_LOCAL):
        plan = plan_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
        shape = (batch, max_len, plan.pad_kv, cfg.head_dim)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if spec.mixer == MLSTM:
        di = 2 * cfg.d_model
        H = cfg.n_heads
        return rec.MLSTMState(
            jnp.zeros((batch, H, di // H // 2, di // H), jnp.float32),
            jnp.zeros((batch, H, di // H // 2), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32),
            jnp.zeros((batch, 3, di), dtype))
    if spec.mixer == SLSTM:
        z = jnp.zeros((batch, cfg.d_model), jnp.float32)
        return rec.SLSTMState(z, z, z, jnp.full_like(z, -1e30))
    if spec.mixer == RGLRU:
        w = cfg.lru_width or cfg.d_model
        return rec.RGLRUState(
            jnp.zeros((batch, w), jnp.float32),
            jnp.zeros((batch, cfg.rglru_conv_width - 1, w), dtype))
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               serve_long: bool = False) -> dict:
    """Empty cache pytree matching the model layout (scan-stacked)."""
    dtype = _dtype(cfg.dtype)
    layout = cfg.effective_layout(serve_long)
    pattern = layout[:len(cfg.pattern)]
    tail = layout[cfg.reps * len(cfg.pattern):]
    scan_caches = tuple(
        jax.tree.map(lambda x: jnp.broadcast_to(x[None], (cfg.reps,) + x.shape),
                     _layer_cache(cfg, spec, batch, max_len, dtype))
        for spec in pattern)
    tail_caches = tuple(_layer_cache(cfg, spec, batch, max_len, dtype)
                        for spec in tail)
    return {"scan": scan_caches, "tail": tail_caches}


def cache_take_rows(cache: dict, rows) -> dict:
    """Gather a sub-batch of a cache pytree: batch is axis 1 for the
    scan-stacked pattern groups (leading axis is reps) and axis 0 for
    tail layers. Used by the serving scheduler to compact a batch when
    some rows finish (only the dKV baseline carries KV across block
    boundaries; the other methods rewrite it at the next refresh)."""
    idx = jnp.asarray(rows, jnp.int32)
    return {
        "scan": jax.tree.map(lambda a: jnp.take(a, idx, axis=1),
                             cache["scan"]),
        "tail": jax.tree.map(lambda a: jnp.take(a, idx, axis=0),
                             cache["tail"]),
    }


# ------------------------------------------------------------- layers

def _write_kv(buf, new, kv_valid):
    """buf: (B, P, H, D); new: (B, S, H, D); kv_valid: (B,) offsets."""
    def upd(b, n, off):
        return jax.lax.dynamic_update_slice_in_dim(b, n, off, axis=0)
    return jax.vmap(upd)(buf, new, kv_valid)


def _write_kv_at(buf, new, idx):
    """Scatter new (B, S, H, D) into buf at per-token slots idx (B, S)."""
    def upd(b, n, i):
        return b.at[i].set(n)
    return jax.vmap(upd)(buf, new, idx)


def apply_layer(cfg, p, spec: LayerSpec, x, *, q_pos, cache, kv_valid,
                mode, cache_positions=None, append_at=None,
                self_kv_mix=None, cache_upto=None, mesh=None,
                data_axes=("data",), use_kernels=False):
    """Returns (y, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    new_cache = cache
    if spec.mixer in (ATTN, ATTN_LOCAL):
        window = cfg.local_window if spec.mixer == ATTN_LOCAL else 0
        if mode == "encode":
            out, kv = apply_attention(cfg, p["mixer"], h, q_pos=q_pos,
                                      window=window, return_kv=True,
                                      use_kernels=use_kernels)
            if cache is not None:
                zero = jnp.zeros((x.shape[0],), jnp.int32)
                new_cache = (_write_kv(cache[0], kv[0].astype(cache[0].dtype), zero),
                             _write_kv(cache[1], kv[1].astype(cache[1].dtype), zero))
        else:
            P_len = cache[0].shape[1]
            if cache_positions is None:
                cache_positions = jnp.broadcast_to(
                    jnp.arange(P_len)[None], (x.shape[0], P_len)).astype(jnp.int32)
            kv_pos = jnp.concatenate([cache_positions, q_pos], axis=1)
            override = None
            if self_kv_mix is not None:
                gk = jax.vmap(lambda b, i: b[i])(cache[0], q_pos)
                gv = jax.vmap(lambda b, i: b[i])(cache[1], q_pos)
                override = (self_kv_mix, gk, gv)
            out, kv = apply_attention(cfg, p["mixer"], h, q_pos=q_pos,
                                      kv_pos=kv_pos, kv_cache=cache,
                                      kv_valid=kv_valid, window=window,
                                      return_kv=True,
                                      self_kv_override=override,
                                      use_kernels=use_kernels)
            if mode == "append":
                if append_at is not None:
                    new_cache = (_write_kv_at(cache[0], kv[0].astype(cache[0].dtype), append_at),
                                 _write_kv_at(cache[1], kv[1].astype(cache[1].dtype), append_at))
                else:
                    new_cache = (_write_kv(cache[0], kv[0].astype(cache[0].dtype), kv_valid),
                                 _write_kv(cache[1], kv[1].astype(cache[1].dtype), kv_valid))
    else:
        apply_fn = {MLSTM: rec.apply_mlstm, SLSTM: rec.apply_slstm,
                    RGLRU: rec.apply_rglru}[spec.mixer]
        if mode == "encode" and cache is None:
            out = apply_fn(cfg, p["mixer"], h)
        elif mode in ("encode", "append") and cache_upto is not None:
            # Block-refresh: the cached recurrent state must be the state
            # at the prefix boundary, not after the (masked) query region
            # — split the scan there (exactness test: test_models.py::
            # test_cached_step_consistency).
            out1, st = apply_fn(cfg, p["mixer"], h[:, :cache_upto],
                                return_state=True)
            out2, _ = apply_fn(cfg, p["mixer"], h[:, cache_upto:],
                               state=st, return_state=True)
            out = jnp.concatenate([out1, out2], axis=1)
            new_cache = st
        else:
            out, st = apply_fn(cfg, p["mixer"], h, state=cache,
                               return_state=True)
            if mode in ("encode", "append"):
                new_cache = st
    x = x + out
    if cfg.seq_parallel:
        x = _seq_shard(x, mesh, data_axes)
    if spec.ffn != NONE:
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.ffn == MOE:
            y, aux = apply_moe(cfg, p["ffn"], h2, mesh=mesh, data_axes=data_axes)
        else:
            y = apply_ffn(p["ffn"], h2, spec.ffn)
        x = x + y
        if cfg.seq_parallel:
            x = _seq_shard(x, mesh, data_axes)
    return x, new_cache, aux


def _seq_shard(x, mesh, data_axes):
    """HC2: constrain the residual stream to (batch, S/model, d). GSPMD
    then lowers each TP output psum into reduce-scatter(+all-gather at
    the next matmul), Megatron-LM sequence parallelism — and the
    between-block elementwise ops (norms, residual adds) run sharded."""
    from jax.sharding import PartitionSpec as P
    if mesh is None or "model" not in mesh.axis_names \
            or x.shape[1] % mesh.shape["model"]:
        return x
    dp = tuple(a for a in data_axes if a in mesh.axis_names) or None
    if dp and len(dp) == 1:
        dp = dp[0]
    return jax.lax.with_sharding_constraint(x, P(dp, "model", None))


# ------------------------------------------------------------- forward

def apply_model(cfg: ModelConfig, params, *, tokens=None, embeds=None,
                prefix_embeds=None,
                positions=None, mode: str = "encode", cache=None,
                kv_valid=None, cache_positions=None, append_at=None,
                self_kv_mix=None, cache_upto=None, serve_long: bool = False,
                mesh=None, data_axes=("data",),
                skip_head: bool = False,
                use_kernels: bool = False) -> ModelOutput:
    """tokens: (B, S) int32 or embeds: (B, S, F|d). positions: (B, S).
    ``use_kernels`` routes attention layers to the Pallas block kernel
    (decode path; the reference path remains the training/autodiff
    route)."""
    dtype = _dtype(cfg.dtype)
    if tokens is not None:
        x = params["embed"][tokens].astype(dtype)
        B, S = tokens.shape
    else:
        e = embeds.astype(dtype)
        if cfg.frontend_embed_dim and e.shape[-1] == cfg.frontend_embed_dim:
            e = e @ params["frontend_proj"].astype(dtype)
        x = e
        B, S = x.shape[0], x.shape[1]
    if prefix_embeds is not None:
        # Modality-frontend stub (DESIGN.md §6): precomputed patch/frame
        # embeddings projected and prepended to the token embeddings.
        pe = prefix_embeds.astype(dtype)
        if cfg.frontend_embed_dim and pe.shape[-1] == cfg.frontend_embed_dim:
            pe = pe @ params["frontend_proj"].astype(dtype)
        x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    positions = positions.astype(jnp.int32)
    if kv_valid is None:
        kv_valid = jnp.zeros((B,), jnp.int32)
    kv_valid = jnp.asarray(kv_valid)
    if kv_valid.ndim < 2:
        kv_valid = jnp.broadcast_to(kv_valid.astype(jnp.int32), (B,))

    layout = cfg.effective_layout(serve_long)
    n_pos = len(cfg.pattern)
    pattern = layout[:n_pos]
    tail_specs = layout[cfg.reps * n_pos:]

    scan_caches = cache["scan"] if cache is not None else ()
    have_cache = cache is not None

    def body(carry, xs):
        xc, auxc = carry
        p_i, c_i = xs
        new_cs = []
        for pos, spec in enumerate(pattern):
            def layer_fn(p_l, xc_, cache_, *, _spec=spec):
                return apply_layer(cfg, p_l, _spec, xc_, q_pos=positions,
                                   cache=cache_, kv_valid=kv_valid,
                                   mode=mode,
                                   cache_positions=cache_positions,
                                   append_at=append_at,
                                   self_kv_mix=self_kv_mix,
                                   cache_upto=cache_upto, mesh=mesh,
                                   data_axes=data_axes,
                                   use_kernels=use_kernels)
            if cfg.remat:
                layer_fn = jax.checkpoint(layer_fn)
            xc, nc, a = layer_fn(p_i[pos], xc,
                                 c_i[pos] if have_cache else None)
            new_cs.append(nc)
            auxc = auxc + a
        if mode == "step":
            # cache is unchanged in step mode — returning it as scan ys
            # would allocate a full cache copy (EXPERIMENTS.md §Perf #1)
            return (xc, auxc), ()
        return (xc, auxc), tuple(new_cs)

    aux = jnp.zeros((), jnp.float32)
    if cfg.reps > 0:
        if have_cache:
            xs = (params["scan"], scan_caches)
        else:
            dummy = tuple(jnp.zeros((cfg.reps,)) for _ in pattern)
            xs = (params["scan"], dummy)
        (x, aux), new_scan = jax.lax.scan(body, (x, aux), xs,
                                          unroll=min(cfg.scan_unroll,
                                                     cfg.reps))
    else:
        new_scan = ()

    new_tail = []
    for j, spec in enumerate(tail_specs):
        x, nc, a = apply_layer(cfg, params["tail"][j], spec, x,
                               q_pos=positions,
                               cache=cache["tail"][j] if have_cache else None,
                               kv_valid=kv_valid, mode=mode,
                               cache_positions=cache_positions,
                               append_at=append_at,
                               self_kv_mix=self_kv_mix,
                               cache_upto=cache_upto, mesh=mesh,
                               data_axes=data_axes,
                               use_kernels=use_kernels)
        aux = aux + a
        new_tail.append(nc)

    x = rms_norm(x, params["out_norm"], cfg.norm_eps)
    if skip_head:
        logits = x  # final hidden states; caller owns the head projection
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = softcap(logits, cfg.logit_softcap)

    new_cache = None
    if have_cache and mode != "step":
        new_cache = {"scan": new_scan, "tail": tuple(new_tail)}
    if kv_valid.ndim == 2:  # bool-mask caches are managed by the caller
        new_valid = kv_valid
    else:
        new_valid = kv_valid + (S if mode in ("encode", "append") else 0)
    return ModelOutput(logits, aux, new_cache, new_valid)
