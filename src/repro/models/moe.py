"""Mixture-of-Experts layer: top-k routing with two execution paths.

``dense``  — every expert computed for every token, combined with top-k
             gates. Exact; used for smoke tests and as the oracle in the
             EP-equivalence tests.
``ep``     — expert-parallel: experts sharded over the ``data`` mesh axis,
             per-expert hidden dim over ``model``. Tokens are dispatched
             with a fixed-capacity all_to_all (shard_map), grouped-matmul'd
             on the owning shard (sort-based packing, no one-hot dispatch
             einsum — keeps the roofline honest), and combined with a
             second all_to_all. Capacity overflow drops tokens (counted).

Suffix pruning (the paper's spatial component) directly shrinks the
token count entering this dispatch during decode — the all-to-all bytes
scale with the query region size, which is one of the roofline terms we
track per MoE arch.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _dense_init

if hasattr(jax, "shard_map"):  # jax >= 0.6
    _shard_map = jax.shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
else:  # jax 0.4.x: experimental namespace, check_rep instead of check_vma
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_rep": False}


def init_moe(key, cfg, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, E), d, jnp.float32),
        "w_gate": _dense_init(ks[1], (E, d, f), d, dtype),
        "w_up": _dense_init(ks[2], (E, d, f), d, dtype),
        "w_down": _dense_init(ks[3], (E, f, d), f, dtype),
    }


def _route(cfg, p, x2d):
    """x2d: (T, d) -> (probs (T,E) f32, topk weights (T,k), topk ids (T,k))."""
    logits = x2d.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return probs, w, ids


def _balance_stats(cfg, probs, ids):
    """Per-token routing statistics: (f_e assignment fractions,
    P_e mean router probs), each (E,). Linear in tokens, so they can be
    averaged across shards/chunks and recombined exactly."""
    E = cfg.n_experts
    f = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=(0, 1))
    pm = jnp.mean(probs, axis=0)
    return f, pm


def load_balance_loss(cfg, probs, ids) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    f, pm = _balance_stats(cfg, probs, ids)
    return cfg.n_experts * jnp.sum(f * pm)


def _expert_ffn(xe, wg, wu, wd):
    """xe: (E, C, d); weights (E, d, f)/(E, f, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


# ------------------------------------------------------------- dense path

def apply_moe_dense(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    probs, w, ids = _route(cfg, p, x2)
    # all-experts compute: (E, T, d)
    xe = jnp.broadcast_to(x2[None], (cfg.n_experts,) + x2.shape)
    ye = _expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"])   # (E, T, d)
    onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)  # (T,k,E)
    comb = jnp.einsum("tke,tk->te", onehot, w)                      # (T,E)
    y = jnp.einsum("etd,te->td", ye.astype(jnp.float32), comb)
    aux = load_balance_loss(cfg, probs, ids)
    return y.reshape(B, S, d).astype(x.dtype), aux


# ------------------------------------------------------------- ranks

def _rank_within(keys: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """For int keys (A,), rank of each element among equal keys (stable)."""
    A = keys.shape[0]
    order = jnp.argsort(keys, stable=True)
    sorted_keys = keys[order]
    starts = jnp.searchsorted(sorted_keys, jnp.arange(n_groups), side="left")
    rank_sorted = jnp.arange(A) - starts[sorted_keys]
    return jnp.zeros((A,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


# ------------------------------------------------------------- EP path

def _moe_local(cfg, p, x, n_shards, axis, model_axis, n_model: int = 1):
    """Runs per-shard inside shard_map. x: (T_loc, d) local tokens.

    Router is replicated (d, E). Returns (y (T_loc, d), f_e, p_e,
    dropped count).

    Two dispatch layouts (EXPERIMENTS.md §Perf HC3):
      1D (default): full-d activations dispatched over ``data``; expert
         weights (E_loc, d, f_loc) with f over ``model``. The model-axis
         psum runs AFTER the return all_to_all and combine, on the
         (T_loc, d) token outputs rather than the (E_loc, Ce, d) expert
         buffers — linear ops commute, ~12x smaller psum.
      2D (cfg.moe_2d_dispatch): every model shard dispatches only its
         d/n_model activation slice (the 1D layout sends identical
         full-d copies down every model column); expert weights
         (E_loc, d_loc, f) with d over ``model``; one f-sized psum
         before the nonlinearity; w_down emits exact d/n_model slices
         that return via all_to_all and all_gather. a2a bytes / device
         drop by n_model.
    """
    T, d = x.shape
    k = cfg.moe_top_k
    E = cfg.n_experts
    E_loc = p["w_gate"].shape[0]
    probs, w, ids = _route(cfg, p, x)

    # -------- dispatch
    A = T * k
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)         # (A,)
    eid = ids.reshape(A).astype(jnp.int32)
    wgt = w.reshape(A)
    dest = eid // E_loc                                          # owning shard
    C = max(1, int(math.ceil(A / n_shards * cfg.moe_capacity_factor)))
    rank = _rank_within(dest, n_shards)
    slot = dest * C + rank
    valid = rank < C
    slot = jnp.where(valid, slot, n_shards * C)                 # drop slot
    two_d = cfg.moe_2d_dispatch and n_model > 1
    if two_d:
        d_loc = d // n_model
        j = jax.lax.axis_index(model_axis)
        x_send = jax.lax.dynamic_slice_in_dim(x, j * d_loc, d_loc, axis=1)
    else:
        d_loc = d
        x_send = x
    buf = jnp.zeros((n_shards * C + 1, d_loc), x.dtype).at[slot].set(
        x_send[tok])
    ebuf = jnp.full((n_shards * C + 1,), E_loc, jnp.int32).at[slot].set(eid % E_loc)
    vbuf = jnp.zeros((n_shards * C + 1,), jnp.bool_).at[slot].set(valid)
    sent = buf[:-1].reshape(n_shards, C, d_loc)
    sent_e = ebuf[:-1].reshape(n_shards, C)
    sent_v = vbuf[:-1].reshape(n_shards, C)

    recv = jax.lax.all_to_all(sent, axis, 0, 0, tiled=True)      # (G, C, dl)
    recv_e = jax.lax.all_to_all(sent_e, axis, 0, 0, tiled=True)
    recv_v = jax.lax.all_to_all(sent_v, axis, 0, 0, tiled=True)

    # -------- grouped expert compute (sort-based packing)
    R = n_shards * C
    rx = recv.reshape(R, d_loc)
    re = jnp.where(recv_v.reshape(R), recv_e.reshape(R), E_loc)  # invalid -> E_loc
    Ce = max(1, int(math.ceil(R / E_loc * cfg.moe_capacity_factor)))
    rrank = _rank_within(re, E_loc + 1)
    pos = re * Ce + rrank
    ok = (re < E_loc) & (rrank < Ce)
    pos = jnp.where(ok, pos, E_loc * Ce)
    xe = jnp.zeros((E_loc * Ce + 1, d_loc), x.dtype).at[pos].set(rx)
    xe = xe[:-1].reshape(E_loc, Ce, d_loc)
    if two_d:
        # weights are (E_loc, d_loc, f): partial contraction over the
        # local d slice, one f-sized psum before the nonlinearity
        hg = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]),
                          model_axis)
        hu = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xe, p["w_up"]),
                          model_axis)
        h = jax.nn.silu(hg) * hu
        # w_down (E_loc, f, d_loc): exact local d slice, no psum
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    else:
        ye = _expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"])
        # NOTE: partial over model (f_loc contraction). The psum runs
        # after the return a2a + combine (linear ops commute) on the
        # (T, d) outputs — ~12x less psum traffic than on (E, Ce, d)
        # expert buffers (§Perf HC3a).
    yflat = jnp.concatenate(
        [ye.reshape(E_loc * Ce, d_loc), jnp.zeros((1, d_loc), ye.dtype)],
        axis=0)
    back = jnp.where(ok[:, None], yflat[pos], 0.0).reshape(n_shards, C, d_loc)

    ret = jax.lax.all_to_all(back, axis, 0, 0, tiled=True)       # (G, C, dl)
    rflat = jnp.concatenate(
        [ret.reshape(n_shards * C, d_loc), jnp.zeros((1, d_loc), ret.dtype)],
        axis=0)
    contrib = rflat[slot] * wgt[:, None].astype(ret.dtype)       # (A, dl)
    y = jnp.zeros((T, d_loc), jnp.float32).at[tok].add(
        jnp.where(valid[:, None], contrib, 0.0).astype(jnp.float32))
    if two_d:
        y = jax.lax.all_gather(y, model_axis, axis=1, tiled=True)  # (T, d)
    else:
        y = jax.lax.psum(y, model_axis)                          # HC3a

    f_e, p_e = _balance_stats(cfg, probs, ids)
    dropped = jax.lax.psum(jnp.sum(~valid) + jnp.sum(recv_v.reshape(R) & ~ok),
                           axis)
    return y.astype(x.dtype), f_e, p_e, dropped


def apply_moe_ep(cfg, p, x, mesh, *, data_axes=("data",), model_axis="model"):
    """x: (B, S, d) global array, batch sharded over data_axes. Experts
    shard over the innermost data axis. Dispatch runs in token chunks
    (``moe_dispatch_chunk``) so the a2a buffers stay bounded at large
    global batch (1M tokens x top-8 x d=7168 would otherwise need
    ~9 GB/device of dispatch buffers — see EXPERIMENTS.md §Perf)."""
    B, S, d = x.shape
    axis = data_axes[-1]
    n_shards = mesh.shape[axis]
    n_model = mesh.shape.get(model_axis, 1)
    two_d = cfg.moe_2d_dispatch and n_model > 1 and d % n_model == 0
    batch_spec = P(data_axes if len(data_axes) > 1 else data_axes[0], None, None)
    if two_d:
        wspec = P(axis, model_axis, None)
        dspec = P(axis, None, model_axis)
    else:
        wspec = P(axis, None, model_axis)
        dspec = P(axis, model_axis, None)
    pspec = {"router": P(None, None), "w_gate": wspec, "w_up": wspec,
             "w_down": dspec}

    def local(x_l, p_l):
        import os
        T = x_l.shape[0] * x_l.shape[1]
        x2 = x_l.reshape(T, d)
        nm = n_model if two_d else 1
        chunk = cfg.moe_dispatch_chunk
        if os.environ.get("REPRO_DISABLE_CHUNKING") == "1":
            chunk = 0  # exact-flops dry-runs (see layers._score_budget)
        if chunk and T > chunk and T % chunk == 0:
            def f(xc):
                return _moe_local(cfg, p_l, xc, n_shards, axis, model_axis,
                                  n_model=nm)
            ys, fs, ps, drops = jax.lax.map(f, x2.reshape(T // chunk, chunk, d))
            y, f_e, p_e, drop = (ys.reshape(T, d), fs.mean(0), ps.mean(0),
                                 drops.sum())
        else:
            y, f_e, p_e, drop = _moe_local(cfg, p_l, x2, n_shards, axis,
                                           model_axis, n_model=nm)
        # exact global aux: average the linear statistics across shards
        # FIRST, then combine (equals the dense single-host value)
        f_e = jax.lax.pmean(f_e, data_axes)
        p_e = jax.lax.pmean(p_e, data_axes)
        aux = cfg.n_experts * jnp.sum(f_e * p_e)
        return y.reshape(x_l.shape), aux, drop

    y, aux, drop = _shard_map(
        local, mesh=mesh,
        in_specs=(batch_spec, pspec),
        out_specs=(batch_spec, P(), P()),
        **_SHARD_MAP_NOCHECK,
    )(x, p)
    return y, aux


def apply_moe_ep_replicated(cfg, p, x, mesh, *, ep_axis="data",
                            model_axis="model"):
    """Replicated-token expert parallelism for tiny query regions
    (long_500k decode, batch=1): every shard computes its local experts
    for ALL tokens, gates zero out non-chosen experts, and a psum over
    (data, model) combines. No all-to-all; overhead E_local/top_k on a
    tiny T — the right trade at batch 1 (DESIGN.md §5)."""
    B, S, d = x.shape
    wspec = P(ep_axis, None, model_axis)
    pspec = {"router": P(None, None), "w_gate": wspec, "w_up": wspec,
             "w_down": P(ep_axis, model_axis, None)}

    def local(x_l, p_l):
        T = B * S
        x2 = x_l.reshape(T, d)
        probs, w, ids = _route(cfg, p_l, x2)
        E_loc = p_l["w_gate"].shape[0]
        off = jax.lax.axis_index(ep_axis) * E_loc
        onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)
        comb = jnp.einsum("tke,tk->te", onehot, w)            # (T, E)
        comb_loc = jax.lax.dynamic_slice_in_dim(comb, off, E_loc, axis=1)
        xe = jnp.broadcast_to(x2[None], (E_loc,) + x2.shape)
        ye = _expert_ffn(xe, p_l["w_gate"], p_l["w_up"], p_l["w_down"])
        y = jnp.einsum("etd,te->td", ye.astype(jnp.float32), comb_loc)
        y = jax.lax.psum(y, (ep_axis, model_axis))
        aux = load_balance_loss(cfg, probs, ids)
        return y.reshape(x_l.shape).astype(x_l.dtype), aux

    y, aux = _shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None, None), pspec),
        out_specs=(P(None, None, None), P()),
        **_SHARD_MAP_NOCHECK,
    )(x, p)
    return y, aux


def apply_moe(cfg, p, x, mesh=None, data_axes=("data",)):
    if mesh is not None and cfg.moe_impl in ("ep", "auto"):
        if not data_axes:
            if "data" in mesh.axis_names and mesh.shape["data"] > 1:
                return apply_moe_ep_replicated(cfg, p, x, mesh)
        elif mesh.shape.get(data_axes[-1], 1) > 1:
            return apply_moe_ep(cfg, p, x, mesh, data_axes=data_axes)
    return apply_moe_dense(cfg, p, x)
