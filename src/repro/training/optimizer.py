"""Pure-JAX optimizers (no optax): AdamW with optional low-precision
moment states (used by the trillion-param kimi-k2 training config to fit
the 16 GB/chip HBM budget — see DESIGN.md §7), plus grad clipping and a
warmup-cosine schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: str = "float32"    # "bfloat16" for memory-tight configs
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(cfg: AdamWConfig, params) -> AdamWState:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState,
                 params) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        update = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
