"""Masked-diffusion training objective (LLaDA, Nie et al. 2025).

Forward process: sample t ~ U(eps, 1) per example; independently mask
each (loss-eligible) token with probability t. Reverse model predicts
the original token at masked positions under *bidirectional* attention.
Loss = cross-entropy at masked positions, importance-weighted by 1/t —
the ELBO weighting of masked discrete diffusion.

``loss_mask`` restricts masking to the answer region (SFT-style); for
pretraining pass all-True.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import softcap
from repro.models.model import apply_model


def chunked_ce(cfg: ModelConfig, params, hidden, tokens, weights,
               chunk: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Streamed cross-entropy: projects hidden -> logits one sequence
    chunk at a time so the (B, S, V) logits tensor is never materialized
    (essential at vocab 256k x 1M tokens — see EXPERIMENTS.md §Perf).

    Returns (sum of weighted nll, sum of weighted argmax-correct).
    """
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    B, S, D = hidden.shape
    n = max(1, -(-S // chunk))
    pad = n * chunk - S
    if pad:  # zero-weight padding contributes nothing to either sum
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        tokens = jnp.pad(tokens, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
        S = S + pad
    hs = hidden.reshape(B, n, S // n, D).swapaxes(0, 1)
    ts = tokens.reshape(B, n, S // n).swapaxes(0, 1)
    ws = weights.reshape(B, n, S // n).swapaxes(0, 1)

    def one(c):
        h, t, w = c
        logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = softcap(logits, cfg.logit_softcap)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tok_logit = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = ((lse - tok_logit) * w).sum()
        correct = ((jnp.argmax(logits, -1) == t) * w).sum()
        return nll, correct

    nll, correct = jax.lax.map(one, (hs, ts, ws))
    return nll.sum(), correct.sum()


def diffusion_loss(cfg: ModelConfig, params, tokens, loss_mask, rng,
                   *, aux_weight: float = 0.01, mesh=None,
                   data_axes=("data",)) -> Tuple[jnp.ndarray, dict]:
    B, S = tokens.shape
    k_t, k_mask = jax.random.split(rng)
    t = jax.random.uniform(k_t, (B, 1), minval=0.05, maxval=1.0)
    mask = (jax.random.uniform(k_mask, (B, S)) < t) & loss_mask
    # guarantee at least one masked position per row (degenerate rows
    # otherwise contribute no signal)
    none = ~jnp.any(mask, axis=1, keepdims=True)
    first = jnp.argmax(loss_mask, axis=1)
    forced = jax.nn.one_hot(first, S, dtype=jnp.bool_) & loss_mask
    mask = mask | (none & forced)

    x = jnp.where(mask, cfg.mask_token_id, tokens)
    w = mask.astype(jnp.float32) / t                      # 1/t ELBO weight
    n_mask = mask.sum()
    big = cfg.vocab_size * S > 4_000_000                  # stream the CE
    out = apply_model(cfg, params, tokens=x, mode="encode", mesh=mesh,
                      data_axes=data_axes, skip_head=big)
    if big:
        nll, correct = chunked_ce(cfg, params, out.logits, tokens, w)
        ce = nll / jnp.maximum(w.sum(), 1e-6)
        acc = correct / jnp.maximum(w.sum(), 1e-6)
    else:
        logp = jax.nn.log_softmax(out.logits, axis=-1)
        tok_lp = jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]
        ce = -(tok_lp * w).sum() / jnp.maximum(w.sum(), 1e-6)
        acc = ((jnp.argmax(out.logits, -1) == tokens) & mask).sum() \
            / jnp.maximum(n_mask, 1)
    loss = ce + aux_weight * out.aux_loss
    return loss, {"ce": ce, "aux": out.aux_loss, "masked_acc": acc,
                  "n_masked": n_mask}
