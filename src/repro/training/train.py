"""Training loop: masked-diffusion LM on the synthetic corpus.

Used by examples/train_and_serve.py to produce the small model that the
serving benchmarks decode (giving real accuracy numbers for the methods
table), and lowered at production shape by launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import ArithmeticDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig
from repro.obs.log import get_logger, setup_logging
from repro.models.model import init_params
from repro.training import checkpoint
from repro.training.loss import diffusion_loss
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)

log = get_logger(__name__)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 300
    batch_size: int = 32
    seq_len: int = 96
    seed: int = 0
    log_every: int = 25
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    checkpoint_path: Optional[str] = None


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh=None,
                    data_axes=("data",)):
    def train_step(params, opt_state, tokens, loss_mask, rng):
        def loss_fn(p):
            return diffusion_loss(cfg, p, tokens, loss_mask, rng,
                                  mesh=mesh, data_axes=data_axes)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(opt_cfg, grads,
                                                      opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics
    return train_step


def train(cfg: ModelConfig, tcfg: TrainConfig, params=None, verbose=True):
    if verbose and not get_logger("repro").handlers:
        # direct library use (examples, tests with verbose=True): keep
        # progress visible without a CLI having configured logging
        setup_logging()
    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=tcfg.seq_len, seed=tcfg.seed)
    key = jax.random.PRNGKey(tcfg.seed)
    if params is None:
        params = init_params(cfg, key)
    opt_cfg = dataclasses.replace(tcfg.opt, total_steps=tcfg.steps)
    opt_state = adamw_init(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    history = []
    t0 = time.perf_counter()
    for step in range(tcfg.steps):
        b = ds.batch(step, tcfg.batch_size)
        key, sub = jax.random.split(key)
        params, opt_state, m = step_fn(params, opt_state,
                                       jnp.asarray(b.tokens),
                                       jnp.asarray(b.loss_mask), sub)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(v) for k, v in m.items()}
            m["step"] = step
            history.append(m)
            if verbose:
                log.info("step %5d loss %.4f masked_acc %.3f lr %.2e "
                         "(%.1fs)", step, m["loss"], m["masked_acc"],
                         m["lr"], time.perf_counter() - t0)
    if tcfg.checkpoint_path:
        checkpoint.save(tcfg.checkpoint_path, params,
                        {"steps": tcfg.steps, "config": cfg.name})
    return params, history
