"""Minimal dependency-free checkpointing: pytree -> .npz (+ structure).

Arrays are gathered to host (fine for CPU-scale training; the multi-pod
path would swap in per-shard writes keyed by PartitionSpec — noted in
DESIGN.md, not needed for the dry-run).
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, list]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump({"treedef": str(treedef), "n_leaves": len(leaves),
                   "dtypes": [str(np.asarray(l).dtype) for l in leaves],
                   "metadata": metadata or {}}, f)


def restore(path: str, like: Any) -> Any:
    leaves, treedef = _flatten(like)
    with np.load(path + ".npz") as z:
        loaded = [z[f"leaf_{i}"] for i in range(len(leaves))]
    assert len(loaded) == len(leaves), "checkpoint/model structure mismatch"
    cast = [np.asarray(a, dtype=np.asarray(l).dtype) if a.dtype != np.asarray(l).dtype else a
            for a, l in zip(loaded, leaves)]
    for a, l in zip(cast, leaves):
        assert a.shape == l.shape, f"shape mismatch {a.shape} vs {l.shape}"
    return treedef.unflatten(cast)


def load_metadata(path: str) -> dict:
    with open(path + ".json") as f:
        return json.load(f).get("metadata", {})
