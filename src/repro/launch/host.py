"""Host resource budgeting for multi-engine serving — the ONE
sanctioned place that mutates XLA/JAX process environment.

Running N ``EngineLoop`` decode threads in one process gives XLA:CPU a
single shared intra-op thread pool sized to every visible core; under
concurrent per-engine dispatch (and worse, concurrent first-block
compiles) the engines fight over it and per-engine decode-busy inflates
far beyond the work actually done (PR 6 trace attribution; ROADMAP open
item 1). The fix is to *budget*: size the pool to one engine's share of
the host, derived as ``cores // engines`` and overridable with
``--host-threads-per-engine``.

Mechanics, for the jaxlib this repo pins (0.4.x):

* ``PJRT_NPROC`` — read by XLA's ``DefaultThreadPoolSize()`` when the
  CPU PjRt client is created; sizes the Eigen intra-op pool and the
  client's async work pool. This is the effective intra-op knob (the
  classic ``intra_op_parallelism_threads`` XLA_FLAGS spelling is
  rejected by this jaxlib's flag parser).
* ``--xla_cpu_multi_thread_eigen=false`` — appended when the budget is
  a single thread, so legacy Eigen paths can't spawn their own workers.
* inter-op parallelism needs no flag here: the N decode threads *are*
  the inter-op dimension (one in-flight dispatch per engine by
  construction).

Every helper below must run **before the first jax backend
initialization** (env is read once at CPU client creation);
``apply_host_budget`` raises if a backend already exists. Nothing in
this module imports jax at module scope, so importing it is always
safe. ``scripts/test.sh lint`` enforces that no other module mutates
XLA-related environment — thread budgets, fake device counts, and the
persistent compile cache all flow through this file.
"""
from __future__ import annotations

import dataclasses
import os
import sys
from typing import Dict, Optional

_XLA_ENV_KEYS = ("XLA_FLAGS", "PJRT_NPROC", "JAX_PLATFORMS")


@dataclasses.dataclass(frozen=True)
class HostBudget:
    """Effective per-engine host compute budget. ``intra_op`` is the
    XLA:CPU pool size each engine's dispatches may use; it is surfaced
    in ``/metrics`` (``repro_host_threads_per_engine``) and the engine
    span metadata so a trace always records what it ran under."""
    engines: int
    cores: int
    intra_op: int
    source: str          # "derived" | "override" | "pool/<either>"

    def describe(self) -> str:
        return (f"{self.intra_op} intra-op thread(s)/engine "
                f"({self.source}; {self.engines} engine(s) on "
                f"{self.cores} core(s))")


def compute_host_budget(engines: int, threads_per_engine: int = 0,
                        cores: Optional[int] = None) -> HostBudget:
    """Partition host compute across engines: ``cores // engines``
    intra-op threads each (floor 1), unless ``threads_per_engine``
    overrides it."""
    engines = max(1, engines)
    if cores is None:
        cores = os.cpu_count() or 1
    if threads_per_engine > 0:
        return HostBudget(engines, cores, threads_per_engine, "override")
    return HostBudget(engines, cores, max(1, cores // engines), "derived")


def compute_pool_budgets(pool_sizes: Dict[str, int],
                         threads_per_engine: int = 0,
                         cores: Optional[int] = None) \
        -> Dict[str, HostBudget]:
    """Per-pool budget records for a disaggregated fleet
    (``--pool prefill:N,decode:M``). ``PJRT_NPROC`` is process-global —
    every engine in the process shares ONE intra-op pool size, so the
    thread count is derived from the *total* engine count and cannot
    differ between pools; what differs per pool is the record itself
    (engine count, ``source="pool/..."``), which each engine carries
    into its metrics (``repro_host_threads_per_engine``) and trace
    spans so a post-mortem can see what a pool ran under. Apply the
    process env with ``apply_host_budget`` on the *total* budget."""
    total = sum(max(0, n) for n in pool_sizes.values())
    base = compute_host_budget(total, threads_per_engine, cores)
    return {role: HostBudget(n, base.cores, base.intra_op,
                             f"pool/{base.source}")
            for role, n in pool_sizes.items()}


def _backend_initialized() -> bool:
    mod = sys.modules.get("jax._src.xla_bridge")
    return bool(getattr(mod, "_backends", None))


def apply_host_budget(budget: HostBudget) -> HostBudget:
    """Apply ``budget`` to this process's environment. Must run before
    the first jax backend init — the CPU client reads ``PJRT_NPROC``
    exactly once at creation."""
    if _backend_initialized():
        raise RuntimeError(
            "apply_host_budget must run before the first jax backend "
            "initialization (XLA reads PJRT_NPROC once, at CPU client "
            "creation)")
    os.environ["PJRT_NPROC"] = str(budget.intra_op)
    if budget.intra_op == 1:
        _append_xla_flags("--xla_cpu_multi_thread_eigen=false")
    return budget


def force_host_device_count(n: int) -> None:
    """Fake ``n`` host devices (CI / demo meshes on CPU)."""
    _append_xla_flags(f"--xla_force_host_platform_device_count={n}")


def default_platform(platform: str = "cpu") -> None:
    """Pin the jax platform unless the caller already chose one."""
    os.environ.setdefault("JAX_PLATFORMS", platform)


def budget_env(budget: Optional[HostBudget] = None, *,
               host_devices: int = 0, platform: str = "",
               base: Optional[dict] = None) -> dict:
    """Environment dict for a *subprocess* (benchmark children, test
    harnesses): the same knobs ``apply_host_budget`` et al. set in this
    process, composed without mutating it."""
    env = dict(base if base is not None else os.environ)
    flags = env.get("XLA_FLAGS", "")
    if budget is not None:
        env["PJRT_NPROC"] = str(budget.intra_op)
        if budget.intra_op == 1 \
                and "--xla_cpu_multi_thread_eigen" not in flags:
            flags = (flags + " --xla_cpu_multi_thread_eigen=false").strip()
    if host_devices and "--xla_force_host_platform_device_count" not in flags:
        flags = (flags + " --xla_force_host_platform_device_count="
                 f"{host_devices}").strip()
    if flags:
        env["XLA_FLAGS"] = flags
    if platform:
        env.setdefault("JAX_PLATFORMS", platform)
    return env


def enable_compile_cache(cache_dir: str) -> bool:
    """Wire JAX's persistent compilation cache at ``cache_dir`` and
    start counting its hit/miss events (``repro.obs.compile``). Safe to
    call after jax import (it uses ``jax.config``, not env); returns
    False when this jax build has no persistent cache support."""
    if not cache_dir:
        return False
    import jax
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # cache everything — the fused per-block fns are exactly the
        # small-but-hot compiles the default min-time threshold skips
        for knob, val in (
                ("jax_persistent_cache_min_compile_time_secs", 0.0),
                ("jax_persistent_cache_min_entry_size_bytes", 0)):
            try:
                jax.config.update(knob, val)
            except (AttributeError, Exception):
                pass
    except Exception:
        return False
    from repro.obs.compile import watch_persistent_cache
    watch_persistent_cache()
    return True


def _append_xla_flags(flag: str) -> None:
    cur = os.environ.get("XLA_FLAGS", "")
    if flag not in cur:
        os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()
