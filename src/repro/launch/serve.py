"""Serving launcher: load a checkpoint (or train briefly), start the
batched engine, and serve synthetic requests with the selected method.

    PYTHONPATH=src python -m repro.launch.serve --arch tiny \
        --method streaming --n 32 [--ckpt results/bench_model]
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--method", default="streaming",
                    choices=["vanilla", "dkv", "prefix", "fast", "streaming"])
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--tau0", type=float, default=0.9)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--train-steps", type=int, default=600)
    args = ap.parse_args()

    import jax
    from repro.core.decoder import DecodeConfig
    from repro.core.engine import ServingEngine
    from repro.data.synthetic import ArithmeticDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.models import get_config, init_params
    from repro.training import checkpoint
    from repro.training.train import TrainConfig, train

    cfg = get_config(args.arch, block_size=8)
    if args.ckpt:
        params = checkpoint.restore(args.ckpt,
                                    init_params(cfg, jax.random.PRNGKey(0)))
    else:
        params, _ = train(cfg, TrainConfig(steps=args.train_steps,
                                           batch_size=32, seq_len=44))
    d = DecodeConfig(method=args.method, gen_len=args.gen_len, block_size=8,
                     window=args.window, tau0=args.tau0, alpha=args.alpha)
    eng = ServingEngine(cfg, params, d)
    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=44)
    samples = ds.eval_set(args.n)
    for s in samples:
        eng.submit(s.prompt, max_tokens=args.gen_len)
    done = eng.run_to_completion()
    hits = sum(int(c.text.strip() == s.answer)
               for c, s in zip(sorted(done, key=lambda c: c.uid), samples))
    print(f"method={args.method} served={len(done)} acc={hits/len(done):.2f} "
          f"tok/s={eng.throughput:.1f}")


if __name__ == "__main__":
    main()
