"""Serving launcher: load a checkpoint (or train briefly), start the
engine in continuous or synchronous-batch mode, and serve synthetic
requests with the selected method.

    PYTHONPATH=src python -m repro.launch.serve --arch tiny \
        --method streaming --n 32 --mode continuous \
        [--ckpt results/bench_model] [--stream]

or serve over HTTP (SSE streaming, /healthz, /metrics):

    PYTHONPATH=src python -m repro.launch.serve --arch tiny --http 8000
    curl -N localhost:8000/v1/completions \
        -d '{"prompt": "Q:12+34=? A:", "max_tokens": 16, "stream": true}'

or mesh-parallel / multi-engine (one EngineLoop per submesh, requests
routed least-loaded; on CPU use --force-host-devices to fake chips):

    PYTHONPATH=src python -m repro.launch.serve --arch tiny --http 8000 \
        --mesh 2,1 --engines 2 --force-host-devices 4

Multi-engine hosts should also budget and pre-warm (repro.launch.host):

    ... --engines 2 --host-threads-per-engine 2 \
        --compile-cache-dir results/compile_cache --prewarm 16:32

or disaggregate prefill from decode (one shared prefix store; primed
requests hand off prefill pool -> decode pool at admission):

    PYTHONPATH=src python -m repro.launch.serve --arch tiny --http 8000 \
        --prefix-cache --pool prefill:1,decode:2

Quality auditing + post-mortems (repro.obs.audit, HTTP mode):

    ... --http 8000 --audit-rate 0.05 --audit-oracle auto \
        --flight-dir results/flight --slo-ttfb-p50-ms 500
"""
from __future__ import annotations

import argparse
import os

import numpy as np


def _parse_mesh(s: str):
    try:
        data, model = (int(v) for v in s.split(","))
    except ValueError:
        raise SystemExit(f"--mesh wants 'data,model' ints, got {s!r}")
    return data, model


def _parse_pool(s: str):
    """``"prefill:N,decode:M"`` -> {"prefill": N, "decode": M}."""
    sizes = {"prefill": 0, "decode": 0}
    for part in s.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            role, n = part.split(":")
            sizes[role.strip()] += int(n)
        except (ValueError, KeyError):
            raise SystemExit(
                f"--pool wants 'prefill:N,decode:M', got {part!r}")
    if sizes["decode"] < 1:
        raise SystemExit("--pool needs at least one decode engine "
                         "(prefill-only engines can never finish a "
                         "request)")
    if sizes["prefill"] < 1:
        raise SystemExit("--pool without a prefill engine is plain "
                         "--engines; drop the flag")
    return sizes


def _parse_prewarm(s: str):
    """``"P:G[,P:G...]"`` -> [(prompt_len, gen_len), ...]."""
    buckets = []
    for part in s.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            p, g = (int(v) for v in part.split(":"))
        except ValueError:
            raise SystemExit(
                f"--prewarm wants 'P:G[,P:G...]' ints, got {part!r}")
        buckets.append((p, g))
    return buckets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--method", default="streaming",
                    choices=["vanilla", "dkv", "prefix", "fast", "streaming"])
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "batch"])
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--max-slots", type=int, default=8,
                    help="continuous mode: concurrent decode lanes")
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--tau0", type=float, default=0.9)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--train-steps", type=int, default=600)
    ap.add_argument("--stream", action="store_true",
                    help="print per-block chunks as they commit")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route attention/confidence through the Pallas "
                         "kernels (REPRO_PALLAS_INTERPRET=0 on real TPU)")
    ap.add_argument("--host-loop", action="store_true",
                    help="legacy per-step host denoise loop instead of "
                         "the fused device-resident loop")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cross-request prefix KV cache (repro.cache): "
                         "chunk-aligned prompt prefill, radix-tree "
                         "content matching, cache-affinity routing "
                         "with --engines > 1")
    ap.add_argument("--cache-chunk", type=int, default=16,
                    help="prefix-cache chunk size in prompt tokens")
    ap.add_argument("--cache-bytes", type=int, default=256 << 20,
                    help="prefix-cache byte budget per engine (LRU "
                         "eviction beyond it)")
    ap.add_argument("--http", type=int, default=0, metavar="PORT",
                    help="serve over HTTP on this port instead of the "
                         "synthetic in-process workload (continuous "
                         "mode only; Ctrl-C drains gracefully)")
    ap.add_argument("--http-host", default="127.0.0.1")
    ap.add_argument("--max-pending", type=int, default=64,
                    help="HTTP mode: bounded admission queue; beyond "
                         "this, POSTs get 429 + Retry-After")
    ap.add_argument("--mesh", default="", metavar="DATA,MODEL",
                    help="per-engine mesh dims, e.g. 2,2: batch shards "
                         "over the data axis, attention/FFN over model "
                         "(DecodeExecutor placement layer); empty = "
                         "single-device")
    ap.add_argument("--engines", type=int, default=1, metavar="N",
                    help="engine loops, one per disjoint submesh, "
                         "behind one HTTP front end (least-loaded "
                         "routing; HTTP mode only for N > 1)")
    ap.add_argument("--pool", default="", metavar="prefill:N,decode:M",
                    help="disaggregated engine pools: N prefill-only "
                         "engines prime prompt KV into ONE shared "
                         "prefix store and hand each request off to "
                         "one of M decode engines (implies --engines "
                         "N+M; needs --http and --prefix-cache)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="fake this many host devices via XLA_FLAGS "
                         "(CI/demo; must be >= engines * data * model)")
    ap.add_argument("--host-threads-per-engine", type=int, default=0,
                    metavar="T",
                    help="XLA:CPU intra-op threads each engine's "
                         "dispatches may use; 0 = cores // engines "
                         "(repro.launch.host, applied before jax init)")
    ap.add_argument("--compile-cache-dir", default="", metavar="DIR",
                    help="JAX persistent compilation cache: restarts "
                         "and sibling engine processes reuse compiled "
                         "fused-block variants instead of recompiling")
    ap.add_argument("--prewarm", default="", metavar="P:G[,P:G...]",
                    help="compile every fused-block variant for these "
                         "(prompt_len:gen_len) shape buckets on every "
                         "engine BEFORE the HTTP front end admits "
                         "traffic; later compiles log loudly and count "
                         "in repro_post_warm_compiles_total")
    ap.add_argument("--no-steal", action="store_true",
                    help="disable block-boundary work stealing between "
                         "engine loops (--engines > 1)")
    ap.add_argument("--trace-dir", default="", metavar="DIR",
                    help="record request span trees + decode timelines "
                         "and write Chrome-trace JSON (Perfetto-"
                         "loadable) into DIR on shutdown (HTTP mode)")
    ap.add_argument("--trace-flush-s", type=float, default=0.0,
                    metavar="S",
                    help="with --trace-dir: also rewrite trace.json "
                         "atomically every S seconds, so a crashed run "
                         "keeps its trace up to the last flush")
    ap.add_argument("--audit-rate", type=float, default=0.0,
                    metavar="FRAC",
                    help="shadow-audit this fraction of completed "
                         "requests: re-decode on a low-priority lane "
                         "through the host-loop oracle and/or a cold "
                         "(cache-bypass) path and compare tokens "
                         "bit-for-bit (repro.obs.audit; 0 = off)")
    ap.add_argument("--audit-oracle", default="auto",
                    choices=["host", "cold", "both", "auto"],
                    help="audit lanes: 'host' flips the fused loop, "
                         "'cold' bypasses the prefix cache, 'both' runs "
                         "each, 'auto' picks every lane the engine "
                         "config supports")
    ap.add_argument("--flight-dir", default="", metavar="DIR",
                    help="flight recorder: on SLO breach, audit "
                         "divergence, crash, or GET /debug/flight, dump "
                         "trace ring buffers + metrics snapshot + "
                         "scheduler/gang state under DIR")
    ap.add_argument("--metrics-log", default="", metavar="PATH",
                    help="append every time-series recorder sample "
                         "(repro.obs.series) as a JSON line to PATH "
                         "(HTTP mode; the in-memory ring behind "
                         "/debug/timeline and /console is always on)")
    ap.add_argument("--metrics-interval-s", type=float, default=0.5,
                    metavar="S",
                    help="recorder sampling interval per engine "
                         "(0 disables the recorders entirely)")
    ap.add_argument("--slo-ttfb-p50-ms", type=float, default=0.0,
                    help="SLO watchdog: rolling TTFB p50 target in ms "
                         "(breach dumps a flight recording; 0 = off)")
    ap.add_argument("--slo-token-latency-ms", type=float, default=0.0,
                    help="SLO watchdog: rolling per-token latency p50 "
                         "target in ms (0 = off)")
    ap.add_argument("--slo-goodput-tok-s", type=float, default=0.0,
                    help="SLO watchdog: rolling completed-tokens/s "
                         "floor (0 = off)")
    ap.add_argument("--profile-blocks", type=int, default=0, metavar="N",
                    help="capture a jax.profiler trace over the first "
                         "N decoded blocks (written under --trace-dir, "
                         "or results/profile)")
    ap.add_argument("--log-level", default="info",
                    choices=["debug", "info", "warning", "error"])
    ap.add_argument("--log-json", action="store_true",
                    help="JSON-lines log records instead of text")
    args = ap.parse_args()

    from repro.obs.log import setup_logging
    setup_logging(level=args.log_level, json_mode=args.log_json)

    # flag validation up front — nothing below may cost the user a
    # training run or N param placements before a SystemExit
    if args.engines > 1 and not args.http:
        raise SystemExit("--engines N > 1 needs --http (the router lives "
                         "in the HTTP front end)")
    if args.mesh and not args.http and args.mode != "continuous":
        raise SystemExit("--mesh needs continuous mode or --http (the "
                         "placement layer drives the continuous engine; "
                         "the legacy batch engine is single-device)")
    if args.prefix_cache and args.method == "vanilla":
        raise SystemExit("--prefix-cache has no effect with --method "
                         "vanilla (no KV cache to reuse)")
    pool_sizes = _parse_pool(args.pool) if args.pool else None
    if pool_sizes is not None:
        if not args.http:
            raise SystemExit("--pool needs --http (the prefill->decode "
                             "handoff rides the EngineRouter in the "
                             "HTTP front end)")
        if not args.prefix_cache:
            raise SystemExit("--pool needs --prefix-cache (primed "
                             "prompt KV travels through the shared "
                             "prefix store)")
        n_pool = pool_sizes["prefill"] + pool_sizes["decode"]
        if args.engines not in (1, n_pool):
            raise SystemExit(f"--pool {args.pool} implies --engines "
                             f"{n_pool}, got --engines {args.engines}")
        args.engines = n_pool
    slo_targets = {"ttfb_p50_s": args.slo_ttfb_p50_ms / 1e3,
                   "token_latency_s": args.slo_token_latency_ms / 1e3,
                   "goodput_tok_s": args.slo_goodput_tok_s}
    if not args.http:
        for flag, on in (("--audit-rate", args.audit_rate > 0),
                         ("--flight-dir", bool(args.flight_dir)),
                         ("--slo-*", any(slo_targets.values())),
                         ("--trace-flush-s", args.trace_flush_s > 0),
                         ("--metrics-log", bool(args.metrics_log))):
            if on:
                raise SystemExit(f"{flag} needs --http (the audit/SLO/"
                                 "flight layer rides the HTTP serving "
                                 "loop)")
    if not 0.0 <= args.audit_rate <= 1.0:
        raise SystemExit(f"--audit-rate wants [0, 1], got "
                         f"{args.audit_rate}")
    if args.trace_flush_s > 0 and not args.trace_dir:
        raise SystemExit("--trace-flush-s needs --trace-dir (it "
                         "rewrites DIR/trace.json periodically)")
    mesh_dims = _parse_mesh(args.mesh) if args.mesh else None
    prewarm_buckets = _parse_prewarm(args.prewarm) if args.prewarm else []

    # host env knobs (thread budget, fake devices) must land before the
    # first jax backend init — repro.launch.host is the one sanctioned
    # XLA-env mutation point (scripts/test.sh lint enforces this)
    from repro.launch import host as host_budgeting
    budget = host_budgeting.compute_host_budget(
        args.engines, args.host_threads_per_engine)
    pool_budgets = None
    if pool_sizes is not None:
        pool_budgets = host_budgeting.compute_pool_budgets(
            pool_sizes, args.host_threads_per_engine)
    host_budgeting.apply_host_budget(budget)
    if args.force_host_devices:
        host_budgeting.force_host_device_count(args.force_host_devices)

    import jax

    if args.compile_cache_dir:
        if host_budgeting.enable_compile_cache(args.compile_cache_dir):
            print(f"persistent compile cache at {args.compile_cache_dir}")
        else:
            print("persistent compile cache unsupported by this jax "
                  "build; continuing without")
    print(f"host budget: {budget.describe()}")
    if pool_budgets is not None:
        for role in ("prefill", "decode"):
            print(f"pool {role}: {pool_budgets[role].describe()}")
    from repro.core.decoder import DecodeConfig
    from repro.core.engine import ServingEngine
    from repro.data.synthetic import ArithmeticDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.models import get_config, init_params
    from repro.training import checkpoint
    from repro.training.train import TrainConfig, train

    if mesh_dims is not None:
        # jax is up: the device-count precondition costs nothing to
        # check here, and failing inside make_submeshes would waste a
        # checkpoint restore or a whole training run first
        need = args.engines * mesh_dims[0] * mesh_dims[1]
        if len(jax.devices()) < need:
            raise SystemExit(
                f"--mesh {args.mesh} x --engines {args.engines} needs "
                f"{need} devices, have {len(jax.devices())} "
                f"(--force-host-devices {need} fakes them on CPU)")

    cfg = get_config(args.arch, block_size=8)
    if args.ckpt:
        params = checkpoint.restore(args.ckpt,
                                    init_params(cfg, jax.random.PRNGKey(0)))
    else:
        params, _ = train(cfg, TrainConfig(steps=args.train_steps,
                                           batch_size=32, seq_len=44))
    d = DecodeConfig(method=args.method, gen_len=args.gen_len, block_size=8,
                     window=args.window, tau0=args.tau0, alpha=args.alpha,
                     use_kernels=args.use_kernels, fused=not args.host_loop,
                     prefix_cache=args.prefix_cache,
                     cache_chunk=args.cache_chunk)
    tok = ByteTokenizer(cfg.vocab_size)

    # placement: one DecodeExecutor per engine submesh (None = today's
    # single-device path); params are placed per mesh, caches are born
    # sharded, gang batches shard over the data axis
    executors = [None] * args.engines
    if mesh_dims is not None:
        from repro.launch.mesh import make_submeshes
        from repro.serving import DecodeExecutor
        executors = [DecodeExecutor(cfg, params, m)
                     for m in make_submeshes(args.engines, *mesh_dims)]

    # disaggregated pools share ONE store: the prefill pool publishes
    # chunk KV into it, the decode pool's admission prefill finds the
    # full hit. Keyed by mesh *shape* (numerics are placement-shape-
    # dependent, not device-id-dependent), so every same-shape engine
    # may read it.
    shared_store = None
    if pool_sizes is not None:
        from repro.cache import HOST_PLACEMENT, PrefixKVCache
        shared_store = PrefixKVCache(
            chunk_tokens=args.cache_chunk, max_bytes=args.cache_bytes,
            placement=(executors[0].shape_key
                       if executors[0] is not None else HOST_PLACEMENT),
            shared=True)

    def make_engine(ex, role: str = "both"):
        from repro.serving import ContinuousEngine
        store = None
        if args.prefix_cache:
            if shared_store is not None:
                store = shared_store
            else:
                # one store per engine (placement-bound, like the KV
                # pool); the router's cache-affinity policy relies on
                # that split
                from repro.cache import HOST_PLACEMENT, PrefixKVCache
                store = PrefixKVCache(
                    chunk_tokens=args.cache_chunk,
                    max_bytes=args.cache_bytes,
                    placement=ex.placement if ex is not None
                    else HOST_PLACEMENT)
        return ContinuousEngine(cfg, params, d, max_slots=args.max_slots,
                                tokenizer=tok, executor=ex,
                                prefix_cache=store,
                                prefill_only=(role == "prefill"),
                                host_budget=(pool_budgets[role]
                                             if pool_budgets is not None
                                             else budget))

    tracer = None
    if args.trace_dir:
        from repro.obs.trace import Tracer
        tracer = Tracer()

    def attach_profiler(engine):
        if args.profile_blocks > 0:
            # jax.profiler traces are process-global: exactly one
            # engine may own the capture window
            from repro.obs.profiler import BlockProfiler
            engine.profiler = BlockProfiler(
                args.trace_dir or "results/profile", args.profile_blocks)

    def export_trace():
        if tracer is not None:
            path = os.path.join(args.trace_dir, "trace.json")
            tracer.export(path)
            print(f"chrome trace written to {path} "
                  f"(open in ui.perfetto.dev)")

    def prewarm_all(engines):
        if not prewarm_buckets:
            return
        # sequential, before the front end opens admission: every
        # (shape bucket x method x placement) fused-block variant is
        # compiled now, so steady-state traffic never pays a compile
        for i, eng in enumerate(engines):
            rep = eng.prewarm(prewarm_buckets)
            print(f"engine-{i} prewarmed {rep['variants']} variant(s) "
                  f"over {len(rep['buckets'])} bucket(s) in "
                  f"{rep['seconds']:.1f}s")

    if args.http:
        from repro.server import run as run_http
        roles = None
        if pool_sizes is not None:
            roles = (["prefill"] * pool_sizes["prefill"]
                     + ["decode"] * pool_sizes["decode"])
        engines = [make_engine(ex, roles[i] if roles else "both")
                   for i, ex in enumerate(executors)]
        attach_profiler(engines[0])
        prewarm_all(engines)
        audit = None
        if args.audit_rate > 0:
            from repro.obs import AuditConfig
            audit = AuditConfig(sample_rate=args.audit_rate,
                                oracle=args.audit_oracle)
        watchdog = None
        if any(slo_targets.values()):
            from repro.obs import SLOWatchdog
            watchdog = SLOWatchdog(
                **{k: (v or None) for k, v in slo_targets.items()})
        flight = None
        if args.flight_dir:
            from repro.obs import FlightRecorder
            flight = FlightRecorder(args.flight_dir, tracer=tracer)
        flusher = None
        if tracer is not None and args.trace_flush_s > 0:
            from repro.obs import TraceFlusher
            flusher = TraceFlusher(
                tracer, os.path.join(args.trace_dir, "trace.json"),
                interval_s=args.trace_flush_s).start()
        try:
            run_http(engines if len(engines) > 1 else engines[0],
                     host=args.http_host, port=args.http,
                     max_pending=args.max_pending, tracer=tracer,
                     steal=not args.no_steal, audit=audit,
                     watchdog=watchdog, flight=flight, roles=roles,
                     metrics_interval_s=args.metrics_interval_s,
                     metrics_log=args.metrics_log or None)
        finally:
            if flusher is not None:
                flusher.stop(final_flush=False)
            export_trace()
        return
    ds = ArithmeticDataset(tok, seq_len=44)
    samples = ds.eval_set(args.n)
    if args.mode == "continuous":
        eng = make_engine(executors[0])
        if tracer is not None:
            eng.set_tracer(tracer, "engine-0")
        attach_profiler(eng)
        prewarm_all([eng])
        for s in samples:
            eng.submit(s.prompt, max_tokens=args.gen_len,
                       trace_id=tracer.new_trace_id()
                       if tracer is not None else "")
        if args.stream:
            done = []
            eng.on_chunk(None, lambda ch: print(
                f"  uid={ch.uid} block={ch.block_idx} "
                f"{'[done] ' if ch.finished else ''}{ch.text!r}"))
            while not eng.scheduler.idle:
                done.extend(eng.step())
        else:
            done = eng.run_to_completion()
        snap = eng.metrics.snapshot()
        hits = sum(int(c.text.strip() == s.answer)
                   for c, s in zip(sorted(done, key=lambda c: c.uid), samples))
        print(f"mode=continuous method={args.method} served={len(done)} "
              f"acc={hits/len(done):.2f} tok/s={snap['throughput_tok_s']:.1f} "
              f"p50={snap['latency_p50_s']*1e3:.0f}ms "
              f"p99={snap['latency_p99_s']*1e3:.0f}ms "
              f"ttfb_p50={snap['ttfb_p50_s']*1e3:.0f}ms "
              f"occ={snap['mean_occupancy']:.2f} "
              f"merges={snap['gang_merges']} "
              + (f"cache_hit_toks={snap['prefix_cache_hit_tokens']} "
                 if args.prefix_cache else "") +
              f"syncs/blk={snap['host_syncs_per_block']:.2f} "
              f"steps/blk={snap['device_steps_per_block']:.2f} "
              f"jit_cache={eng.jit_cache_size()}")
        export_trace()
        return
    eng = ServingEngine(cfg, params, d, mode="batch")
    for s in samples:
        eng.submit(s.prompt, max_tokens=args.gen_len)
    done = eng.run_to_completion()
    hits = sum(int(c.text.strip() == s.answer)
               for c, s in zip(sorted(done, key=lambda c: c.uid), samples))
    print(f"mode=batch method={args.method} served={len(done)} "
          f"acc={hits/len(done):.2f} tok/s={eng.throughput:.1f}")


if __name__ == "__main__":
    main()
