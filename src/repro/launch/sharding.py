"""PartitionSpec construction for params / optimizer state / caches.

Scheme (DESIGN.md §5):
  * model axis — tensor parallel: attention heads (padded per
    heads.plan_heads), d_ff, per-expert hidden, vocab.
  * data axis — batch; in train mode additionally FSDP-shards the
    non-expert weight matrices along d_model/d_ff; MoE experts shard
    their expert axis here (expert parallelism) in every mode.
  * pod axis — pure data parallelism.

Every proposed spec is divisibility-guarded against the actual shape:
axes that don't divide fall back to replication (e.g. xlstm's 4 mLSTM
heads never shard over model=16 — its wide projections do instead).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import (ATTN, ATTN_LOCAL, MLSTM, MOE, NONE, RGLRU,
                                 SLSTM, LayerSpec, ModelConfig)
from repro.models.model import init_cache, init_params


def _guard(spec: P, shape, mesh) -> P:
    """Drop sharding on axes whose extent doesn't divide the dim."""
    out = []
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            out.append(None)
            continue
        ns = names if isinstance(names, tuple) else (names,)
        total = 1
        for n in ns:
            total *= mesh.shape[n]
        out.append(names if dim % total == 0 else None)
    return P(*out)


class SpecBuilder:
    def __init__(self, cfg: ModelConfig, mesh, mode: str = "serve"):
        assert mode in ("serve", "train")
        self.cfg = cfg
        self.mesh = mesh
        self.model = "model" if "model" in mesh.axis_names else None
        self.fsdp = "data" if (mode == "train" and "data" in mesh.axis_names) \
            else None
        self.ep = "data" if "data" in mesh.axis_names else None
        self.dp: Tuple[str, ...] = tuple(a for a in mesh.axis_names
                                         if a in ("pod", "data"))
        self.mode = mode

    # ---------------- per-layer param specs

    def _attn(self) -> dict:
        m, f = self.model, self.fsdp
        d = {"wq": P(f, m, None), "wk": P(f, m, None), "wv": P(f, m, None),
             "wo": P(m, None, f)}
        if self.cfg.qk_norm:
            d["q_norm"] = P(None)
            d["k_norm"] = P(None)
        return d

    def _ffn(self, kind: str) -> dict:
        m, f = self.model, self.fsdp
        if kind == "swiglu":
            return {"w_gate": P(f, m), "w_up": P(f, m), "w_down": P(m, f)}
        return {"w_up": P(f, m), "w_down": P(m, f)}

    def _moe(self) -> dict:
        m, e = self.model, self.ep
        if self.cfg.moe_2d_dispatch:  # §Perf HC3b: d over model, f full
            return {"router": P(None, None),
                    "w_gate": P(e, m, None), "w_up": P(e, m, None),
                    "w_down": P(e, None, m)}
        return {"router": P(None, None),
                "w_gate": P(e, None, m), "w_up": P(e, None, m),
                "w_down": P(e, m, None)}

    def _mlstm(self) -> dict:
        m, f = self.model, self.fsdp
        return {"w_up": P(f, m), "w_z": P(f, m), "conv": P(None, m),
                "wq": P(m, None, None), "wk": P(m, None, None),
                "wv": P(m, None, None), "w_i": P(m, None), "w_f": P(m, None),
                "gn": P(m), "w_down": P(m, f)}

    def _slstm(self) -> dict:
        m, f = self.model, self.fsdp
        d = {"gn": P(None),
             "w_ffn_up": P(f, m), "w_ffn_down": P(m, f)}
        for g in "zifo":
            d[f"w_{g}"] = P(f, m)
            d[f"r_{g}"] = P(None, None, None)
        return d

    def _rglru(self) -> dict:
        m, f = self.model, self.fsdp
        return {"w_in": P(f, m), "w_gate": P(f, m), "w_out": P(m, f),
                "conv": P(None, m), "w_a": P(None, m), "w_x": P(None, m),
                "lam": P(m)}

    def layer(self, spec: LayerSpec) -> dict:
        mixer = {ATTN: self._attn, ATTN_LOCAL: self._attn,
                 MLSTM: self._mlstm, SLSTM: self._slstm,
                 RGLRU: self._rglru}[spec.mixer]()
        d = {"norm1": P(None), "mixer": mixer}
        if spec.ffn != NONE:
            d["norm2"] = P(None)
            d["ffn"] = self._moe() if spec.ffn == MOE else self._ffn(spec.ffn)
        return d

    # ---------------- whole-model specs

    def params(self):
        cfg = self.cfg
        m, f = self.model, self.fsdp
        specs = {"embed": P(m, f), "out_norm": P(None)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(f, m)
        if cfg.frontend_embed_dim:
            specs["frontend_proj"] = P(None, m)
        stack = lambda p: P(*((None,) + tuple(p)))
        specs["scan"] = tuple(
            jax.tree.map(stack, self.layer(s),
                         is_leaf=lambda x: isinstance(x, P))
            for s in cfg.pattern)
        specs["tail"] = tuple(self.layer(s)
                              for s in cfg.layout[cfg.reps * len(cfg.pattern):])
        shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        return jax.tree.map(lambda sh, sp: _guard(sp, sh.shape, self.mesh),
                            shapes, specs,
                            is_leaf=lambda x: isinstance(x, P))

    def cache(self, batch: int, max_len: int, serve_long: bool = False,
              ctx_parallel: bool = False):
        """Specs matching init_cache's structure (incl. scan stacking)."""
        cfg = self.cfg
        m = self.model
        dp = self.dp
        b = (dp if len(dp) > 1 else dp[0]) if (dp and not ctx_parallel) else None
        seq = "data" if ctx_parallel else None

        def one(spec: LayerSpec):
            if spec.mixer in (ATTN, ATTN_LOCAL):
                s = P(b, seq, m, None)
                return (s, s)
            if spec.mixer == MLSTM:
                from repro.models.recurrent import MLSTMState
                return MLSTMState(P(b, None, None, m), P(b, None, None),
                                  P(b, None), P(b, None, m))
            if spec.mixer == SLSTM:
                from repro.models.recurrent import SLSTMState
                return SLSTMState(P(b, m), P(b, m), P(b, m), P(b, m))
            if spec.mixer == RGLRU:
                from repro.models.recurrent import RGLRUState
                return RGLRUState(P(b, m), P(b, None, m))
            raise ValueError(spec.mixer)

        layout = cfg.effective_layout(serve_long)
        pattern = layout[:len(cfg.pattern)]
        tail = layout[cfg.reps * len(cfg.pattern):]
        stack = lambda p: P(*((None,) + tuple(p)))
        scan = tuple(jax.tree.map(stack, one(s),
                                  is_leaf=lambda x: isinstance(x, P))
                     for s in pattern)
        specs = {"scan": scan, "tail": tuple(one(s) for s in tail)}
        shapes = jax.eval_shape(
            lambda: init_cache(cfg, batch, max_len, serve_long))
        return jax.tree.map(lambda sh, sp: _guard(sp, sh.shape, self.mesh),
                            shapes, specs,
                            is_leaf=lambda x: isinstance(x, P))

    def batch_spec(self, extra_dims: int = 1) -> P:
        dp = self.dp
        b = dp if len(dp) > 1 else (dp[0] if dp else None)
        return P(b, *([None] * extra_dims))

    def opt(self, param_specs):
        from repro.training.optimizer import AdamWState
        return AdamWState(P(), jax.tree.map(lambda s: s, param_specs),
                          jax.tree.map(lambda s: s, param_specs))
