"""Training launcher.

CPU-scale real training (tiny archs / smoke variants):
    PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 300

Production-shape lowering check (any assigned arch; ShapeDtypeStructs
only — see dryrun.py for the full matrix):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --lower-only
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--lower-only", action="store_true")
    args = ap.parse_args()

    if args.lower_only:
        from repro.launch.dryrun import run_one
        run_one(args.arch, "train_4k", False)
        return

    from repro.models import get_config
    from repro.training.train import TrainConfig, train
    cfg = get_config(args.arch)
    params, hist = train(cfg, TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq_len,
        checkpoint_path=args.ckpt or None))
    print(f"done: loss {hist[-1]['loss']:.4f} "
          f"masked_acc {hist[-1]['masked_acc']:.3f}")


if __name__ == "__main__":
    main()
