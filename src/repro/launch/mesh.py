"""Production meshes (TPU v5e target).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the pod
axis is pure data parallelism (gradients psum over pod+data; serving
replicates over pod).

Functions, not module constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6 names mesh axis kinds explicitly; older jax (the CI
    # image ships 0.4.x) predates AxisType and treats every axis as
    # what AxisType.Auto means, so omitting the kwarg is equivalent.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False, data: int = 16,
                         model: int = 16, pods: int = 2):
    """(data, model) = (16, 16) per pod; multi_pod prepends pods=2.
    The data/model overrides exist only for reduced-device CI tests —
    production always uses the defaults."""
    shape = (pods, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (real or fake) devices exist —
    used by sharded smoke tests."""
    return _make_mesh((data, model), ("data", "model"))


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_submeshes(n: int, data: int = 1, model: int = 1, devices=None):
    """Split the device set into ``n`` disjoint (data, model) meshes —
    one per serving engine (``repro.server.EngineRouter``). Contiguous
    device slices so each submesh stays within its natural locality
    domain (a TPU slice; adjacent fake host devices in CI)."""
    import numpy as np
    from jax.sharding import Mesh

    devs = list(devices if devices is not None else jax.devices())
    per = data * model
    if len(devs) < n * per:
        raise ValueError(
            f"need {n} x {data}x{model} = {n * per} devices, "
            f"have {len(devs)}")
    return [Mesh(np.array(devs[i * per:(i + 1) * per]).reshape(data, model),
                 ("data", "model")) for i in range(n)]
