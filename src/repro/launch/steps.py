"""Production step functions + ShapeDtypeStruct input specs.

One builder per input-shape class:
  train_4k    -> train_step   (masked-diffusion loss + AdamW update)
  prefill_32k -> prefill_step (build KV caches / recurrent states)
  decode_32k  -> serve_step   (ONE denoise iteration of the current
                               block against the full cache; streaming
                               variant uses the pruned query region,
                               baseline variant the full suffix)
  long_500k   -> serve_step   (batch 1, context-parallel cache, local
                               attention for dense archs)

``input_specs(cfg, shape)`` returns (ShapeDtypeStructs, in_shardings,
out_shardings) — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.schedule import confidence_and_tokens
from repro.launch.mesh import data_axes_of
from repro.launch.sharding import SpecBuilder
from repro.models.config import ModelConfig
from repro.models.model import apply_model, init_cache, init_params
from repro.training.loss import diffusion_loss
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode", long=True),
}

# paper defaults: block 32, window 96, gen length 512 (Table 12)
BLOCK = 32
WINDOW = 96
GEN_LEN = 512


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


class LoweringSpec(NamedTuple):
    fn: Any                 # python callable to jit
    args: Tuple             # ShapeDtypeStructs
    in_shardings: Tuple
    out_shardings: Any
    meta: dict


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    # trillion-param MoE: bf16 moments to fit 16G HBM (DESIGN.md §7)
    bf16 = cfg.param_count() > 200e9
    return AdamWConfig(state_dtype="bfloat16" if bf16 else "float32")


# --------------------------------------------------------------- train

def build_train(cfg: ModelConfig, mesh, shape=SHAPES["train_4k"]) -> LoweringSpec:
    da = data_axes_of(mesh)
    opt_cfg = opt_config_for(cfg)

    def train_step(params, opt_state, tokens, loss_mask, prefix_embeds, rng):
        def loss_fn(p):
            return diffusion_loss(cfg, p, tokens, loss_mask, rng,
                                  mesh=mesh, data_axes=da)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params2, opt_state2, om = adamw_update(opt_cfg, grads, opt_state, params)
        return params2, opt_state2, dict(metrics, loss=loss, **om)

    def train_step_frontend(params, opt_state, tokens, loss_mask,
                            prefix_embeds, rng):
        # modality archs: loss over the token region, conditioned on the
        # (stub) frontend prefix embeddings
        def loss_fn(p):
            return _dl_frontend(cfg, p, tokens, loss_mask, prefix_embeds,
                                rng, mesh, da)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params2, opt_state2, om = adamw_update(opt_cfg, grads, opt_state, params)
        return params2, opt_state2, dict(metrics, loss=loss, **om)

    B, S = shape["batch"], shape["seq"]
    sb = SpecBuilder(cfg, mesh, mode="train")
    pspec = sb.params()
    ospec = sb.opt(pspec)
    bspec = sb.batch_spec(1)
    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    opt_sds = jax.eval_shape(lambda: adamw_init(opt_cfg, params_sds))
    rng_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))

    Pf = cfg.frontend_prefix_len if cfg.frontend_embed_dim else 0
    S_tok = S - Pf
    tokens_sds = _sds((B, S_tok), jnp.int32)
    mask_sds = _sds((B, S_tok), jnp.bool_)
    fn = train_step if not Pf else train_step_frontend
    args = [params_sds, opt_sds, tokens_sds, mask_sds]
    insh = [_ns(mesh, pspec), _ns(mesh, ospec), _ns(mesh, bspec),
            _ns(mesh, bspec)]
    if Pf:
        args.append(_sds((B, Pf, cfg.frontend_embed_dim), jnp.bfloat16))
        insh.append(_ns(mesh, sb.batch_spec(2)))
    else:
        args.append(_sds((B, 0, max(cfg.frontend_embed_dim, 1)), jnp.bfloat16))
        insh.append(_ns(mesh, sb.batch_spec(2)))
    args.append(rng_sds)
    insh.append(None)
    outsh = (_ns(mesh, pspec), _ns(mesh, ospec), None)
    return LoweringSpec(fn, tuple(args), tuple(insh), outsh,
                        dict(kind="train", batch=B, seq=S))


def _dl_frontend(cfg, params, tokens, loss_mask, prefix_embeds, rng, mesh, da):
    """diffusion loss with a frozen (stub) frontend prefix."""
    B, S = tokens.shape
    k_t, k_mask = jax.random.split(rng)
    t = jax.random.uniform(k_t, (B, 1), minval=0.05, maxval=1.0)
    mask = (jax.random.uniform(k_mask, (B, S)) < t) & loss_mask
    x = jnp.where(mask, cfg.mask_token_id, tokens)
    out = apply_model(cfg, params, tokens=x, prefix_embeds=prefix_embeds,
                      mode="encode", mesh=mesh, data_axes=da, skip_head=True)
    hidden = out.logits[:, prefix_embeds.shape[1]:]
    from repro.training.loss import chunked_ce
    w = mask.astype(jnp.float32) / t
    nll, correct = chunked_ce(cfg, params, hidden, tokens, w)
    ce = nll / jnp.maximum(w.sum(), 1e-6)
    loss = ce + 0.01 * out.aux_loss
    return loss, {"ce": ce, "aux": out.aux_loss,
                  "masked_acc": correct / jnp.maximum(w.sum(), 1e-6),
                  "n_masked": mask.sum()}


# --------------------------------------------------------------- prefill

def build_prefill(cfg: ModelConfig, mesh, shape=SHAPES["prefill_32k"],
                  serve_long=False) -> LoweringSpec:
    da = data_axes_of(mesh)
    B, S = shape["batch"], shape["seq"]
    max_len = S + GEN_LEN
    ctx_par = bool(shape.get("long")) and B == 1
    moe_da = () if ctx_par else da

    def prefill_step(params, tokens, prefix_embeds, cache):
        out = apply_model(cfg, params, tokens=tokens,
                          prefix_embeds=prefix_embeds if
                          cfg.frontend_embed_dim else None,
                          mode="encode", cache=cache, serve_long=serve_long,
                          mesh=mesh, data_axes=moe_da)
        return out.cache, out.kv_valid

    sb = SpecBuilder(cfg, mesh, mode="serve")
    pspec = sb.params()
    cspec = sb.cache(B, max_len, serve_long, ctx_parallel=ctx_par)
    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    cache_sds = jax.eval_shape(lambda: init_cache(cfg, B, max_len, serve_long))
    Pf = cfg.frontend_prefix_len if cfg.frontend_embed_dim else 0
    tokens_sds = _sds((B, S - Pf), jnp.int32)
    emb_sds = _sds((B, Pf, max(cfg.frontend_embed_dim, 1)), jnp.bfloat16)
    bspec = sb.batch_spec(1) if not ctx_par else P(None, "data")
    args = (params_sds, tokens_sds, emb_sds, cache_sds)
    insh = (_ns(mesh, pspec), _ns(mesh, bspec),
            _ns(mesh, sb.batch_spec(2) if not ctx_par else P(None, None, None)),
            _ns(mesh, cspec))
    outsh = (_ns(mesh, cspec), _ns(mesh, sb.batch_spec(0) if not ctx_par
                                   else P(None)))
    return LoweringSpec(prefill_step, args, insh, outsh,
                        dict(kind="prefill", batch=B, seq=S))


# --------------------------------------------------------------- decode

def build_serve(cfg: ModelConfig, mesh, shape, variant="streaming") -> LoweringSpec:
    da = data_axes_of(mesh)
    B, S = shape["batch"], shape["seq"]
    serve_long = bool(shape.get("long"))
    ctx_par = serve_long and B == 1
    moe_da = () if ctx_par or B < mesh.shape.get("data", 1) else da
    K = cfg.block_size
    if variant == "streaming":
        Sq = K + WINDOW + 1
    elif variant == "frozen":
        # HC1 (EXPERIMENTS.md §Perf): frozen-suffix steps query only the
        # block; suffix/trailing KV are read from the cache (bool mask)
        Sq = K
    else:  # paper baseline: full suffix of a gen-512 target (block 0)
        Sq = GEN_LEN

    def serve_step(params, q_tokens, q_pos, cache, kv_valid):
        out = apply_model(cfg, params, tokens=q_tokens, positions=q_pos,
                          mode="step", cache=cache, kv_valid=kv_valid,
                          serve_long=serve_long, mesh=mesh, data_axes=moe_da)
        conf, toks = confidence_and_tokens(out.logits[:, :K])
        return conf, toks

    sb = SpecBuilder(cfg, mesh, mode="serve")
    pspec = sb.params()
    cspec = sb.cache(B, S, serve_long, ctx_parallel=ctx_par)
    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    cache_sds = jax.eval_shape(lambda: init_cache(cfg, B, S, serve_long))
    bspec = sb.batch_spec(1) if not ctx_par else P(None, None)
    b0 = sb.batch_spec(0) if not ctx_par else P(None)
    kv_valid_sds = _sds((B, S), jnp.bool_) if variant == "frozen" \
        else _sds((B,), jnp.int32)
    kv_valid_spec = (sb.batch_spec(1) if not ctx_par else P(None, "data")) \
        if variant == "frozen" else b0
    args = (params_sds, _sds((B, Sq), jnp.int32), _sds((B, Sq), jnp.int32),
            cache_sds, kv_valid_sds)
    insh = (_ns(mesh, pspec), _ns(mesh, bspec), _ns(mesh, bspec),
            _ns(mesh, cspec), _ns(mesh, kv_valid_spec))
    outsh = (_ns(mesh, bspec), _ns(mesh, bspec))
    return LoweringSpec(serve_step, args, insh, outsh,
                        dict(kind="decode", batch=B, seq=S, q_len=Sq,
                             variant=variant, long=serve_long))


def build(cfg: ModelConfig, mesh, shape_name: str,
          variant: str = "streaming") -> LoweringSpec:
    shape = SHAPES[shape_name]
    if shape["kind"] == "train":
        return build_train(cfg, mesh, shape)
    if shape["kind"] == "prefill":
        return build_prefill(cfg, mesh, shape)
    return build_serve(cfg, mesh, shape, variant=variant)
