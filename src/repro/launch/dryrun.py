import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, proving the distribution config is coherent,
and extract the roofline terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape decode_32k [--multi-pod] [--variant streaming|baseline]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Results land in results/dryrun/<arch>__<shape>__<mesh>[__<variant>].json:
per-device memory (arguments/temp/output), per-device HLO FLOPs & bytes,
collective bytes by op type, and the derived roofline terms
(TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
import argparse
import json
import re
import time
import traceback

import numpy as np

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}

_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*(?:\()?((?:f|bf|s|u|pred|c)[\w]*)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


_CONVERT_RE = re.compile(r"= f32\[([\d,]+)\][^=]*\bconvert\(")


def parse_cpu_promotion_bytes(hlo_text: str, threshold=64 * 2**20) -> int:
    """Bytes of large f32 `convert` results. XLA:CPU has no native bf16
    arithmetic, so it converts bf16 buffers (params, KV caches) to f32 —
    and hoists whole-stack converts out of the layer scan. TPU consumes
    bf16 natively in the MXU, so these buffers don't exist there; we
    subtract them to get the TPU temp estimate (see §Dry-run notes)."""
    total = 0
    for m in _CONVERT_RE.finditer(hlo_text):
        size = 4
        for d in m.group(1).split(","):
            size *= int(d)
        if size >= threshold:
            total += size
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in compiled HLO."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, dt, dims, kind = m.groups()
        if line.strip().startswith("%") and "-done" in line:
            continue
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        nbytes = size * _DTYPE_BYTES.get(dt, 4)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def roofline_terms(flops, hbm_bytes, coll: dict, n_chips: int) -> dict:
    """Per-device seconds for each roofline term. cost_analysis FLOPs
    are already per-device on SPMD-partitioned modules."""
    coll_bytes = sum(v["bytes"] for v in coll.values())
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
        "collective_bytes": coll_bytes,
    }


def model_flops(cfg, meta) -> float:
    """6*N*D (train) / 2*N*D (one forward) with N = active params."""
    n = cfg.active_param_count()
    if meta["kind"] == "train":
        tokens = meta["batch"] * meta["seq"]
        return 6.0 * n * tokens
    if meta["kind"] == "prefill":
        return 2.0 * n * meta["batch"] * meta["seq"]
    return 2.0 * n * meta["batch"] * meta["q_len"]


def SHAPE_KIND(shape_name: str) -> str:
    from repro.launch.steps import SHAPES
    return SHAPES[shape_name]["kind"]


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on jax >= 0.6 but a
    one-element list of dicts on the 0.4.x line — normalize."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def run_one(arch: str, shape_name: str, multi_pod: bool,
            variant: str = "streaming", out_dir: str = "results/dryrun",
            mesh_dims=None, unroll: int = 1):
    import jax
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.models import get_config

    t0 = time.perf_counter()
    kw = {}
    if mesh_dims:  # reduced-device test path only
        kw = dict(data=mesh_dims[0], model=mesh_dims[1])
    mesh = make_production_mesh(multi_pod=multi_pod, **kw)
    tp = mesh.shape["model"]
    cfg = get_config(arch, tp=tp, dtype="bfloat16", param_dtype="bfloat16",
                     block_size=steps.BLOCK,
                     # full unroll -> exact HLO flops/collective counts
                     # (XLA cost analysis counts a while body ONCE)
                     scan_unroll=(10_000 if unroll < 0 else unroll),
                     # activation checkpointing for the training pass
                     **({"remat": True} if SHAPE_KIND(shape_name) == "train"
                        else {}))
    spec = steps.build(cfg, mesh, shape_name, variant=variant)
    with mesh:
        lowered = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                          out_shardings=spec.out_shardings).lower(*spec.args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    promo = parse_cpu_promotion_bytes(hlo)
    n_chips = int(np.prod(list(mesh.shape.values())))
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops, hbm, coll, n_chips)
    mf = model_flops(cfg, spec.meta)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "variant": variant if spec.meta["kind"] == "decode" else "",
        "meta": spec.meta,
        "n_chips": n_chips,
        "per_device": {
            "flops": flops,
            "hbm_bytes": hbm,
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "cpu_promotion_bytes": promo,
            "temp_bytes_tpu_estimate": max(mem.temp_size_in_bytes - promo, 0),
            "total_bytes": (mem.argument_size_in_bytes
                            + mem.output_size_in_bytes
                            + mem.temp_size_in_bytes),
            "total_bytes_tpu_estimate": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + max(mem.temp_size_in_bytes - promo, 0)),
        },
        "collectives": coll,
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else None,
        "unrolled": unroll != 1,
        "compile_s": round(time.perf_counter() - t0, 1),
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    rec["dominant_term"] = dom
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{rec['mesh']}"
    if rec["variant"] and rec["variant"] != "streaming":
        tag += f"__{variant}"
    if rec["unrolled"]:
        tag += "__unrolled"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"OK {tag}: mem/dev={rec['per_device']['total_bytes']/2**30:.2f}GiB "
          f"(tpu-est {rec['per_device']['total_bytes_tpu_estimate']/2**30:.2f}) "
          f"flops/dev={flops:.3g} dom={dom} "
          f"terms=({terms['compute_s']:.2e},{terms['memory_s']:.2e},"
          f"{terms['collective_s']:.2e})s compile={rec['compile_s']}s")
    return rec


def _compile_stats(cfg, mesh, shape_name, variant):
    """Lower+compile; return (flops, hbm_bytes, collectives, mem, hlo)."""
    import jax
    from repro.launch import steps
    spec = steps.build(cfg, mesh, shape_name, variant=variant)
    with mesh:
        compiled = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                           out_shardings=spec.out_shardings) \
            .lower(*spec.args).compile()
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        hlo = compiled.as_text()
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            parse_collectives(hlo), mem, hlo, spec)


def run_corrected(arch: str, shape_name: str, variant: str = "streaming",
                  out_dir: str = "results/roofline", mesh_dims=None,
                  multi_pod: bool = False):
    """Exact-trip-count roofline record via finite differences.

    XLA cost analysis counts a `while` (scan) body ONCE regardless of
    trip count, and fully unrolling big training graphs is prohibitively
    slow to compile. Instead compile the same program with scan
    unroll=1 and unroll=2: the difference isolates one scan-body's
    flops/bytes/collectives, and

        total = u1 + (reps - 1) * (u2 - u1)

    recovers the true per-step totals (tail layers and out-of-loop ops
    live in u1). Validated against a full unroll on qwen3-32b
    decode_32k (see EXPERIMENTS.md §Roofline notes).
    """
    import dataclasses as _dc
    import jax
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.models import get_config

    t0 = time.perf_counter()
    os.environ["REPRO_DISABLE_CHUNKING"] = "1"
    kw = dict(data=mesh_dims[0], model=mesh_dims[1]) if mesh_dims else {}
    mesh = make_production_mesh(multi_pod=multi_pod, **kw)
    base = get_config(arch, tp=mesh.shape["model"], dtype="bfloat16",
                      param_dtype="bfloat16", block_size=steps.BLOCK,
                      **({"remat": True} if SHAPE_KIND(shape_name) == "train"
                         else {}))
    cfg1 = _dc.replace(base, scan_unroll=1)
    cfg2 = _dc.replace(base, scan_unroll=2)
    f1, b1, c1, mem1, hlo1, spec = _compile_stats(cfg1, mesh, shape_name,
                                                  variant)
    f2, b2, c2, *_ = _compile_stats(cfg2, mesh, shape_name, variant)
    R = base.reps
    flops = f1 + (R - 1) * (f2 - f1)
    hbm = b1 + (R - 1) * (b2 - b1)
    coll = {}
    for kind in set(c1) | set(c2):
        a = c1.get(kind, {"count": 0, "bytes": 0})
        b = c2.get(kind, {"count": 0, "bytes": 0})
        coll[kind] = {
            "count": a["count"] + (R - 1) * (b["count"] - a["count"]),
            "bytes": a["bytes"] + (R - 1) * (b["bytes"] - a["bytes"]),
        }
    promo = parse_cpu_promotion_bytes(hlo1)
    n_chips = int(np.prod(list(mesh.shape.values())))
    terms = roofline_terms(flops, hbm, coll, n_chips)
    mf = model_flops(base, spec.meta)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "variant": variant if spec.meta["kind"] == "decode" else "",
        "meta": spec.meta, "n_chips": n_chips,
        "per_device": {
            "flops": flops, "hbm_bytes": hbm,
            "argument_bytes": mem1.argument_size_in_bytes,
            "output_bytes": mem1.output_size_in_bytes,
            "temp_bytes": mem1.temp_size_in_bytes,
            "cpu_promotion_bytes": promo,
            "temp_bytes_tpu_estimate": max(mem1.temp_size_in_bytes - promo, 0),
            "total_bytes": (mem1.argument_size_in_bytes
                            + mem1.output_size_in_bytes
                            + mem1.temp_size_in_bytes),
            "total_bytes_tpu_estimate": (
                mem1.argument_size_in_bytes + mem1.output_size_in_bytes
                + max(mem1.temp_size_in_bytes - promo, 0)),
        },
        "collectives": coll,
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else None,
        "unrolled": True, "method": "trip_count_diff",
        "compile_s": round(time.perf_counter() - t0, 1),
    }
    rec["dominant_term"] = max(("compute_s", "memory_s", "collective_s"),
                               key=lambda k: terms[k])
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{rec['mesh']}"
    if rec["variant"] and rec["variant"] != "streaming":
        tag += f"__{variant}"
    tag += "__unrolled"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    t = terms
    print(f"OK {tag}: flops/dev={flops:.3g} dom={rec['dominant_term']} "
          f"terms=({t['compute_s']:.2e},{t['memory_s']:.2e},"
          f"{t['collective_s']:.2e})s compile={rec['compile_s']}s",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="streaming")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--mesh-dims", default="",
                    help="testing only: 'data,model' override")
    ap.add_argument("--unroll", type=int, default=1,
                    help="scan unroll; -1 = full (exact flops accounting)")
    args = ap.parse_args()
    mesh_dims = tuple(int(x) for x in args.mesh_dims.split(",")) \
        if args.mesh_dims else None

    if args.all:
        from repro.configs import ASSIGNED
        from repro.launch.steps import SHAPES
        failures = []
        for arch in ASSIGNED:
            for shape in SHAPES:
                for mp in (False, True):
                    try:
                        run_one(arch, shape, mp, out_dir=args.out,
                                unroll=args.unroll)
                    except Exception as e:
                        failures.append((arch, shape, mp, repr(e)))
                        print(f"FAIL {arch} {shape} mp={mp}: {e}")
                        traceback.print_exc()
        print(f"{len(failures)} failures")
        raise SystemExit(1 if failures else 0)
    run_one(args.arch, args.shape, args.multi_pod, args.variant, args.out,
            mesh_dims=mesh_dims, unroll=args.unroll)


if __name__ == "__main__":
    main()
