"""``jax.profiler`` start/stop around the first N decoded blocks.

``--profile-blocks N`` captures a device-level profile of exactly the
steady-state region that matters (skipping jit warm-up is the caller's
job — the engine ticks the profiler only after its warm-up wave).
The capture is written as a TensorBoard-loadable trace under
``<trace_dir>/jax_profile``; it complements the host-side Chrome trace
the :class:`~repro.obs.trace.Tracer` exports.

Failure to start the profiler (unsupported backend, second profiler
already live) degrades to a no-op with a warning — observability must
never take down serving.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.obs.log import get_logger

log = get_logger(__name__)


class BlockProfiler:
    """Counts decoded blocks; profiles the first ``n_blocks`` of them.

    Call ``tick(k)`` with the number of blocks decoded since the last
    tick (0 is fine and cheap). The first tick with work starts the
    capture; the tick that crosses ``n_blocks`` stops it. ``close()``
    stops a capture left running at shutdown.
    """

    def __init__(self, trace_dir: str, n_blocks: int):
        self.trace_dir = os.path.join(trace_dir, "jax_profile")
        self.n_blocks = n_blocks
        self.seen = 0
        self.active = False
        self.done = n_blocks <= 0

    def tick(self, blocks_decoded: int) -> None:
        if self.done:
            return
        if not self.active and blocks_decoded > 0:
            try:
                import jax
                os.makedirs(self.trace_dir, exist_ok=True)
                jax.profiler.start_trace(self.trace_dir)
                self.active = True
                log.info("jax profiler started",
                         extra={"trace_dir": self.trace_dir,
                                "profile_blocks": self.n_blocks})
            except Exception as e:
                log.warning("jax profiler unavailable: %s", e)
                self.done = True
                return
        self.seen += blocks_decoded
        if self.active and self.seen >= self.n_blocks:
            self._stop()

    def _stop(self) -> None:
        try:
            import jax
            jax.profiler.stop_trace()
            log.info("jax profiler stopped",
                     extra={"blocks": self.seen,
                            "trace_dir": self.trace_dir})
        except Exception as e:   # pragma: no cover - defensive
            log.warning("jax profiler stop failed: %s", e)
        self.active = False
        self.done = True

    def close(self) -> None:
        if self.active:
            self._stop()
