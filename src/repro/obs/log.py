"""Structured JSON-lines logging for the serving stack.

Loggers live under the ``"repro"`` hierarchy (``repro.server.http``,
``repro.serving.engine``, ...). ``setup_logging`` configures that
*parent* once — handler, level, text vs JSON — so library modules just
``get_logger(__name__)`` and emit; nothing is configured at import
time, and the root logger is never touched (embedding apps keep their
own logging).

JSON mode emits one object per line with a stable core
(``ts``/``level``/``logger``/``msg``) plus any context fields passed
via ``extra=`` — the serving stack uses ``uid``, ``engine``, ``gang``,
and ``trace_id`` so log lines join against trace exports and metrics
by the same identifiers.
"""
from __future__ import annotations

import json
import logging
import sys
import time
from typing import Optional

# Context keys the serving stack attaches via ``extra=``; anything
# else non-standard on the record is passed through too.
_CORE = ("ts", "level", "logger", "msg")
_STD_ATTRS = frozenset(logging.LogRecord(
    "", 0, "", 0, "", (), None).__dict__) | {
        "message", "asctime", "taskName"}


class JsonFormatter(logging.Formatter):
    """One JSON object per record; ``extra=`` fields ride along."""

    def format(self, record: logging.LogRecord) -> str:
        doc = {
            "ts": round(record.created, 6),
            "level": record.levelname,
            "logger": record.name,
            "msg": record.getMessage(),
        }
        for k, v in record.__dict__.items():
            if k not in _STD_ATTRS and k not in _CORE:
                doc[k] = v
        if record.exc_info:
            doc["exc"] = self.formatException(record.exc_info)
        return json.dumps(doc, default=str)


class TextFormatter(logging.Formatter):
    """Human-oriented single line, context fields appended as k=v."""

    def format(self, record: logging.LogRecord) -> str:
        base = (f"{time.strftime('%H:%M:%S', time.localtime(record.created))}"
                f" {record.levelname:<7} {record.name}: "
                f"{record.getMessage()}")
        ctx = " ".join(f"{k}={v}" for k, v in record.__dict__.items()
                       if k not in _STD_ATTRS and k not in _CORE)
        if ctx:
            base = f"{base} [{ctx}]"
        if record.exc_info:
            base = f"{base}\n{self.formatException(record.exc_info)}"
        return base


def setup_logging(level: str = "info", json_mode: bool = False,
                  stream=None) -> logging.Logger:
    """(Re)configure the ``repro`` parent logger. Idempotent: replaces
    any handler a previous call installed rather than stacking."""
    root = logging.getLogger("repro")
    root.setLevel(getattr(logging, level.upper(), logging.INFO))
    fmt: logging.Formatter = JsonFormatter() if json_mode \
        else TextFormatter()
    handler = logging.StreamHandler(stream or sys.stderr)
    handler.setFormatter(fmt)
    for h in list(root.handlers):
        root.removeHandler(h)
    root.addHandler(handler)
    root.propagate = False
    return root


def get_logger(name: Optional[str] = None) -> logging.Logger:
    """Logger under the ``repro`` hierarchy. Module callers pass
    ``__name__`` (already ``repro.*``); bare names are nested."""
    if not name:
        return logging.getLogger("repro")
    if name == "repro" or name.startswith("repro."):
        return logging.getLogger(name)
    return logging.getLogger(f"repro.{name}")
