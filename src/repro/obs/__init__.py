"""Observability subsystem: tracing, decode telemetry, histograms,
structured logging, and profiler hooks.

Layering (threaded through every serving layer):

    trace      — request span trees + per-thread timelines on a
                 lock-free-ish ring-buffer ``Tracer`` (monotonic
                 clocks), exported as Chrome-trace JSON loadable in
                 Perfetto: one track per engine/decode thread plus an
                 async track per request (accept → admission → blocks
                 → finalize), correlated by trace id.
    telemetry  — per-block diffusion dynamics harvested from the fused
                 decode loop in its ONE existing host sync (steps used
                 vs the τ-schedule cap, tokens committed per step,
                 confidence histogram, suffix-window size, early-exit/
                 straggler flags), aggregated per (method, block index).
    metrics    — bucketed ``Histogram`` counters for Prometheus
                 exposition and device memory gauges.
    log        — JSON-lines structured logger carrying uid/engine/gang
                 fields (``--log-level`` / ``--log-json``).
    profiler   — ``jax.profiler`` start/stop around the first N decoded
                 blocks (``--profile-blocks N``).
    audit      — online quality auditing: shadow-oracle re-decode of
                 sampled completions (host-loop and cache-bypass
                 lanes, divergences classified by source and attributed
                 to their block), confidence-calibration/early-exit-
                 regret counters, rolling SLO watchdog, and a
                 flight-recorder post-mortem dump
                 (``--flight-dir`` / ``GET /debug/flight``).
    series     — fleet time-series: a per-engine ``MetricsRecorder``
                 sampling counter deltas on the decode-thread cadence
                 into a bounded ring, derived rate series (tok/s, rps,
                 goodput, busy fractions — the pool-sizing signal),
                 fleet/pool fan-in for ``GET /debug/timeline`` and the
                 ``GET /console`` page, optional ``--metrics-log``
                 JSONL persistence.

Everything is optional: a ``tracer=None`` (the default everywhere)
costs one ``is None`` test per call site, and telemetry rides inside
the already-compiled fused loop, so ``host_syncs_per_block`` is
unchanged with observability on.
"""
from repro.obs.audit import (AuditConfig, AuditResult, FlightRecorder,
                             ShadowAuditor, SLOWatchdog)
from repro.obs.compile import (CompileWatch, persistent_cache_counters,
                               watch_persistent_cache)
from repro.obs.log import get_logger, setup_logging
from repro.obs.metrics import Histogram, device_memory_stats
from repro.obs.profiler import BlockProfiler
from repro.obs.series import (JsonlSink, MetricsRecorder, fleet_series,
                              timeline_doc)
from repro.obs.telemetry import (CONF_BUCKETS, BlockStats,
                                 TelemetryAggregator)
from repro.obs.trace import Tracer, TraceFlusher, span

__all__ = [
    "Tracer", "TraceFlusher", "span", "BlockStats", "TelemetryAggregator",
    "CONF_BUCKETS",
    "Histogram", "device_memory_stats", "BlockProfiler",
    "CompileWatch", "watch_persistent_cache", "persistent_cache_counters",
    "get_logger", "setup_logging",
    "AuditConfig", "AuditResult", "ShadowAuditor", "SLOWatchdog",
    "FlightRecorder",
    "MetricsRecorder", "JsonlSink", "fleet_series", "timeline_doc",
]
