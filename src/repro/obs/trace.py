"""Request span trees and per-thread timelines, exported as
Chrome-trace JSON (the Trace Event Format Perfetto and
``chrome://tracing`` load natively).

Design:

* **ring buffer per thread** — each thread that emits events gets its
  own bounded ``deque``; the hot path is one ``dict`` construction and
  one ``deque.append`` with no lock taken (the registry of rings is
  the only locked structure, touched once per thread). A full ring
  drops its *oldest* events — a long-running server keeps the recent
  window rather than dying or blocking the decode thread.
* **monotonic clocks** — timestamps are ``time.perf_counter_ns``
  deltas from the tracer's birth, emitted in microseconds (the unit
  the trace-event spec mandates). Wall-clock anchors never appear, so
  spans are immune to NTP steps.
* **two track families** — synchronous work is recorded as complete
  (``ph:"X"``) events on the emitting thread's track (one track per
  engine decode thread / asyncio thread), while each request gets an
  *async* track (``ph:"b"``/``"e"``, ``cat:"request"``, ``id`` = trace
  id) whose nested spans form the request's lifecycle tree: accept →
  queue → decode → block k → finalize. Both families can carry
  explicit timestamps, so a span whose bounds are only known after the
  fact (a decoded block, a queue wait) is emitted *once*, complete —
  no dangling ``b`` if the process stops mid-request.

``span(tracer, name, ...)`` is the call-site helper: with
``tracer=None`` (observability off) it returns a shared no-op context
manager, so instrumented code pays one ``is None`` test.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

_NULL_CTX = contextlib.nullcontext()


def span(tracer: Optional["Tracer"], name: str, **args):
    """Thread-track span helper for maybe-absent tracers."""
    return _NULL_CTX if tracer is None else tracer.span(name, **args)


class _Span:
    """Context manager recording one complete ("X") event on exit."""
    __slots__ = ("tr", "name", "pid", "args", "t0")

    def __init__(self, tr: "Tracer", name: str, pid: int, args: dict):
        self.tr = tr
        self.name = name
        self.pid = pid
        self.args = args

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self.tr.complete(self.name, self.t0, t1, pid=self.pid,
                         **self.args)
        return False


class Tracer:
    """Process-wide event sink. All emit methods are callable from any
    thread; ``export``/``events`` snapshot every ring (reads race
    benignly with appends — an event is either in or out, never torn,
    since each event is one append of an immutable dict)."""

    def __init__(self, capacity_per_thread: int = 1 << 16):
        self.capacity = capacity_per_thread
        self._t0 = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._rings: Dict[int, deque] = {}
        self._local = threading.local()
        # pid 0 is the front-end track group; engines claim 1..N via
        # ``process()``
        self._meta: List[dict] = [{"ph": "M", "name": "process_name",
                                   "pid": 0, "tid": 0,
                                   "args": {"name": "frontend"}}]
        self._pids = itertools.count(1)      # 0 = front end
        self._ids = itertools.count(1)
        self.dropped = 0                     # rings that hit capacity

    # ------------------------------------------------------ plumbing

    def _us(self, t_ns: int) -> float:
        return (t_ns - self._t0) / 1e3

    def _ring(self) -> deque:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            ring = deque(maxlen=self.capacity)
            self._local.ring = ring
            with self._lock:
                self._rings[threading.get_ident()] = ring
        return ring

    def _emit(self, ev: dict) -> None:
        ring = self._ring()
        if len(ring) == ring.maxlen:
            self.dropped += 1
        ring.append(ev)

    # ------------------------------------------------------ identity

    def new_trace_id(self) -> str:
        """Process-unique request correlation id (hex, header-safe)."""
        return f"{os.getpid():x}-{next(self._ids):08x}"

    def process(self, label: str) -> int:
        """Allocate a pid (a top-level Perfetto track group) and name
        it — one per engine, plus pid 0 for the front end."""
        with self._lock:
            pid = next(self._pids)
            self._meta.append({"ph": "M", "name": "process_name",
                               "pid": pid, "tid": 0,
                               "args": {"name": label}})
        return pid

    def name_thread(self, label: str, pid: int = 0) -> None:
        with self._lock:
            self._meta.append({"ph": "M", "name": "thread_name",
                               "pid": pid, "tid": threading.get_ident(),
                               "args": {"name": label}})

    # ------------------------------------------------------ emission

    def span(self, name: str, pid: int = 0, **args) -> _Span:
        """Live thread-track span (bounds taken from enter/exit)."""
        return _Span(self, name, pid, args)

    def complete(self, name: str, t0_ns: int, t1_ns: int, pid: int = 0,
                 **args) -> None:
        """Thread-track span with explicit monotonic-ns bounds."""
        self._emit({"ph": "X", "name": name, "pid": pid,
                    "tid": threading.get_ident(),
                    "ts": self._us(t0_ns),
                    "dur": max((t1_ns - t0_ns) / 1e3, 0.001),
                    "args": args})

    def instant(self, name: str, pid: int = 0, **args) -> None:
        self._emit({"ph": "i", "name": name, "pid": pid,
                    "tid": threading.get_ident(), "s": "t",
                    "ts": self._us(time.perf_counter_ns()), "args": args})

    def async_begin(self, trace_id: str, name: str, pid: int = 0,
                    t_ns: Optional[int] = None, **args) -> None:
        """Open one span on the request's async track. Spans sharing a
        trace id nest by timestamp — emit begin/end in lifecycle order
        and Perfetto renders the tree."""
        self._emit({"ph": "b", "cat": "request", "id": trace_id,
                    "name": name, "pid": pid,
                    "tid": threading.get_ident(),
                    "ts": self._us(t_ns if t_ns is not None
                                   else time.perf_counter_ns()),
                    "args": args})

    def async_end(self, trace_id: str, name: str, pid: int = 0,
                  t_ns: Optional[int] = None, **args) -> None:
        self._emit({"ph": "e", "cat": "request", "id": trace_id,
                    "name": name, "pid": pid,
                    "tid": threading.get_ident(),
                    "ts": self._us(t_ns if t_ns is not None
                                   else time.perf_counter_ns()),
                    "args": args})

    def async_span(self, trace_id: str, name: str, t0_ns: int,
                   t1_ns: int, pid: int = 0, **args) -> None:
        """Complete async span with known bounds (e.g. one decoded
        block attributed to each live request after the fact)."""
        self.async_begin(trace_id, name, pid=pid, t_ns=t0_ns, **args)
        self.async_end(trace_id, name, pid=pid, t_ns=t1_ns)

    # ------------------------------------------------------ export

    def events(self) -> List[dict]:
        """Snapshot of every ring, time-ordered, metadata first."""
        with self._lock:
            rings = list(self._rings.values())
            meta = list(self._meta)
        evs: List[dict] = []
        for ring in rings:
            evs.extend(ring)          # deque iteration is GIL-atomic
        evs.sort(key=lambda e: e.get("ts", 0.0))
        return meta + evs

    def request_events(self, trace_id: str) -> List[dict]:
        """Async-track events for one request, time-ordered."""
        return [e for e in self.events() if e.get("id") == trace_id]

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write Chrome-trace JSON atomically (tmp + rename, so a
        reader or a crash mid-write never sees a torn file); returns
        the path written."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_chrome(), f)
        os.replace(tmp, path)
        return path


class TraceFlusher:
    """Periodic Chrome-trace export on a daemon thread, so a crashed or
    killed run keeps its trace up to the last flush instead of losing
    everything to an export that only ran at graceful shutdown. Each
    flush rewrites ``path`` atomically (``Tracer.export``); a failed
    flush is logged-and-dropped, never raised into the process."""

    def __init__(self, tracer: "Tracer", path: str,
                 interval_s: float = 30.0):
        self.tracer = tracer
        self.path = path
        self.interval_s = interval_s
        self.flushes = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-trace-flush")

    def start(self) -> "TraceFlusher":
        self._thread.start()
        return self

    def _run(self) -> None:
        from repro.obs.log import get_logger
        log = get_logger(__name__)
        while not self._stop.wait(self.interval_s):
            try:
                self.tracer.export(self.path)
                self.flushes += 1
            except Exception:
                log.exception("periodic trace flush failed")

    def stop(self, final_flush: bool = True) -> None:
        """Stop the thread; by default write one last (complete)
        export."""
        self._stop.set()
        if self._thread.is_alive():
            self._thread.join(max(self.interval_s, 1.0))
        if final_flush:
            self.tracer.export(self.path)
            self.flushes += 1


def request_tree(events: List[dict]):
    """Rebuild one request's span tree from its async b/e events:
    ``[(name, depth, ts, dur), ...]`` in open order. Raises
    ``ValueError`` on malformed nesting (an ``e`` without a matching
    open ``b``) and reports unclosed spans via depth bookkeeping —
    the well-formedness contract tests/test_obs.py asserts."""
    stack: List[dict] = []
    out = []
    open_idx: List[int] = []
    # at equal timestamps an "e" must sort before the next "b" (a span
    # closing exactly when its sibling opens); ties beyond that keep
    # emission order (sorted() is stable over the ring order)
    for e in sorted(events,
                    key=lambda e: (e["ts"], 0 if e.get("ph") == "e" else 1)):
        if e.get("ph") == "b":
            out.append([e["name"], len(stack), e["ts"], None])
            open_idx.append(len(out) - 1)
            stack.append(e)
        elif e.get("ph") == "e":
            if not stack or stack[-1]["name"] != e["name"]:
                raise ValueError(
                    f"unbalanced async span: end {e['name']!r}, open "
                    f"stack {[s['name'] for s in stack]}")
            b = stack.pop()
            idx = open_idx.pop()
            out[idx][3] = e["ts"] - b["ts"]
    if stack:
        raise ValueError(
            f"unclosed async spans: {[s['name'] for s in stack]}")
    return [tuple(r) for r in out]
