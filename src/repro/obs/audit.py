"""Online quality auditing: shadow-oracle re-decode, confidence
calibration, SLO watchdog, and flight-recorder post-mortems.

Every fast path this stack has grown — the fused device loop, Pallas
kernels, chunk-causal prefix-cache prefill, gang compaction, work
stealing — asserts its correctness through *offline* bit-identity
tests. Nothing watches live traffic. This module closes that gap with
three always-on pieces:

* :class:`ShadowAuditor` samples a configurable fraction of completed
  requests and re-decodes them on a **low-priority lane**: the
  host-loop oracle (``fused`` flipped) and/or a cold, cache-bypass
  decoder (``prompt_cache=None``). Tokens are compared bit-for-bit;
  the first diverging position is attributed to its diffusion block
  and the divergence classified by source — ``fused-vs-host``,
  ``cached-vs-cold``, ``stolen-vs-resident``, or ``dkv-structural``
  (dkv is documented as not batch-invariant, so its divergences are
  expected structure, not alarms). A B=1 re-decode is a valid oracle
  for every *other* method precisely because they are batch-invariant
  (the PR 1 contract the compaction and steal tests already rely on).

* **Confidence calibration + early-exit regret.** The fused loop's
  carry now returns each committed token's commit-time confidence
  (``BlockStats.commit_conf`` — same single host sync per block).
  When an audited request matches its oracle, every token agrees; on a
  divergence the matching prefix agrees and the rest does not. Both
  are binned by commit confidence into ``CONF_BUCKETS`` agree/total
  counters, so Eq. 4 thresholds become monitorable: a low-confidence
  bucket whose agreement decays flags a τ schedule that commits too
  eagerly. Early-exited requests whose audit diverged increment a
  **regret** counter — the EOS that truncated the schedule was not the
  EOS the oracle decoded.

* :class:`SLOWatchdog` + :class:`FlightRecorder`. The watchdog keeps a
  rolling window of completions and evaluates configured TTFB /
  per-token-latency / goodput targets (``repro_slo_*`` metrics). On a
  breach, an audit divergence, or a decode-thread crash, the flight
  recorder dumps the trace ring buffers (Perfetto-loadable), a metrics
  snapshot, and the scheduler/gang state to ``--flight-dir`` — also
  triggerable via ``GET /debug/flight``.

Threading: ``on_completion`` and ``tick`` run on the owning engine's
decode thread (the EngineLoop calls them between scheduler ticks), so
the auditor's counters follow the same single-writer contract as
``ServeMetrics`` mirrors. ``tick`` advances at most **one** decoder
call (one prefill or one block) per invocation and only when the
scheduler's admission signals say paying traffic is idle — the audit
lane can never starve a real request, it decodes in the gaps.

Hot-path discipline (lint-enforced, like the tracer): nothing in this
module may raise out of the serving path. Failures are logged and the
job dropped.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs.log import get_logger
from repro.obs.telemetry import CONF_BUCKETS

log = get_logger("repro.obs.audit")

# divergence source classes (label values of
# repro_audit_divergences_total)
SOURCES = ("fused-vs-host", "cached-vs-cold", "stolen-vs-resident",
           "dkv-structural")


@dataclasses.dataclass
class AuditConfig:
    """Shadow-audit policy. ``sample_rate`` is the fraction of
    completions re-decoded (deterministic stride sampling — every
    ``round(1/rate)``-th completion — so runs are reproducible and two
    engines at the same rate audit the same request indices).
    ``oracle`` picks the re-decode lane(s): ``"host"`` (flip the
    fused/host loop), ``"cold"`` (same loop, prefix cache bypassed),
    ``"both"``, or ``"auto"`` (host always; cold too when the prefix
    cache is on)."""
    sample_rate: float = 0.05
    oracle: str = "auto"
    max_backlog: int = 8         # queued audit jobs before dropping
    max_results: int = 256       # retained AuditResult records

    def __post_init__(self):
        if not 0.0 <= self.sample_rate <= 1.0:
            raise ValueError(f"sample_rate {self.sample_rate} not in [0,1]")
        if self.oracle not in ("host", "cold", "both", "auto"):
            raise ValueError(f"unknown oracle {self.oracle!r}")


@dataclasses.dataclass
class AuditResult:
    """Outcome of one (request, lane) shadow re-decode."""
    uid: int
    trace_id: str
    lane: str                    # "host" | "cold"
    matched: bool
    source: str = ""             # divergence class ("" when matched)
    position: int = -1           # first diverging token (gen-relative)
    block: int = -1              # position // block_size
    span: str = ""               # span-tree node the block decoded in
    n_tokens: int = 0
    expected: int = -1           # oracle token at the divergence
    got: int = -1                # served token at the divergence

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class ShadowAuditor:
    """Samples completions and re-decodes them in traffic gaps.

    One auditor per :class:`~repro.serving.ContinuousEngine`. The audit
    decoders are deliberately *not* registered in the scheduler's
    decoder map: their jit variants must not trip the post-warm compile
    watchdog or pollute the serving compile ledger (an audit lane
    compiling a ``fused=False`` variant is expected, not a pre-warm
    gap).
    """

    def __init__(self, engine, config: Optional[AuditConfig] = None,
                 tracer=None, flight: Optional["FlightRecorder"] = None):
        from repro.core.decoder import DiffusionDecoder  # lazy: heavy

        self._decoder_cls = DiffusionDecoder
        self.engine = engine
        self.config = config or AuditConfig()
        self.tracer = tracer if tracer is not None else engine.tracer
        self.flight = flight
        # test hook: called with (tokens, lane) right before compare;
        # returns (possibly corrupted) tokens. Lets fault-injection
        # tests flip a served token without touching the decode path.
        self.inject: Optional[Callable] = None
        # single-writer counters (decode thread); mirrored into
        # ServeMetrics each engine step like the compile ledger
        self.seen = 0                # completions offered
        self.sampled = 0             # completions picked for audit
        self.completed = 0           # audits finished (all lanes)
        self.dropped = 0             # jobs dropped at a full backlog
        self.errors = 0              # audit attempts that failed
        self.regret = 0              # early-exited requests whose audit
                                     # diverged (the EOS was wrong)
        self.divergences: Dict[str, int] = {s: 0 for s in SOURCES}
        self.conf_agree = [0] * CONF_BUCKETS
        self.conf_total = [0] * CONF_BUCKETS
        self._jobs: deque = deque()
        # in-flight job: (completion, remaining lanes, lane, state)
        self._active = None
        self._lane_decoders: Dict[tuple, object] = {}
        self.results: deque = deque(maxlen=self.config.max_results)

    # ------------------------------------------------------ intake

    def on_completion(self, comp) -> None:
        """Decide whether ``comp`` gets audited. Decode thread; never
        raises (log-and-drop)."""
        try:
            self._on_completion(comp)
        except Exception:
            self.errors += 1
            log.exception("audit intake failed (uid=%s)",
                          getattr(comp, "uid", "?"))

    def _on_completion(self, comp) -> None:
        if self.config.sample_rate <= 0.0:
            return
        if comp.cancelled or comp.prompt_tokens is None \
                or comp.n_blocks == 0:
            return   # partial results have no oracle to agree with
        self.seen += 1
        stride = max(1, round(1.0 / self.config.sample_rate))
        if (self.seen - 1) % stride:
            return
        self.sampled += 1
        if len(self._jobs) >= self.config.max_backlog:
            self.dropped += 1
            return
        self._jobs.append(comp)

    @property
    def pending(self) -> bool:
        return bool(self._jobs or self._active is not None)

    @property
    def backlog(self) -> int:
        return len(self._jobs) + (1 if self._active is not None else 0)

    # ------------------------------------------------------ audit lane

    def tick(self) -> bool:
        """Advance the audit lane by at most one decoder call (one
        prefill or one block). Runs only when the engine's scheduler
        reports no waiting paying traffic and a free slot — the same
        admission signals real requests use, so the audit lane yields
        at every block boundary. Returns True when it did work. Never
        raises."""
        try:
            return self._tick()
        except Exception:
            self.errors += 1
            self._active = None   # drop the poisoned job, keep serving
            log.exception("audit tick failed")
            return False

    def _tick(self) -> bool:
        if self._active is None and not self._jobs:
            return False
        sched = self.engine.scheduler
        if sched.waiting or sched.slots_used >= sched.max_slots:
            return False   # paying traffic owns the engine right now
        if self._active is None:
            comp = self._jobs.popleft()
            lanes = self._lanes(comp)
            if not lanes:
                return False
            self._active = [comp, lanes, None, None]
        comp, lanes, lane, state = self._active
        if lane is None:
            lane = lanes.pop(0)
            dec = self._decoder(lane, comp)
            t0 = time.perf_counter_ns()
            state = dec.prefill(
                np.asarray(comp.prompt_tokens, np.int32)[None])
            self._trace_step("audit_prefill", t0, comp, lane)
            self._active = [comp, lanes, lane, state]
            return True
        dec = self._decoder(lane, comp)
        t0 = time.perf_counter_ns()
        dec.decode_block(state)
        self._trace_step("audit_block", t0, comp, lane,
                         block=state.block_idx - 1)
        if state.finished:
            self._compare(comp, lane, state)
            self._active = [comp, lanes, None, None]
            if not lanes:
                self._active = None
                self.completed += 1
        return True

    def _lanes(self, comp) -> List[str]:
        oracle = self.config.oracle
        cold_ok = (self.engine.dcfg.prefix_cache
                   and self.engine.prefix_cache is not None)
        lanes = []
        if oracle in ("host", "both", "auto"):
            lanes.append("host")
        if oracle == "both" or (oracle == "auto" and cold_ok):
            if oracle == "both" and not cold_ok:
                log.warning("audit oracle 'both' requested but the "
                            "prefix cache is off; skipping cold lane")
            else:
                lanes.append("cold")
        if oracle == "cold":
            lanes = ["cold"] if cold_ok else []
            if not lanes:
                log.warning("audit oracle 'cold' requested but the "
                            "prefix cache is off; nothing to audit")
        return lanes

    def _decoder(self, lane: str, comp):
        """Build (and cache) the oracle decoder for one lane. The
        ``host`` lane flips the fused/host loop and *shares* the
        engine's prefix-cache store — cache effects are held constant,
        so a host-lane divergence isolates the loop implementation. The
        ``cold`` lane keeps the production loop but bypasses the store
        (``prompt_cache=None`` with ``prefix_cache`` still set runs the
        chunked prefill with nothing shared — the documented cache-off
        path), so a cold-lane divergence isolates cached KV content."""
        sched = self.engine.scheduler
        gen_len = len(comp.tokens) if comp.commit_conf is None \
            else len(comp.commit_conf)
        from repro.core.decoder import round_up_blocks
        gen_len = round_up_blocks(max(gen_len, comp.max_tokens),
                                  sched.dcfg.block_size)
        key = (lane, gen_len)
        dec = self._lane_decoders.get(key)
        if dec is None:
            d = dataclasses.replace(sched.dcfg, gen_len=gen_len)
            cache = sched.prefix_cache
            if lane == "host":
                d = dataclasses.replace(d, fused=not d.fused)
            else:
                cache = None
            dec = self._decoder_cls(
                sched.cfg, sched.params, d, mesh=sched.mesh,
                executor=sched.executor, prompt_cache=cache)
            self._lane_decoders[key] = dec
        return dec

    # ------------------------------------------------------ compare

    def _compare(self, comp, lane: str, state) -> None:
        from repro.core.decoder import eos_truncate

        P = state.prompt_len
        gen = np.asarray(state.x[0, P:], np.int32)
        gen, _ = eos_truncate(gen, self.engine.cfg.eos_token_id)
        oracle = gen[:comp.max_tokens]
        served = np.asarray(comp.tokens, np.int32)
        if self.inject is not None:
            served = np.asarray(self.inject(served.copy(), lane), np.int32)
        n = min(len(served), len(oracle))
        neq = np.nonzero(served[:n] != oracle[:n])[0]
        if len(neq):
            pos = int(neq[0])
        elif len(served) != len(oracle):
            pos = n
        else:
            pos = -1
        self._calibrate(comp, n if pos < 0 else pos)
        if pos < 0:
            self.results.append(AuditResult(
                uid=comp.uid, trace_id=comp.trace_id, lane=lane,
                matched=True, n_tokens=len(served)))
            return
        K = self.engine.dcfg.block_size
        block = pos // K
        source = self._classify(lane, comp)
        self.divergences[source] += 1
        if comp.early_exited:
            self.regret += 1
        res = AuditResult(
            uid=comp.uid, trace_id=comp.trace_id, lane=lane,
            matched=False, source=source, position=pos, block=block,
            span=self._span_for_block(comp, block),
            n_tokens=len(served),
            expected=int(oracle[pos]) if pos < len(oracle) else -1,
            got=int(served[pos]) if pos < len(served) else -1)
        self.results.append(res)
        if source == "dkv-structural":
            # documented contract: dkv is not batch-invariant, a B=1
            # re-decode legitimately differs — record, don't alarm
            log.info("audit: dkv structural divergence uid=%s block=%d",
                     comp.uid, block)
        else:
            log.error("audit DIVERGENCE uid=%s lane=%s source=%s "
                      "block=%d pos=%d served=%d oracle=%d span=%r",
                      comp.uid, lane, source, block, pos, res.got,
                      res.expected, res.span)
        if self.tracer is not None:
            self.tracer.instant(
                "audit_divergence", pid=self.engine.obs_pid,
                uid=comp.uid, lane=lane, source=source, block=block,
                position=pos, span=res.span)
        if self.flight is not None and source != "dkv-structural":
            self.flight.dump(f"audit-{source}")

    def _classify(self, lane: str, comp) -> str:
        if self.engine.dcfg.method == "dkv":
            return "dkv-structural"
        if lane == "cold":
            return "cached-vs-cold"
        if comp.stolen:
            return "stolen-vs-resident"
        return "fused-vs-host"

    def _span_for_block(self, comp, block: int) -> str:
        """Attribute the divergence to the span-tree node that decoded
        the block — the ``block N`` async span the scheduler emitted on
        the request's track."""
        name = f"block {block}"
        if self.tracer is None or not comp.trace_id:
            return name
        for ev in self.tracer.request_events(comp.trace_id):
            if ev.get("name") == name:
                return name
        return f"{name} (span evicted)"

    def _calibrate(self, comp, agree_upto: int) -> None:
        """Bin each audited token's commit-time confidence; tokens
        before the first divergence agree with the oracle."""
        cc = comp.commit_conf
        if cc is None:
            return
        n = min(len(cc), len(comp.tokens))
        if n <= 0:
            return
        b = np.clip((np.asarray(cc[:n]) * CONF_BUCKETS).astype(np.int32),
                    0, CONF_BUCKETS - 1)
        for i in range(n):
            self.conf_total[b[i]] += 1
            if i < agree_upto:
                self.conf_agree[b[i]] += 1

    def _trace_step(self, name: str, t0_ns: int, comp, lane: str,
                    **kw) -> None:
        if self.tracer is not None:
            self.tracer.complete(name, t0_ns, time.perf_counter_ns(),
                                 pid=self.engine.obs_pid, uid=comp.uid,
                                 lane=lane, **kw)

    # ------------------------------------------------------ export

    def divergences_total(self) -> int:
        return sum(self.divergences.values())

    def stats(self) -> dict:
        return {
            "seen": self.seen,
            "sampled": self.sampled,
            "completed": self.completed,
            "dropped": self.dropped,
            "errors": self.errors,
            "backlog": self.backlog,
            "regret": self.regret,
            "divergences": dict(self.divergences),
            "conf_agree": list(self.conf_agree),
            "conf_total": list(self.conf_total),
        }


class SLOWatchdog:
    """Rolling SLO evaluation over recent completions. Decode-thread
    writer (``observe`` from EngineLoop's completion funnel); the
    metrics endpoint reads ``current()`` under the same lock. A target
    of ``None`` disables that objective. Breaches latch a counter and
    trigger one debounced flight dump per evaluation window — never an
    exception."""

    def __init__(self, *, ttfb_p50_s: Optional[float] = None,
                 token_latency_s: Optional[float] = None,
                 goodput_tok_s: Optional[float] = None,
                 window: int = 64, min_requests: int = 8,
                 flight: Optional["FlightRecorder"] = None):
        self.targets = {"ttfb_p50_s": ttfb_p50_s,
                        "token_latency_s": token_latency_s,
                        "goodput_tok_s": goodput_tok_s}
        self.window = window
        self.min_requests = min_requests
        self.flight = flight
        self.breaches: Dict[str, int] = {k: 0 for k in self.targets}
        self._breached: Dict[str, bool] = {k: False for k in self.targets}
        self._recent: deque = deque(maxlen=window)
        self._lock = threading.Lock()

    @property
    def enabled(self) -> bool:
        return any(v is not None for v in self.targets.values())

    def observe(self, comp) -> None:
        """Register one completion and re-evaluate. Never raises."""
        try:
            self._observe(comp)
        except Exception:
            log.exception("SLO watchdog observe failed")

    def _observe(self, comp) -> None:
        if not self.enabled or comp.cancelled:
            return
        with self._lock:
            self._recent.append(
                (time.perf_counter(), comp.ttfb_s,
                 comp.latency_s / max(comp.n_tokens, 1), comp.n_tokens))
            state = self._evaluate()
        for key, (value, breach) in state.items():
            was = self._breached[key]
            self._breached[key] = breach
            if breach and not was:
                self.breaches[key] += 1
                log.warning("SLO breach: %s=%.4f vs target %.4f",
                            key, value, self.targets[key])
                if self.flight is not None:
                    self.flight.dump(f"slo-{key}")

    def _evaluate(self) -> Dict[str, tuple]:
        if len(self._recent) < self.min_requests:
            return {}
        rows = list(self._recent)
        out: Dict[str, tuple] = {}
        t = self.targets
        if t["ttfb_p50_s"] is not None:
            v = float(np.percentile([r[1] for r in rows], 50))
            out["ttfb_p50_s"] = (v, v > t["ttfb_p50_s"])
        if t["token_latency_s"] is not None:
            v = float(np.percentile([r[2] for r in rows], 50))
            out["token_latency_s"] = (v, v > t["token_latency_s"])
        if t["goodput_tok_s"] is not None:
            span_s = max(rows[-1][0] - rows[0][0], 1e-9)
            v = sum(r[3] for r in rows[1:]) / span_s
            out["goodput_tok_s"] = (v, v < t["goodput_tok_s"])
        return out

    def current(self) -> dict:
        """Gauge snapshot for ``repro_slo_*`` exposition."""
        with self._lock:
            state = self._evaluate()
        return {
            "targets": {k: v for k, v in self.targets.items()
                        if v is not None},
            "values": {k: v for k, (v, _) in state.items()},
            "breached": {k: int(b) for k, (_, b) in state.items()},
            "breaches_total": dict(self.breaches),
            "window": len(self._recent),
        }


class FlightRecorder:
    """Post-mortem dump sink. ``dump(reason)`` writes one
    ``flight-NNN-<reason>/`` directory under ``flight_dir`` holding

    * ``trace.json`` — the tracer's ring buffers as Perfetto-loadable
      Chrome trace JSON (whatever survived eviction);
    * ``metrics.json`` — every engine's metrics snapshot, telemetry
      rollup, audit stats, and SLO state;
    * ``state.json`` — per-engine scheduler/gang occupancy
      (``BlockScheduler.debug_state``).

    Debounced (``min_interval_s``) and capped (``max_dumps``) so a
    flapping SLO can't fill the disk. Never raises — a failed dump is
    logged and dropped, the serving path continues."""

    def __init__(self, flight_dir: str, tracer=None, *,
                 min_interval_s: float = 10.0, max_dumps: int = 32):
        self.flight_dir = flight_dir
        self.tracer = tracer
        self.min_interval_s = min_interval_s
        self.max_dumps = max_dumps
        self.dumps = 0
        self.suppressed = 0          # debounced / over-cap requests
        self._last_dump = -float("inf")
        self._lock = threading.Lock()
        # () -> dict of JSON-safe state; wired by the server front end
        # (engine metrics + scheduler debug_state + audit/SLO stats)
        self.state_provider: Optional[Callable[[], dict]] = None

    def dump(self, reason: str, force: bool = False) -> Optional[str]:
        """Write one dump; returns its directory or None when debounced
        or failed. Safe from any thread."""
        try:
            return self._dump(reason, force)
        except Exception:
            log.exception("flight dump failed (reason=%s)", reason)
            return None

    def _dump(self, reason: str, force: bool) -> Optional[str]:
        with self._lock:
            now = time.monotonic()
            if not force and (now - self._last_dump < self.min_interval_s
                              or self.dumps >= self.max_dumps):
                self.suppressed += 1
                return None
            self._last_dump = now
            seq = self.dumps
            self.dumps += 1
        safe = "".join(c if c.isalnum() or c in "-_" else "-"
                       for c in reason)[:64]
        path = os.path.join(self.flight_dir, f"flight-{seq:03d}-{safe}")
        os.makedirs(path, exist_ok=True)
        if self.tracer is not None:
            self.tracer.export(os.path.join(path, "trace.json"))
        state = {}
        if self.state_provider is not None:
            try:
                state = self.state_provider()
            except Exception:
                log.exception("flight state provider failed")
                state = {"error": "state provider failed"}
        meta = {"reason": reason, "seq": seq,
                "unix_time": time.time(),
                "dumps": self.dumps, "suppressed": self.suppressed}
        with open(os.path.join(path, "metrics.json"), "w") as f:
            json.dump({"meta": meta,
                       "engines": state.get("engines", []),
                       "slo": state.get("slo")}, f, indent=1)
        with open(os.path.join(path, "state.json"), "w") as f:
            json.dump({"meta": meta,
                       "schedulers": state.get("schedulers", []),
                       "loops": state.get("loops", [])}, f, indent=1)
        if state.get("timeline") is not None:
            # repro.obs.series: the breach window's rate series — the
            # minutes leading up to the trigger, not just its instant
            with open(os.path.join(path, "timeline.json"), "w") as f:
                json.dump({"meta": meta,
                           "timeline": state["timeline"]}, f, indent=1)
        log.warning("flight dump written: %s (reason=%s)", path, reason)
        return path
