"""Fleet time-series recorder: counter deltas sampled on the decode
thread, queryable as windowed rate series.

Everything the stack exposes today is point-in-time — ``/metrics`` is
a snapshot, the SLO watchdog latches breach *onset* — yet the paper's
confidence-aware decoding makes throughput inherently time-varying
(early exit swings tok/s with the prompt mix), and ROADMAP open item 1
needs the prefill/decode busy-seconds *ratio over time* to size
``--pool prefill:N,decode:M``. :class:`MetricsRecorder` closes that
gap:

* **sampling** — ``maybe_sample()`` is called once per ``EngineLoop``
  iteration on the decode thread (the single writer of every counter
  it reads); at most one sample per ``interval_s``. Each sample stores
  ``(t, dt, counter-deltas, gauge-values)`` — deltas *since the
  previous sample*, so ring eviction never corrupts reconstruction
  (a chained absolute-plus-delta encoding would break the moment the
  head sample is dropped).
* **bounded ring** — a ``deque`` sized by ``max_bytes``; a full ring
  drops its oldest sample and counts the drop, exactly the
  :class:`~repro.obs.trace.Tracer` contract. The reader (the asyncio
  thread serving ``/debug/timeline``) snapshots the deque without a
  lock — each sample is one append of an immutable tuple, in or out,
  never torn.
* **rates at query time** — ``series(window_s, step_s)`` buckets the
  samples on a process-shared monotonic grid and derives rate series
  from the per-bucket delta sums: tok/s, rps, goodput,
  cache-hit-tok/s, steal/handoff rates, and the per-pool busy
  *fractions* (``prefill_busy_s`` / ``decode_busy_s`` deltas over
  wall time — the open-item-1 N:M sizing signal). Fleet fan-in
  (:func:`fleet_series`) sums the *raw* per-bucket deltas across
  engines before deriving, so fractions aggregate correctly (an
  average of per-engine rates would not).
* **optional JSONL persistence** — ``--metrics-log`` appends one JSON
  line per sample through a shared :class:`JsonlSink` (lock around the
  write + flush, so concurrent engines never interleave a line;
  ``close()`` at drain means a stopped fleet never leaves a
  half-written record).

Hot-path discipline (lint-enforced, like trace/audit): nothing here
may raise out of the serving path — a recorder failure is logged and
the sample dropped.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from repro.obs.log import get_logger

log = get_logger(__name__)

# cumulative counters sampled each interval (deltas stored); order is
# the wire layout of every ring sample
COUNTERS = (
    "tokens",            # engine.stats (all completions)
    "good_tokens",       # ... from completions that were not cancelled
    "requests",
    "nfe",
    "cancelled",
    "admission_rejects",
    "deadline_misses",
    "steals_in",
    "steals_out",
    "handoffs_in",
    "handoffs_out",
    "cache_hit_tokens",
    "prefill_busy_s",
    "decode_busy_s",
    "busy_s",
    "wall_s",
    "compile_misses",
    "compile_seconds",
    "slo_breaches",
)

# absolute gauge values carried on each sample
GAUGES = ("queue_depth", "live_rows", "inflight", "cache_bytes",
          "audit_backlog")

# derived rate series: name -> (counter, per-second). Fractions divide
# a seconds-counter delta by the bucket's wall dt.
RATES = (
    ("tok_s", "tokens"),
    ("goodput_tok_s", "good_tokens"),
    ("rps", "requests"),
    ("nfe_s", "nfe"),
    ("cache_hit_tok_s", "cache_hit_tokens"),
    ("steal_s", "steals_in"),
    ("handoff_s", "handoffs_in"),
    ("prefill_busy_frac", "prefill_busy_s"),
    ("decode_busy_frac", "decode_busy_s"),
    ("busy_frac", "busy_s"),
)

# per-bucket event counts surfaced as console annotations
EVENTS = (
    ("steals", "steals_in"),
    ("handoffs", "handoffs_in"),
    ("compiles", "compile_misses"),
    ("slo_breaches", "slo_breaches"),
    ("rejects", "admission_rejects"),
)

# conservative per-sample footprint: two tuples of floats plus the
# wrapper tuple (used only to size the ring from max_bytes)
SAMPLE_BYTES = 8 * (len(COUNTERS) + len(GAUGES)) + 240


class JsonlSink:
    """Append-only JSON-lines file shared by every engine's recorder.
    One lock per line write (cold path — once per engine per sampling
    interval), flushed immediately so a crash loses at most the line
    being written, never leaves a torn earlier one. Reference-counted:
    the file closes when the last recorder detaches at drain."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._refs = 0
        self._f = None
        self.lines = 0

    def acquire(self) -> "JsonlSink":
        with self._lock:
            if self._f is None:
                import os
                d = os.path.dirname(self.path)
                if d:
                    os.makedirs(d, exist_ok=True)
                self._f = open(self.path, "a")
            self._refs += 1
        return self

    def write(self, doc: dict) -> None:
        try:
            line = json.dumps(doc) + "\n"
            with self._lock:
                if self._f is None:
                    return
                self._f.write(line)
                self._f.flush()
                self.lines += 1
        except Exception:
            log.exception("metrics-log write failed (dropped)")

    def release(self) -> None:
        with self._lock:
            self._refs -= 1
            if self._refs <= 0 and self._f is not None:
                try:
                    self._f.close()
                except Exception:
                    log.exception("metrics-log close failed")
                self._f = None


class MetricsRecorder:
    """Per-engine background sampler (see module docstring). Owned by
    one ``EngineLoop``; ``maybe_sample``/``close`` run on its decode
    thread, ``series``/``last_rates`` on any reader thread."""

    def __init__(self, engine, *, index: int = 0, role: str = "both",
                 interval_s: float = 0.5, max_bytes: int = 256 << 10,
                 sink: Optional[JsonlSink] = None, watchdog=None,
                 loop=None):
        self.engine = engine
        self.loop = loop                 # owning EngineLoop (inflight gauge)
        self.index = index
        self.role = role
        self.interval_s = max(interval_s, 1e-3)
        self.max_bytes = max_bytes
        self.watchdog = watchdog
        self.sink = sink.acquire() if sink is not None else None
        maxlen = max(16, int(max_bytes // SAMPLE_BYTES))
        self.ring: deque = deque(maxlen=maxlen)
        self.samples = 0
        self.dropped = 0                 # ring evictions
        self.errors = 0                  # failed samples (logged)
        self._closed = False
        self._t0 = time.monotonic()
        self._last_t = self._t0
        self._prev = self._cumulative()

    # ------------------------------------------------------ sampling

    def _cumulative(self):
        """Read every counter's current cumulative value. All reads are
        single ``int``/``float`` attribute loads (GIL-atomic); the
        decode thread is the writer of each, so from ``maybe_sample``
        they are exact, and from ``__init__`` at worst one tick stale."""
        eng = self.engine
        m = eng.metrics
        stats = eng.stats
        breaches = 0
        if self.watchdog is not None:
            try:
                breaches = sum(self.watchdog.breaches.values())
            except Exception:
                pass                     # SLO annotation is best-effort
        return (
            stats.get("tokens", 0),
            stats.get("good_tokens", 0),
            stats.get("requests", 0),
            m.total_nfe,
            m.cancelled,
            m.admission_rejects,
            m.deadline_misses,
            m.steals_in,
            m.steals_out,
            m.handoffs_in,
            m.handoffs_out,
            m.prefix_cache_hit_tokens,
            m.prefill_busy_s,
            m.decode_busy_s,
            m.busy_time_s,
            m.wall_time_s,
            m.compile_misses,
            m.compile_seconds,
            breaches,
        )

    def _gauges(self):
        eng = self.engine
        m = eng.metrics
        try:
            live = eng.scheduler.live_rows
        except Exception:
            live = 0
        # loop._inflight read without its lock: a single GIL-atomic int
        # load, and a gauge may be one tick stale by contract
        inflight = self.loop._inflight if self.loop is not None else 0
        return (m.queue_depth, live, inflight,
                m.prefix_cache_bytes, m.audit_backlog)

    def maybe_sample(self, now: Optional[float] = None) -> bool:
        """Decode-thread cadence hook: one cheap clock read per loop
        iteration, a real sample at most once per ``interval_s``."""
        if self._closed:
            return False
        t = time.monotonic() if now is None else now
        if t - self._last_t < self.interval_s:
            return False
        return self.sample(t)

    def sample(self, now: Optional[float] = None) -> bool:
        """Take one sample unconditionally. Never raises."""
        try:
            t = time.monotonic() if now is None else now
            dt = t - self._last_t
            if dt <= 0:
                return False
            cum = self._cumulative()
            deltas = tuple(c - p for c, p in zip(cum, self._prev))
            gauges = self._gauges()
            if len(self.ring) == self.ring.maxlen:
                self.dropped += 1
            self.ring.append((t, dt, deltas, gauges))
            self._prev = cum
            self._last_t = t
            self.samples += 1
            if self.sink is not None:
                self.sink.write({
                    "engine": self.index, "role": self.role,
                    "t": round(t - self._t0, 4), "dt": round(dt, 4),
                    "d": dict(zip(COUNTERS, deltas)),
                    "g": dict(zip(GAUGES, gauges)),
                })
            return True
        except Exception:
            self.errors += 1
            log.exception("metrics sample failed (dropped)")
            return False

    def close(self) -> None:
        """Drain hook (decode-thread exit): one final sample so the
        tail of the run is recorded, then detach from the JSONL sink —
        a stopped fleet never leaves a live capture or a half-written
        log line. Idempotent."""
        if self._closed:
            return
        self.sample()
        self._closed = True
        if self.sink is not None:
            self.sink.release()

    # ------------------------------------------------------ queries

    @property
    def ring_bytes(self) -> int:
        return len(self.ring) * SAMPLE_BYTES

    def stats(self) -> Dict:
        return {"samples": self.samples, "dropped": self.dropped,
                "errors": self.errors, "ring_bytes": self.ring_bytes,
                "ring_len": len(self.ring), "ring_cap": self.ring.maxlen,
                "interval_s": self.interval_s,
                "log_lines": self.sink.lines if self.sink else 0}

    def last_rates(self) -> Dict:
        """Rates over the most recent sample — the compact snapshot
        ``GET /debug/vars`` embeds per engine."""
        snap = list(self.ring)
        if not snap:
            return {"samples": 0}
        t, dt, deltas, gauges = snap[-1]
        d = dict(zip(COUNTERS, deltas))
        out = {"age_s": round(time.monotonic() - t, 3),
               "dt_s": round(dt, 3), "samples": self.samples}
        for name, counter in RATES:
            out[name] = round(d[counter] / dt, 4)
        out.update(zip(GAUGES, gauges))
        return out

    def buckets(self, window_s: float, step_s: float,
                now: Optional[float] = None) -> List[Optional[dict]]:
        """Raw per-bucket sums over the trailing window: a list of
        ``{counter: delta-sum, "dt": wall-sum}`` (or ``None`` for empty
        buckets), oldest first, on the shared monotonic grid — the
        substrate both per-engine and fleet-aggregated series derive
        from."""
        t_now = time.monotonic() if now is None else now
        n = max(1, int(round(window_s / step_s)))
        start = t_now - n * step_s
        out: List[Optional[dict]] = [None] * n
        for t, dt, deltas, gauges in list(self.ring):
            i = int((t - start) / step_s)
            if i < 0 or i >= n:
                continue
            b = out[i]
            if b is None:
                b = out[i] = dict.fromkeys(COUNTERS, 0.0)
                b["dt"] = 0.0
                b["_gauges"] = list(gauges)
                b["_n"] = 0
            else:
                for j, g in enumerate(gauges):      # keep the latest
                    b["_gauges"][j] = g
            for name, d in zip(COUNTERS, deltas):
                b[name] += d
            b["dt"] += dt
            b["_n"] += 1
        return out

    def series(self, window_s: float = 120.0, step_s: float = 5.0,
               now: Optional[float] = None) -> Dict:
        doc = derive(self.buckets(window_s, step_s, now=now))
        doc.update({"engine": self.index, "role": self.role})
        doc.update(self.stats())
        return doc


def derive(buckets: List[Optional[dict]]) -> Dict:
    """Rate/gauge/event series from raw bucket sums. ``None`` buckets
    (no samples landed there) carry ``None`` values, so a console can
    show gaps instead of faking zeros."""
    rates = {name: [] for name, _ in RATES}
    gauges = {name: [] for name in GAUGES}
    events = {name: [] for name, _ in EVENTS}
    for b in buckets:
        if b is None or b["dt"] <= 0:
            for name, _ in RATES:
                rates[name].append(None)
            for name in GAUGES:
                gauges[name].append(None)
            for name, _ in EVENTS:
                events[name].append(0)
            continue
        dt = b["dt"]
        for name, counter in RATES:
            rates[name].append(round(b[counter] / dt, 4))
        for j, name in enumerate(GAUGES):
            gauges[name].append(b["_gauges"][j])
        for name, counter in EVENTS:
            events[name].append(int(b[counter]))
    return {"rates": rates, "gauges": gauges, "events": events,
            "buckets": len(buckets),
            "filled": sum(b is not None for b in buckets)}


def _merge(acc: Optional[dict], b: Optional[dict]) -> Optional[dict]:
    if b is None:
        return acc
    if acc is None:
        acc = dict.fromkeys(COUNTERS, 0.0)
        acc["dt"] = 0.0
        acc["_gauges"] = [0] * len(GAUGES)
        acc["_n"] = 0
    for name in COUNTERS:
        acc[name] += b[name]
    acc["dt"] += b["dt"]
    acc["_n"] += b["_n"]
    for j in range(len(GAUGES)):        # fleet gauges sum across engines
        acc["_gauges"][j] += b["_gauges"][j]
    return acc


def fleet_series(recorders, window_s: float = 120.0, step_s: float = 5.0,
                 now: Optional[float] = None) -> Dict:
    """Fleet-aggregated series: raw per-bucket deltas are summed across
    engines *before* rates derive, so busy fractions mean "seconds of
    phase work per second of fleet decode-thread time" — the quantity
    the N:M pool-sizing rule compares. Also groups by pool role:
    ``pools[role]`` carries each pool's busy fraction so a
    disaggregated fleet reads its sizing signal directly."""
    t_now = time.monotonic() if now is None else now
    n = max(1, int(round(window_s / step_s)))
    total: List[Optional[dict]] = [None] * n
    by_role: Dict[str, List[Optional[dict]]] = {}
    for rec in recorders:
        bks = rec.buckets(window_s, step_s, now=t_now)
        role = by_role.setdefault(rec.role, [None] * n)
        for i, b in enumerate(bks):
            total[i] = _merge(total[i], b)
            role[i] = _merge(role[i], b)
    doc = derive(total)
    doc["engines"] = len(list(recorders))
    pools = {}
    for role, bks in sorted(by_role.items()):
        d = derive(bks)
        pools[role] = {
            "engines": sum(1 for r in recorders if r.role == role),
            "busy_frac": d["rates"]["busy_frac"],
            "prefill_busy_frac": d["rates"]["prefill_busy_frac"],
            "decode_busy_frac": d["rates"]["decode_busy_frac"],
            "tok_s": d["rates"]["tok_s"],
        }
    doc["pools"] = pools
    return doc


def timeline_doc(loops, window_s: float = 120.0, step_s: float = 5.0,
                 watchdog=None) -> Dict:
    """The ``GET /debug/timeline`` document: per-engine + fleet series
    on one shared time grid (bucket-end offsets in seconds, newest at
    0). ``loops`` is the EngineLoop list; loops without a recorder are
    skipped (the doc says how many reported)."""
    now = time.monotonic()
    recs = [lp.recorder for lp in loops
            if getattr(lp, "recorder", None) is not None]
    n = max(1, int(round(window_s / step_s)))
    t = [round(-(n - 1 - i) * step_s, 3) for i in range(n)]
    doc = {"window_s": window_s, "step_s": step_s, "t": t,
           "engines_total": len(list(loops)),
           "engines_reporting": len(recs),
           "engines": [r.series(window_s, step_s, now=now)
                       for r in recs],
           "fleet": (fleet_series(recs, window_s, step_s, now=now)
                     if recs else None)}
    if watchdog is not None:
        try:
            doc["slo"] = watchdog.current()
        except Exception:
            log.exception("timeline SLO snapshot failed")
    return doc
