"""Bucketed histograms and device memory gauges for /metrics.

``Histogram`` follows the Prometheus model: cumulative bucket counters
(``le`` upper bounds, a ``+Inf`` catch-all), a running sum, and a
count. ``observe`` is lock-guarded — the decode thread observes while
the asyncio thread renders the exposition — and cheap enough for the
per-request/per-block call rates here (a bisect plus three int adds).

``device_memory_stats`` wraps ``jax.Device.memory_stats()``, which is
``None`` on CPU backends — callers get ``{}`` there rather than a
crash, so /metrics works everywhere and shows bytes-in-use only where
the runtime reports it.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, List, Optional, Tuple

# Default bounds (seconds) tuned to the latencies this stack sees on
# CPU: sub-ms queue waits up to multi-second block decodes.
LATENCY_BUCKETS_S = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                     0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

# NFE per generated token is bounded by steps_per_block (≤ block size).
NFE_BUCKETS = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)


class Histogram:
    """Thread-safe cumulative histogram with Prometheus exposition."""

    def __init__(self, name: str, help_text: str,
                 buckets: Iterable[float] = LATENCY_BUCKETS_S):
        self.name = name
        self.help_text = help_text
        self.bounds: Tuple[float, ...] = tuple(sorted(buckets))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._lock = threading.Lock()
        # counts[i] = observations <= bounds[i]; counts[-1] = +Inf
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0
        self._n = 0

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self._counts[i] += 1
            self._sum += value
            self._n += 1

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    def snapshot(self) -> Tuple[List[int], float, int]:
        """(per-bucket counts incl. +Inf, sum, count) — consistent."""
        with self._lock:
            return list(self._counts), self._sum, self._n

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram (same bounds) into this one — used
        to pool per-engine histograms into an aggregate series."""
        if other.bounds != self.bounds:
            raise ValueError("histogram bucket bounds differ")
        counts, s, n = other.snapshot()
        with self._lock:
            for i, c in enumerate(counts):
                self._counts[i] += c
            self._sum += s
            self._n += n

    def prometheus(self, labels: str = "") -> List[str]:
        """Exposition lines. ``labels`` is a pre-rendered label body
        (e.g. ``engine="0"``) merged with the ``le`` label."""
        counts, s, n = self.snapshot()
        lines = [f"# HELP {self.name} {self.help_text}",
                 f"# TYPE {self.name} histogram"]
        sep = "," if labels else ""
        cum = 0
        for bound, c in zip(self.bounds, counts):
            cum += c
            lines.append(f'{self.name}_bucket{{{labels}{sep}le="{bound}"}}'
                         f' {cum}')
        lines.append(f'{self.name}_bucket{{{labels}{sep}le="+Inf"}} {n}')
        body = f"{{{labels}}}" if labels else ""
        lines.append(f"{self.name}_sum{body} {s}")
        lines.append(f"{self.name}_count{body} {n}")
        return lines


def device_memory_stats() -> Dict[str, Dict[str, float]]:
    """Per-device memory stats keyed ``"<platform>:<id>"``. Empty when
    the backend doesn't report them (CPU) or jax is unavailable."""
    try:
        import jax
        devices = jax.devices()
    except Exception:      # pragma: no cover - jax always present here
        return {}
    out: Dict[str, Dict[str, float]] = {}
    for d in devices:
        try:
            stats: Optional[dict] = d.memory_stats()
        except Exception:
            stats = None
        if not stats:
            continue
        out[f"{d.platform}:{d.id}"] = {
            k: float(v) for k, v in stats.items()
            if isinstance(v, (int, float))
        }
    return out
