"""Compile accounting: per-engine jit-variant ledger + process-level
persistent-cache counters.

XLA compiles are the single largest host-side latency source on a cold
engine (the PR 6 trace attributed the multi-engine throughput
regression to concurrent first-block compiles), so they are tracked
like any other resource:

* ``CompileWatch`` — one per ``BlockScheduler``. Every jit-dispatching
  call site (prefill, decode_block, resume re-prime, merge/compaction
  buffer acquire) is wrapped so the scheduler-wide ``jit_cache_size()``
  delta attributes new compiled variants to the call that triggered
  them, with its wall time. After ``mark_warm()`` (the startup pre-warm
  finished), any further compile is a *post-warmup compile*: counted,
  logged loudly, and exported (``repro_post_warm_compiles_total``) —
  the recompile-watchdog test asserts the counter stays zero under a
  mixed-bucket load.
* ``watch_persistent_cache()`` — process-global listener on jax's
  ``/jax/compilation_cache/*`` monitoring events, counting hits and
  misses of the on-disk persistent cache enabled via
  ``repro.launch.host.enable_compile_cache``. These are distinct from
  the CompileWatch numbers: a persistent-cache *hit* still shows up as
  a CompileWatch miss (a new in-process variant was built — just from
  cached bytes instead of an XLA compile).

Both surfaces are read by the ``/metrics`` endpoint and by
``bench_sharded.py`` (zero-post-warm-compiles acceptance line).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from repro.obs.log import get_logger

log = get_logger("obs.compile")


class CompileWatch:
    """Single-writer ledger (the owning engine's decode thread); the
    plain-int counters are mirrored into ``ServeMetrics`` each engine
    step, so cross-thread readers go through the metrics snapshot."""

    def __init__(self) -> None:
        self.misses = 0          # new compiled variants (jit cache grew)
        self.hits = 0            # dispatches fully served by compiled code
        self.seconds = 0.0       # wall attributed to variant-building calls
        self.warm = False        # pre-warm declared complete
        self.post_warm = 0       # variants built after mark_warm()

    def mark_warm(self) -> None:
        self.warm = True

    def counters(self) -> dict:
        """JSON-safe ledger snapshot (debug_state / flight dumps)."""
        return {"misses": self.misses, "hits": self.hits,
                "seconds": self.seconds, "warm": self.warm,
                "post_warm": self.post_warm}

    def watched(self, thunk: Callable, sizer: Callable[[], int],
                what: str, tracer=None, pid: int = 0):
        """Run ``thunk``; attribute any jit-cache growth (measured via
        ``sizer``) to it. Emits a retrospective ``compile`` span on the
        engine's thread track when variants were built, so warm vs cold
        calls are visually distinct in the trace."""
        before = sizer()
        t0_ns = time.perf_counter_ns()
        out = thunk()
        t1_ns = time.perf_counter_ns()
        self.observe(sizer() - before, (t1_ns - t0_ns) / 1e9, what,
                     tracer=tracer, pid=pid, t0_ns=t0_ns, t1_ns=t1_ns)
        return out

    def observe(self, delta: int, wall_s: float, what: str, *,
                tracer=None, pid: int = 0,
                t0_ns: Optional[int] = None,
                t1_ns: Optional[int] = None) -> None:
        if delta <= 0:
            self.hits += 1
            return
        self.misses += delta
        self.seconds += wall_s
        if tracer is not None and t0_ns is not None:
            tracer.complete("compile", t0_ns, t1_ns, pid=pid,
                            variants=delta, what=what)
        if self.warm:
            self.post_warm += delta
            log.warning(
                "post-warmup compile: %d new variant(s) in %s (%.2fs) — "
                "pre-warm missed a (bucket, batch, block) shape",
                delta, what, wall_s)


# ------------------------------------------------ persistent cache events

_pc_lock = threading.Lock()
_pc_counters = {"hits": 0, "misses": 0}
_pc_registered = False


def _on_event(event: str, **kw) -> None:
    if "/jax/compilation_cache/" not in event:
        return
    with _pc_lock:
        if event.endswith("cache_hits"):
            _pc_counters["hits"] += 1
        elif event.endswith("cache_misses"):
            _pc_counters["misses"] += 1


def watch_persistent_cache() -> bool:
    """Register the jax monitoring listener (idempotent). Returns False
    when this jax build exposes no monitoring hooks."""
    global _pc_registered
    if _pc_registered:
        return True
    try:
        from jax._src import monitoring
        monitoring.register_event_listener(_on_event)
    except Exception:
        return False
    _pc_registered = True
    return True


def persistent_cache_counters() -> dict:
    with _pc_lock:
        return dict(_pc_counters)
