"""Per-block diffusion-dynamics telemetry.

The fused decode loop already holds everything interesting on device —
which tokens each step committed, at what confidence, how many steps
the τ schedule actually needed. This module defines the host-side
containers those numbers land in; the decoder harvests them as extra
outputs of the *same* jitted call that returns the block's tokens, so
telemetry adds **zero** host syncs per block (``host_syncs_per_block``
is unchanged with observability on — the acceptance invariant).

Per decoded block the decoder appends one :class:`BlockStats` to
``DecodeState.block_stats``:

* ``committed_per_step[s]`` — tokens committed by confidence/rate
  selection at device step ``s`` (non-done rows only);
* ``straggler_fill`` — tokens force-committed by the end-of-schedule
  straggler finalize (so ``sum(committed_per_step) + straggler_fill ==
  live_rows * block_size`` always holds);
* ``conf_hist`` — histogram of the confidences of committed tokens
  over :data:`CONF_BUCKETS` equal buckets spanning [0, 1];
* ``steps`` vs ``steps_cap`` — device steps used vs the schedule max
  (early exit makes ``steps < steps_cap``);
* ``window`` — suffix/query window size (``Sq``), the paper's pruning
  knob; ``early_exits`` — rows that hit the early-exit test.

:class:`TelemetryAggregator` accumulates those records per
``(method, block_index)`` under a lock (decode thread writes, the
asyncio ``/metrics``/``/telemetry`` reader snapshots).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# Confidence-histogram bucket count over [0, 1). Committed-token
# confidences are max-softmax values, so bucket i covers
# [i/CONF_BUCKETS, (i+1)/CONF_BUCKETS); conf == 1.0 clamps into the
# last bucket.
CONF_BUCKETS = 10


@dataclass
class BlockStats:
    """Dynamics of one decoded block (one ``decode_block`` call)."""
    method: str
    block_idx: int
    batch: int                    # gang batch lanes (incl. padding)
    live_rows: int                # rows not done at block start
    steps: int                    # device steps actually run
    steps_cap: int                # τ-schedule maximum for this block
    committed_per_step: List[int]
    straggler_fill: int           # force-committed at finalize
    conf_hist: List[int]          # len == CONF_BUCKETS
    window: int                   # suffix/query window Sq
    early_exits: int              # rows that early-exited this block
    wall_s: float                 # host wall time of the block call
    # (B, block_size) float32: the confidence each lane's token carried
    # when it was committed (straggler fills record the last step's
    # confidence). Rides the same host sync as the token buffer; the
    # shadow auditor (repro.obs.audit) joins it per-request against the
    # oracle re-decode to calibrate Eq. 4 confidence buckets. Not
    # aggregated by _Agg — the per-request slices are consumed by the
    # scheduler harvest and dropped here.
    commit_conf: object = None

    @property
    def tokens_committed(self) -> int:
        return sum(self.committed_per_step) + self.straggler_fill

    @property
    def nfe(self) -> int:
        return self.steps * self.live_rows


@dataclass
class _Agg:
    """Accumulated dynamics for one (method, block index) key."""
    blocks: int = 0
    live_rows: int = 0
    steps: int = 0
    steps_cap: int = 0
    tokens: int = 0
    straggler_fill: int = 0
    early_exits: int = 0
    wall_s: float = 0.0
    window: int = 0
    committed_per_step: List[int] = field(default_factory=list)
    conf_hist: List[int] = field(
        default_factory=lambda: [0] * CONF_BUCKETS)

    def add(self, bs: BlockStats) -> None:
        self.blocks += 1
        self.live_rows += bs.live_rows
        self.steps += bs.steps
        self.steps_cap += bs.steps_cap
        self.tokens += bs.tokens_committed
        self.straggler_fill += bs.straggler_fill
        self.early_exits += bs.early_exits
        self.wall_s += bs.wall_s
        self.window = bs.window
        if len(bs.committed_per_step) > len(self.committed_per_step):
            self.committed_per_step.extend(
                [0] * (len(bs.committed_per_step)
                       - len(self.committed_per_step)))
        for i, c in enumerate(bs.committed_per_step):
            self.committed_per_step[i] += c
        for i, c in enumerate(bs.conf_hist):
            self.conf_hist[i] += c

    def row(self) -> dict:
        return {
            "blocks": self.blocks,
            "steps_mean": self.steps / max(self.blocks, 1),
            "steps_cap_mean": self.steps_cap / max(self.blocks, 1),
            "tokens": self.tokens,
            "straggler_fill": self.straggler_fill,
            "early_exits": self.early_exits,
            "wall_s": self.wall_s,
            "window": self.window,
            "committed_per_step": list(self.committed_per_step),
            "conf_hist": list(self.conf_hist),
        }


class TelemetryAggregator:
    """Thread-safe per-(method, block index) accumulator of
    :class:`BlockStats`. ``add`` is called from the decode thread per
    block; ``summary``/``totals`` snapshot under the same lock from
    the metrics reader."""

    def __init__(self):
        self._lock = threading.Lock()
        self._by_key: Dict[Tuple[str, int], _Agg] = {}
        self.blocks = 0

    def add(self, bs: BlockStats) -> None:
        with self._lock:
            agg = self._by_key.get((bs.method, bs.block_idx))
            if agg is None:
                agg = self._by_key[(bs.method, bs.block_idx)] = _Agg()
            agg.add(bs)
            self.blocks += 1

    def extend(self, stats: List[BlockStats]) -> None:
        for bs in stats:
            self.add(bs)

    def summary(self) -> dict:
        """``{"method/block_idx": row}`` snapshot, key-sorted."""
        with self._lock:
            items = sorted(self._by_key.items())
            return {f"{m}/{b}": agg.row() for (m, b), agg in items}

    def totals(self) -> dict:
        """Cross-key rollup (drives /metrics gauges)."""
        with self._lock:
            aggs = list(self._by_key.values())
        steps = sum(a.steps for a in aggs)
        caps = sum(a.steps_cap for a in aggs)
        tokens = sum(a.tokens for a in aggs)
        hist = [0] * CONF_BUCKETS
        for a in aggs:
            for i, c in enumerate(a.conf_hist):
                hist[i] += c
        return {
            "blocks": sum(a.blocks for a in aggs),
            "steps": steps,
            "steps_cap": caps,
            "steps_saved_frac": 1.0 - steps / caps if caps else 0.0,
            "tokens": tokens,
            "straggler_fill": sum(a.straggler_fill for a in aggs),
            "early_exits": sum(a.early_exits for a in aggs),
            "wall_s": sum(a.wall_s for a in aggs),
            "conf_hist": hist,
        }
