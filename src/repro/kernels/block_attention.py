"""Pallas TPU kernel: suffix-pruned block attention (flash-style).

The dLLM decode access pattern: a small query region (current block +
pruned suffix + trailing token, typically 33-1057 tokens) attends
bidirectionally over [cached prefix KV || self KV] (up to 512k tokens at
long context). This is the compute hot-spot of every denoise step, so we
tile it explicitly for VMEM:

  grid = (B, H, nQ, nK)   -- nK innermost (sequential on TPU)
  q tile  (TQ, D) VMEM    -- MXU-aligned (TQ, D multiples of 128 ideal)
  k/v tile (TK, D) VMEM
  online-softmax scratch: acc (TQ, D) f32, m/l (TQ, 1) f32

Features folded into the same kernel (all static): GQA head mapping,
attention-logit softcap (gemma2), sliding-window masking (local layers /
long_500k dense variant), and arbitrary KV validity (growing caches and
the dKV position-indexed cache).

Validated on CPU with interpret=True against ref.block_attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# Clamp for the running max so fully-masked tiles don't produce
# exp(-inf - (-inf)) = 1 artifacts.
M_CLAMP = -1e4


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kvpos_ref, kvmask_ref,
            o_ref, acc_ref, m_ref, l_ref, *, scale, softcap, window,
            n_kv_tiles):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, M_CLAMP)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale       # (TQ, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (TK, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)               # (TK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (TQ, TK)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    mask = kvmask_ref[0, :][None, :]                        # (1, TK)
    if window:
        qp = qpos_ref[0, :][:, None]                        # (TQ, 1)
        kp = kvpos_ref[0, :][None, :]                       # (1, TK)
        mask = mask & (jnp.abs(qp - kp) <= window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                     # (TQ, 1)
    m_cur = jnp.maximum(jnp.max(s, axis=1, keepdims=True), M_CLAMP)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                  # (TQ, TK)
    correction = jnp.exp(m_prev - m_new)                    # (TQ, 1)
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * correction + pv
    m_ref[...] = m_new

    @pl.when(j == n_kv_tiles - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "window",
                                             "tq", "tk", "interpret"))
def block_attention(q, k, v, q_pos, kv_pos, kv_mask, *, scale,
                    softcap: float = 0.0, window: int = 0, tq: int = 128,
                    tk: int = 128, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D); masks per ref.py.

    Returns (B, Sq, H, D) f32. Pads Sq/Skv to tile multiples internally.
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    tq = min(tq, max(8, 1 << (Sq - 1).bit_length()))
    tk = min(tk, max(8, 1 << (Skv - 1).bit_length()))
    Sq_p = -(-Sq // tq) * tq
    Skv_p = -(-Skv // tk) * tk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, Sq_p - Sq)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, Skv_p - Skv)))
        kv_mask = jnp.pad(kv_mask, ((0, 0), (0, Skv_p - Skv)))
    nq, nk = Sq_p // tq, Skv_p // tk

    grid = (B, H, nq, nk)
    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               window=window, n_kv_tiles=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, tk, 1, D), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, tk, 1, D), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, tq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, tk), lambda b, h, i, j: (b, j)),
            pl.BlockSpec((1, tk), lambda b, h, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, tq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, H, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tq, D), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32), kv_mask)
    return out[:, :Sq]
