"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def block_attention_ref(q, k, v, q_pos, kv_pos, kv_mask, *, scale,
                        softcap: float = 0.0, window: int = 0):
    """Bidirectional GQA attention with arbitrary KV validity mask.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D); q_pos: (B, Sq) i32;
    kv_pos: (B, Skv) i32; kv_mask: (B, Skv) bool.
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32) * scale
    qg = qf.reshape(B, Sq, Hkv, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if softcap:
        scores = softcap_ref(scores, softcap)
    mask = jnp.broadcast_to(kv_mask[:, None, :], (B, Sq, k.shape[1]))
    if window:
        dist = jnp.abs(q_pos[:, :, None].astype(jnp.int32)
                       - kv_pos[:, None, :].astype(jnp.int32))
        mask = mask & (dist <= window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked query rows emit exactly zero (kernel semantics), not
    # the uniform average that softmax(-inf row) would give
    any_valid = jnp.any(mask, axis=-1)[:, None, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D)


def softcap_ref(x, cap):
    return cap * jnp.tanh(x / cap)


def confidence_argmax_ref(logits):
    """logits: (N, V) f32 -> (conf (N,), idx (N,) i32).

    conf = max softmax prob = exp(max - logsumexp)."""
    m = jnp.max(logits, axis=-1)
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    conf = jnp.exp(m.astype(jnp.float32) - lse)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return conf, idx
