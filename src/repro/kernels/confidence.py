"""Pallas TPU kernel: fused confidence + argmax over the vocabulary.

Eq. 4 of the paper needs, per query position, the max softmax probability
(the commit confidence) and the argmax token. Materializing softmax over
a 256k vocab every denoise step is pure HBM waste; this kernel streams
vocab tiles through VMEM once, tracking running (max, sum-exp, argmax):

  conf = exp(max - logsumexp) = 1 / sumexp_normalized_by_max

  grid = (nS, nV)  -- vocab tiles innermost/sequential
  logits tile (TS, TV) VMEM; scratch m/s (TS,1) f32, amax (TS,1) i32

Validated with interpret=True against ref.confidence_argmax_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(x_ref, conf_ref, idx_ref, m_ref, s_ref, a_ref, *, n_v_tiles, tv):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        a_ref[...] = jnp.zeros_like(a_ref)

    x = x_ref[...].astype(jnp.float32)                       # (TS, TV)
    tile_max = jnp.max(x, axis=1, keepdims=True)             # (TS, 1)
    tile_arg = jnp.argmax(x, axis=1).astype(jnp.int32)[:, None] + j * tv

    m_prev = m_ref[...]
    better = tile_max > m_prev
    m_new = jnp.maximum(m_prev, tile_max)
    s_ref[...] = s_ref[...] * jnp.exp(m_prev - m_new) + \
        jnp.sum(jnp.exp(x - m_new), axis=1, keepdims=True)
    a_ref[...] = jnp.where(better, tile_arg, a_ref[...])
    m_ref[...] = m_new

    @pl.when(j == n_v_tiles - 1)
    def _finalize():
        conf_ref[...] = 1.0 / jnp.maximum(s_ref[...], 1e-30)
        idx_ref[...] = a_ref[...]


@functools.partial(jax.jit, static_argnames=("ts", "tv", "interpret"))
def confidence_argmax(logits, *, ts: int = 128, tv: int = 512,
                      interpret: bool = True):
    """logits: (N, V) -> (conf (N,) f32, idx (N,) i32)."""
    N, V = logits.shape
    ts = min(ts, max(8, 1 << (N - 1).bit_length()))
    tv = min(tv, max(128, 1 << (V - 1).bit_length()))
    N_p = -(-N // ts) * ts
    V_p = -(-V // tv) * tv
    x = logits
    if N_p != N:
        x = jnp.pad(x, ((0, N_p - N), (0, 0)))
    if V_p != V:
        x = jnp.pad(x, ((0, 0), (0, V_p - V)), constant_values=NEG_INF)
    ns, nv = N_p // ts, V_p // tv
    kernel = functools.partial(_kernel, n_v_tiles=nv, tv=tv)
    conf, idx = pl.pallas_call(
        kernel,
        grid=(ns, nv),
        in_specs=[pl.BlockSpec((ts, tv), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((ts, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((ts, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((N_p, 1), jnp.float32),
                   jax.ShapeDtypeStruct((N_p, 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((ts, 1), jnp.float32),
                        pltpu.VMEM((ts, 1), jnp.float32),
                        pltpu.VMEM((ts, 1), jnp.int32)],
        interpret=interpret,
    )(x)
    return conf[:N, 0], idx[:N, 0]
