"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (CPU validation container); on real TPU
set REPRO_PALLAS_INTERPRET=0.
"""
from __future__ import annotations

import math
import os

import jax.numpy as jnp

from repro.kernels.block_attention import block_attention as _block_attention
from repro.kernels.confidence import confidence_argmax as _confidence_argmax

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def block_attention(q, k, v, q_pos, kv_pos, kv_mask, *, scale=None,
                    softcap: float = 0.0, window: int = 0, **kw):
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    kw.setdefault("interpret", INTERPRET)
    return _block_attention(q, k, v, q_pos, kv_pos, kv_mask, scale=scale,
                            softcap=softcap, window=window, **kw)


def sliding_window_attention(q, k, v, q_pos, kv_pos, *, window: int,
                             scale=None, softcap: float = 0.0, **kw):
    """Local-attention specialization (gemma2 local layers, long_500k
    dense variant): full KV validity, distance-window mask only."""
    kv_mask = jnp.ones(kv_pos.shape, jnp.bool_)
    return block_attention(q, k, v, q_pos, kv_pos, kv_mask, scale=scale,
                           softcap=softcap, window=window, **kw)


def confidence_argmax(logits, **kw):
    """logits: (..., V) -> (conf (...,), idx (...,)).

    2-D inputs (the fused-head path feeds row chunks) go straight to the
    kernel — no intermediate full-vocab reshape of an array that is
    already in kernel layout."""
    kw.setdefault("interpret", INTERPRET)
    if logits.ndim == 2:
        return _confidence_argmax(logits, **kw)
    shape = logits.shape[:-1]
    conf, idx = _confidence_argmax(logits.reshape(-1, logits.shape[-1]), **kw)
    return conf.reshape(shape), idx.reshape(shape)


def head_confidence_argmax(hidden, head, *, mask_id: int = -1,
                           logit_softcap: float = 0.0,
                           row_chunk: int = 1024, **kw):
    """Fused LM-head projection + confidence/argmax (Eq. 4) without ever
    materializing the full ``(..., V)`` logits in HBM.

    hidden: (..., d) final hidden states (``apply_model(skip_head=True)``);
    head: (d, V) projection. Rows are chunked so peak memory is
    O(row_chunk x V); within each chunk the Pallas kernel streams vocab
    tiles through VMEM. ``mask_id >= 0`` bans that token (LLaDA: never
    emit [MASK]) inside the projected tile, before the reduction."""
    from repro.core.schedule import chunked_head_reduce
    return chunked_head_reduce(
        hidden, head, lambda logits: confidence_argmax(logits, **kw),
        mask_id=mask_id, logit_softcap=logit_softcap, row_chunk=row_chunk)
