"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (CPU validation container); on real TPU
set REPRO_PALLAS_INTERPRET=0.
"""
from __future__ import annotations

import math
import os

import jax.numpy as jnp

from repro.kernels.block_attention import block_attention as _block_attention
from repro.kernels.confidence import confidence_argmax as _confidence_argmax

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def block_attention(q, k, v, q_pos, kv_pos, kv_mask, *, scale=None,
                    softcap: float = 0.0, window: int = 0, **kw):
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    kw.setdefault("interpret", INTERPRET)
    return _block_attention(q, k, v, q_pos, kv_pos, kv_mask, scale=scale,
                            softcap=softcap, window=window, **kw)


def sliding_window_attention(q, k, v, q_pos, kv_pos, *, window: int,
                             scale=None, softcap: float = 0.0, **kw):
    """Local-attention specialization (gemma2 local layers, long_500k
    dense variant): full KV validity, distance-window mask only."""
    kv_mask = jnp.ones(kv_pos.shape, jnp.bool_)
    return block_attention(q, k, v, q_pos, kv_pos, kv_mask, scale=scale,
                           softcap=softcap, window=window, **kw)


def confidence_argmax(logits, **kw):
    """logits: (..., V) -> (conf (...,), idx (...,))."""
    shape = logits.shape[:-1]
    kw.setdefault("interpret", INTERPRET)
    conf, idx = _confidence_argmax(logits.reshape(-1, logits.shape[-1]), **kw)
    return conf.reshape(shape), idx.reshape(shape)
