"""Serving metrics: per-request latency records and fleet aggregates.

Thread-safety contract: ``ServeMetrics`` is written by exactly one
decode thread (``add_request``/``sample_tick``/counter ``+=``) and read
by the asyncio thread serving ``/metrics`` and ``/health``
(``snapshot``). The mutating entry points and ``snapshot`` share a
lock, so a snapshot never sees a request list mid-append or totals that
mix two completions; the lone-writer counter assignments
(``queue_depth = ...`` etc.) stay bare — a torn read of a single int is
impossible under the GIL and the lock covers every compound update."""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List

import numpy as np

from repro.obs.metrics import (Histogram, LATENCY_BUCKETS_S,
                               NFE_BUCKETS)


def percentile(values, q: float) -> float:
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


@dataclasses.dataclass
class RequestMetrics:
    uid: int
    queue_s: float        # submit -> admitted
    ttfb_s: float         # submit -> first block committed
    latency_s: float      # submit -> finished
    n_tokens: int
    nfe: int
    n_blocks: int
    host_syncs: int = 0   # device->host sync points while the row was live
    logit_syncs: int = 0  # ... of which were full (B, K, V) logit copies
    cache_hit_tokens: int = 0  # prompt KV tokens reused from repro.cache


@dataclasses.dataclass
class ServeMetrics:
    """Aggregated over one engine lifetime. The engine samples slot
    occupancy every scheduler tick and registers each completion."""
    max_slots: int = 0
    requests: List[RequestMetrics] = dataclasses.field(default_factory=list)
    ticks: int = 0
    busy_time_s: float = 0.0           # wall time with >= 1 live row
    wall_time_s: float = 0.0
    occupancy_weighted: float = 0.0    # sum(live/max_slots * tick_dt)
    total_nfe: int = 0
    total_host_syncs: int = 0          # fused loop: ~1 per decoded block
    total_logit_syncs: int = 0         # host loop: 1 per step (fixed-sched)
    # request-lifecycle counters, exported by the HTTP /metrics endpoint
    queue_depth: int = 0               # gauge: queued, not yet in a slot
    admission_rejects: int = 0         # bounded-queue rejections (HTTP 429)
    cancelled: int = 0                 # explicit / disconnect / deadline
    deadline_misses: int = 0           # cancels whose cause was timeout_s
    gang_merges: int = 0               # cross-gang straggler merges
    # cross-request prefix cache (repro.cache): request-level hit
    # counters accumulate per completion; bytes/evictions/nodes are
    # gauges mirrored from the store each engine step
    prefix_cache_hits: int = 0         # completed requests with a warm
                                       # prefill (cache_hit_tokens > 0)
    prefix_cache_hit_tokens: int = 0   # prompt tokens served from cache
    prefix_cache_evictions: int = 0    # chunks evicted (LRU, byte budget)
    prefix_cache_bytes: int = 0        # resident chunk KV bytes
    prefix_cache_nodes: int = 0        # resident chunks
    # block-boundary work stealing (EngineRouter): requests this engine
    # gave up to an idle sibling / adopted from a loaded one
    steals_out: int = 0
    steals_in: int = 0
    # disaggregated prefill/decode pools: busy-seconds split by phase
    # (mirrored from the scheduler each engine step — prefill passes vs
    # decode_block walls) and the prefill→decode handoff flow through
    # the shared radix store
    prefill_busy_s: float = 0.0
    decode_busy_s: float = 0.0
    handoffs_out: int = 0              # rows this engine primed and gave up
    handoffs_in: int = 0               # rows adopted from the prefill pool
    handoff_wait_s: float = 0.0        # extraction -> decode-pool adoption
    # compile ledger (repro.obs.CompileWatch, mirrored each engine
    # step): new jit variants built vs dispatches served warm, wall
    # attributed to variant-building calls, and — after startup
    # pre-warm — variants that should not exist
    compile_misses: int = 0
    compile_hits: int = 0
    compile_seconds: float = 0.0
    post_warm_compiles: int = 0
    prewarmed: int = 0                 # 1 once Engine.prewarm() finished
    # effective host budget (repro.launch.host): XLA:CPU intra-op pool
    # threads this engine's dispatches may use (0 = unbudgeted)
    host_threads: int = 0
    # shadow auditor (repro.obs.audit, mirrored each engine step):
    # completions sampled for re-decode, audits finished, jobs dropped
    # at the bounded backlog, bit-level divergences found, and the
    # current backlog depth (gauge)
    audits_sampled: int = 0
    audits_completed: int = 0
    audit_dropped: int = 0
    audit_divergences: int = 0
    audit_backlog: int = 0
    audit_regret: int = 0              # early-exited rows the oracle
                                       # would have continued differently
    # decode thread writes / asyncio metrics reader snapshots
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    # bucketed distributions for Prometheus exposition (each histogram
    # carries its own lock; observed on the decode thread)
    hist_ttfb: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(
            "repro_ttfb_seconds", "Submit to first committed block",
            LATENCY_BUCKETS_S), repr=False, compare=False)
    hist_queue: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(
            "repro_queue_wait_seconds", "Submit to gang admission",
            LATENCY_BUCKETS_S), repr=False, compare=False)
    hist_block_wall: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(
            "repro_block_wall_seconds", "Wall time of one decode_block",
            LATENCY_BUCKETS_S), repr=False, compare=False)
    hist_nfe_per_token: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(
            "repro_nfe_per_token", "Model evaluations per emitted token",
            NFE_BUCKETS), repr=False, compare=False)
    hist_handoff: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(
            "repro_handoff_wait_seconds",
            "Prefill-pool extraction to decode-pool adoption",
            LATENCY_BUCKETS_S), repr=False, compare=False)

    def sample_tick(self, live_rows: int, tick_dt: float) -> None:
        with self._lock:
            self.ticks += 1
            self.wall_time_s += tick_dt
            if live_rows:
                self.busy_time_s += tick_dt
            if self.max_slots:
                self.occupancy_weighted += \
                    (live_rows / self.max_slots) * tick_dt

    def add_request(self, rm: RequestMetrics) -> None:
        with self._lock:
            self.requests.append(rm)
            self.total_nfe += rm.nfe
            self.total_host_syncs += rm.host_syncs
            self.total_logit_syncs += rm.logit_syncs
        self.hist_ttfb.observe(rm.ttfb_s)
        self.hist_queue.observe(rm.queue_s)
        self.hist_nfe_per_token.observe(rm.nfe / max(rm.n_tokens, 1))

    @property
    def histograms(self) -> List[Histogram]:
        return [self.hist_ttfb, self.hist_queue, self.hist_block_wall,
                self.hist_nfe_per_token, self.hist_handoff]

    # ------------------------------------------------------ aggregates

    @property
    def total_tokens(self) -> int:
        with self._lock:
            return sum(r.n_tokens for r in self.requests)

    @property
    def throughput(self) -> float:
        """Generated tokens per second of scheduler wall time."""
        with self._lock:
            tokens = sum(r.n_tokens for r in self.requests)
            return tokens / max(self.wall_time_s, 1e-9)

    @property
    def mean_occupancy(self) -> float:
        with self._lock:
            return self.occupancy_weighted / max(self.wall_time_s, 1e-9)

    @property
    def total_blocks(self) -> int:
        with self._lock:
            return sum(r.n_blocks for r in self.requests)

    def snapshot(self) -> Dict:
        with self._lock:
            requests = list(self.requests)
            wall = self.wall_time_s
            occ = self.occupancy_weighted
            total_nfe = self.total_nfe
            total_syncs = self.total_host_syncs
        lat = [r.latency_s for r in requests]
        ttfb = [r.ttfb_s for r in requests]
        tokens = sum(r.n_tokens for r in requests)
        blocks = sum(r.n_blocks for r in requests)
        return {
            "requests": len(requests),
            "tokens": tokens,
            "wall_time_s": wall,
            "throughput_tok_s": tokens / max(wall, 1e-9),
            "mean_occupancy": occ / max(wall, 1e-9),
            "total_nfe": total_nfe,
            "nfe_per_request": (total_nfe / len(requests)
                                if requests else 0.0),
            # decode-loop residency: the fused device loop syncs ~once
            # per block; the legacy host loop once (or more) per step
            "total_host_syncs": total_syncs,
            "host_syncs_per_block": (total_syncs / blocks
                                     if blocks else 0.0),
            "device_steps_per_block": (total_nfe / blocks
                                       if blocks else 0.0),
            "logit_host_copies": self.total_logit_syncs,
            "queue_depth": self.queue_depth,
            "admission_rejects": self.admission_rejects,
            "cancelled": self.cancelled,
            "deadline_misses": self.deadline_misses,
            "gang_merges": self.gang_merges,
            "prefix_cache_hits": self.prefix_cache_hits,
            "prefix_cache_hit_tokens": self.prefix_cache_hit_tokens,
            "prefix_cache_evictions": self.prefix_cache_evictions,
            "prefix_cache_bytes": self.prefix_cache_bytes,
            "prefix_cache_nodes": self.prefix_cache_nodes,
            "busy_time_s": self.busy_time_s,
            "prefill_busy_s": self.prefill_busy_s,
            "decode_busy_s": self.decode_busy_s,
            "handoffs_out": self.handoffs_out,
            "handoffs_in": self.handoffs_in,
            "handoff_wait_s": self.handoff_wait_s,
            "queue_wait_s": sum(r.queue_s for r in requests),
            "steals_out": self.steals_out,
            "steals_in": self.steals_in,
            "compile_misses": self.compile_misses,
            "compile_hits": self.compile_hits,
            "compile_seconds": self.compile_seconds,
            "post_warm_compiles": self.post_warm_compiles,
            "prewarmed": self.prewarmed,
            "host_threads": self.host_threads,
            "audits_sampled": self.audits_sampled,
            "audits_completed": self.audits_completed,
            "audit_dropped": self.audit_dropped,
            "audit_divergences": self.audit_divergences,
            "audit_backlog": self.audit_backlog,
            "audit_regret": self.audit_regret,
            "latency_p50_s": percentile(lat, 50),
            "latency_p99_s": percentile(lat, 99),
            "ttfb_p50_s": percentile(ttfb, 50),
            "ttfb_p99_s": percentile(ttfb, 99),
        }
