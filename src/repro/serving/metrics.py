"""Serving metrics: per-request latency records and fleet aggregates."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


def percentile(values, q: float) -> float:
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


@dataclasses.dataclass
class RequestMetrics:
    uid: int
    queue_s: float        # submit -> admitted
    ttfb_s: float         # submit -> first block committed
    latency_s: float      # submit -> finished
    n_tokens: int
    nfe: int
    n_blocks: int
    host_syncs: int = 0   # device->host sync points while the row was live
    logit_syncs: int = 0  # ... of which were full (B, K, V) logit copies
    cache_hit_tokens: int = 0  # prompt KV tokens reused from repro.cache


@dataclasses.dataclass
class ServeMetrics:
    """Aggregated over one engine lifetime. The engine samples slot
    occupancy every scheduler tick and registers each completion."""
    max_slots: int = 0
    requests: List[RequestMetrics] = dataclasses.field(default_factory=list)
    ticks: int = 0
    busy_time_s: float = 0.0           # wall time with >= 1 live row
    wall_time_s: float = 0.0
    occupancy_weighted: float = 0.0    # sum(live/max_slots * tick_dt)
    total_nfe: int = 0
    total_host_syncs: int = 0          # fused loop: ~1 per decoded block
    total_logit_syncs: int = 0         # host loop: 1 per step (fixed-sched)
    # request-lifecycle counters, exported by the HTTP /metrics endpoint
    queue_depth: int = 0               # gauge: queued, not yet in a slot
    admission_rejects: int = 0         # bounded-queue rejections (HTTP 429)
    cancelled: int = 0                 # explicit / disconnect / deadline
    deadline_misses: int = 0           # cancels whose cause was timeout_s
    gang_merges: int = 0               # cross-gang straggler merges
    # cross-request prefix cache (repro.cache): request-level hit
    # counters accumulate per completion; bytes/evictions/nodes are
    # gauges mirrored from the store each engine step
    prefix_cache_hits: int = 0         # completed requests with a warm
                                       # prefill (cache_hit_tokens > 0)
    prefix_cache_hit_tokens: int = 0   # prompt tokens served from cache
    prefix_cache_evictions: int = 0    # chunks evicted (LRU, byte budget)
    prefix_cache_bytes: int = 0        # resident chunk KV bytes
    prefix_cache_nodes: int = 0        # resident chunks

    def sample_tick(self, live_rows: int, tick_dt: float) -> None:
        self.ticks += 1
        self.wall_time_s += tick_dt
        if live_rows:
            self.busy_time_s += tick_dt
        if self.max_slots:
            self.occupancy_weighted += (live_rows / self.max_slots) * tick_dt

    def add_request(self, rm: RequestMetrics) -> None:
        self.requests.append(rm)
        self.total_nfe += rm.nfe
        self.total_host_syncs += rm.host_syncs
        self.total_logit_syncs += rm.logit_syncs

    # ------------------------------------------------------ aggregates

    @property
    def total_tokens(self) -> int:
        return sum(r.n_tokens for r in self.requests)

    @property
    def throughput(self) -> float:
        """Generated tokens per second of scheduler wall time."""
        return self.total_tokens / max(self.wall_time_s, 1e-9)

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_weighted / max(self.wall_time_s, 1e-9)

    @property
    def total_blocks(self) -> int:
        return sum(r.n_blocks for r in self.requests)

    def snapshot(self) -> Dict:
        lat = [r.latency_s for r in self.requests]
        ttfb = [r.ttfb_s for r in self.requests]
        blocks = self.total_blocks
        return {
            "requests": len(self.requests),
            "tokens": self.total_tokens,
            "wall_time_s": self.wall_time_s,
            "throughput_tok_s": self.throughput,
            "mean_occupancy": self.mean_occupancy,
            "total_nfe": self.total_nfe,
            "nfe_per_request": (self.total_nfe / len(self.requests)
                                if self.requests else 0.0),
            # decode-loop residency: the fused device loop syncs ~once
            # per block; the legacy host loop once (or more) per step
            "total_host_syncs": self.total_host_syncs,
            "host_syncs_per_block": (self.total_host_syncs / blocks
                                     if blocks else 0.0),
            "device_steps_per_block": (self.total_nfe / blocks
                                       if blocks else 0.0),
            "logit_host_copies": self.total_logit_syncs,
            "queue_depth": self.queue_depth,
            "admission_rejects": self.admission_rejects,
            "cancelled": self.cancelled,
            "deadline_misses": self.deadline_misses,
            "gang_merges": self.gang_merges,
            "prefix_cache_hits": self.prefix_cache_hits,
            "prefix_cache_hit_tokens": self.prefix_cache_hit_tokens,
            "prefix_cache_evictions": self.prefix_cache_evictions,
            "prefix_cache_bytes": self.prefix_cache_bytes,
            "prefix_cache_nodes": self.prefix_cache_nodes,
            "latency_p50_s": percentile(lat, 50),
            "latency_p99_s": percentile(lat, 99),
            "ttfb_p50_s": percentile(ttfb, 50),
            "ttfb_p99_s": percentile(ttfb, 99),
        }
