"""Continuous-batching serving engine: the user-facing front end over
``BlockScheduler`` + ``PrefixKVPool`` + ``StreamRouter`` + metrics.

    eng = ContinuousEngine(cfg, params, dcfg, max_slots=8)
    uid = eng.submit("Q:12+34=? A:", max_tokens=32)
    for chunk in eng.stream():          # per-block streaming
        print(chunk.uid, chunk.text, end="")
    print(eng.metrics.snapshot())

or drive it like the legacy synchronous engine:

    eng.submit(...); completions = eng.run_to_completion()
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Iterator, List, Optional, Union

import numpy as np

from repro.core.decoder import DecodeConfig, DecodeState
from repro.data.tokenizer import ByteTokenizer
from repro.models.config import ModelConfig
from repro.obs.telemetry import TelemetryAggregator
from repro.obs.trace import span
from repro.serving.metrics import RequestMetrics, ServeMetrics
from repro.serving.pool import PrefixKVPool
from repro.serving.scheduler import BlockScheduler
from repro.serving.stream import RequestStream, StreamRouter
from repro.serving.types import (BlockChunk, Completion, ServeRequest,
                                 round_up_blocks)


class ContinuousEngine:
    def __init__(self, cfg: ModelConfig, params, dcfg: DecodeConfig, *,
                 max_slots: int = 8, max_gang: Optional[int] = None,
                 pool: Optional[PrefixKVPool] = None,
                 max_waiting: Optional[int] = None,
                 tokenizer=None, mesh=None, pad_pow2: bool = False,
                 executor=None, prefix_cache=None, tracer=None,
                 host_budget=None, prefill_only: bool = False):
        self.cfg = cfg
        self.dcfg = dcfg
        self.executor = executor
        # prefill-pool member (disaggregated serving): primes prompt KV
        # into the shared radix store and hands rows to the decode pool
        # instead of decoding blocks — see BlockScheduler.prefill_only
        self.prefill_only = prefill_only
        # effective per-engine host compute budget (repro.launch.host
        # applies it process-wide before jax init; the engine carries it
        # for /metrics and trace metadata)
        self.host_budget = host_budget
        self.tok = tokenizer or ByteTokenizer(cfg.vocab_size)
        # one pool per executor: buffers are placed on the executor's
        # mesh and must never migrate (see PrefixKVPool)
        self.pool = pool if pool is not None \
            else PrefixKVPool(cfg, executor=executor)
        self.metrics = ServeMetrics(max_slots=max(max_slots, 1))
        # per-(method, block index) decode dynamics — always on: the
        # numbers ride the fused loop's existing host sync, and the
        # aggregator add is a dict update per block
        self.telemetry = TelemetryAggregator()
        self.tracer = tracer
        self.obs_pid = 0
        self.scheduler = BlockScheduler(
            cfg, params, dcfg, max_slots=max_slots, max_gang=max_gang,
            pool=self.pool, max_waiting=max_waiting, tokenizer=self.tok,
            mesh=mesh, pad_pow2=pad_pow2, executor=executor,
            prefix_cache=prefix_cache, prefill_only=prefill_only,
            tracer=tracer, telemetry=self.telemetry,
            block_hist=self.metrics.hist_block_wall)
        self.metrics.max_slots = self.scheduler.max_slots
        # cross-request prefix KV store (None unless dcfg.prefix_cache;
        # the scheduler creates and owns placement binding)
        self.prefix_cache = self.scheduler.prefix_cache
        self.router = StreamRouter()
        self.stats = defaultdict(float)    # legacy ServingEngine keys
        # jax.profiler window over the first N decoded blocks
        # (repro.obs.profiler.BlockProfiler); ticked from step()
        self.profiler = None
        self._prof_blocks_seen = 0
        # shadow auditor (repro.obs.audit): attached by the owning loop
        # or front end; its counters mirror into metrics each step like
        # the compile ledger
        self.auditor = None
        if host_budget is not None:
            self.metrics.host_threads = host_budget.intra_op

    def set_tracer(self, tracer, label: str) -> None:
        """Attach (or re-attach) a tracer and claim a named track for
        this engine — called by the owning EngineLoop/front end, which
        knows the engine's index in the fleet."""
        self.tracer = tracer
        self.obs_pid = tracer.process(label)
        self.scheduler.tracer = tracer
        self.scheduler.pid = self.obs_pid
        if self.host_budget is not None:
            # stamp the effective budget onto the engine's track so a
            # trace always records what resources it ran under
            tracer.instant("host_budget", pid=self.obs_pid,
                           intra_op=self.host_budget.intra_op,
                           cores=self.host_budget.cores,
                           engines=self.host_budget.engines,
                           source=self.host_budget.source)

    # ------------------------------------------------------ submission

    def submit(self, prompt: Union[str, np.ndarray],
               max_tokens: int = 64, trace_id: str = "") -> int:
        toks = self.tok.encode(prompt) if isinstance(prompt, str) \
            else np.asarray(prompt, np.int32)
        gen_len = round_up_blocks(max_tokens, self.dcfg.block_size)
        t_ns = time.perf_counter_ns()
        try:
            req = self.scheduler.submit(toks, gen_len, max_tokens,
                                        trace_id=trace_id)
        except RuntimeError:
            self.metrics.admission_rejects += 1
            raise
        if self.tracer is not None and trace_id:
            # "request" opens just before the scheduler's "queue" span
            # (explicit earlier timestamp) and closes in _record — the
            # one terminal point every path (EOS, length, cancel,
            # deadline, disconnect) funnels through
            self.tracer.async_begin(trace_id, "request", pid=self.obs_pid,
                                    t_ns=t_ns, uid=req.uid,
                                    max_tokens=max_tokens)
        return req.uid

    def expected_prefix_hit(self, prompt: Union[str, np.ndarray]) -> int:
        """Longest prefix (tokens) of ``prompt`` resident in this
        engine's cross-request cache. 0 when caching is off. Pure read
        over the store — the multi-engine router calls it from the
        asyncio thread as its cache-affinity signal."""
        if self.prefix_cache is None:
            return 0
        toks = self.tok.encode(prompt) if isinstance(prompt, str) \
            else np.asarray(prompt, np.int32)
        return self.prefix_cache.match_len(toks)

    # ------------------------------------------------------ pre-warm

    def prewarm(self, buckets, batch_sizes=None) -> dict:
        """Compile every (prompt_len, gen_len) × gang-batch × block
        fused-decode variant this engine can hit under load, *before*
        admission opens — so no request ever pays a first-block compile,
        and concurrent engines never compile inside each other's decode
        window (the PR 6 regression). ``buckets`` is an iterable of
        ``(prompt_len, gen_len)`` shape buckets; ``batch_sizes``
        defaults to every padded gang size admission or compaction can
        produce (1..max_gang through ``_pad_batch``, plus raw 1 for
        resumed single rows). Marks the compile ledger warm; any compile
        after this is counted, logged, and exported as
        ``repro_post_warm_compiles_total``."""
        sched = self.scheduler
        if batch_sizes:
            sizes = sorted(set(batch_sizes))
        else:
            sizes = sorted({1} | {sched._pad_batch(n)
                                  for n in range(1, sched.max_gang + 1)})
        t0 = time.perf_counter()
        before = sched.jit_cache_size()
        for (P, gen_len) in buckets:
            decoder = sched.decoder_for(gen_len)
            # dummy prompts must not enter the radix store: detach it
            # for the warmup (the n_hit=0 prefill path compiles the
            # same chunk variants a store miss would)
            store, decoder.prompt_cache = decoder.prompt_cache, None
            try:
                for B in sizes:
                    # pass 1 exercises a FRESH pool buffer, pass 2 a
                    # RECYCLED one (released by pass 1). The two can
                    # carry spelling-distinct-but-equivalent shardings
                    # (explicit out_shardings vs compiler-chosen output
                    # spec), which the jit cache treats as different
                    # variants — loop until the cache stops growing so
                    # both families are compiled before admission.
                    for _ in range(3):
                        before_b = sched.jit_cache_size()
                        with span(self.tracer, "prewarm",
                                  pid=self.obs_pid, batch=B,
                                  prompt_len=P, gen_len=gen_len):
                            self._prewarm_one(decoder, P, gen_len, B)
                        if sched.jit_cache_size() == before_b:
                            break
            finally:
                decoder.prompt_cache = store
        variants = sched.jit_cache_size() - before
        wall = time.perf_counter() - t0
        sched.compile_watch.mark_warm()
        self.metrics.prewarmed = 1
        self.metrics.compile_misses = sched.compile_watch.misses
        self.metrics.compile_seconds = sched.compile_watch.seconds
        return {"buckets": [list(b) for b in buckets],
                "batch_sizes": sizes, "variants": variants,
                "seconds": round(wall, 2)}

    def _prewarm_one(self, decoder, P: int, gen_len: int, B: int) -> None:
        sched = self.scheduler
        watch = sched.compile_watch
        prompts = np.full((B, P), 1, np.int32)
        cache = None
        if decoder.dcfg.method != "vanilla":
            cache = watch.watched(
                lambda: self.pool.acquire(B, P + gen_len),
                sched.jit_cache_size, "prewarm_acquire",
                tracer=self.tracer, pid=self.obs_pid)
        state = watch.watched(
            lambda: decoder.prefill(prompts, cache=cache),
            sched.jit_cache_size, "prewarm_prefill",
            tracer=self.tracer, pid=self.obs_pid)
        if self.prefill_only:
            # a prefill-pool engine never decodes a block: warming only
            # the pool-acquire + chunk-prefill variants keeps its
            # startup cost proportional to the work it actually does
            if state.cache is not None:
                self.pool.release(B, P + gen_len, state.cache)
                state.cache = None
            return
        while state.block_idx < state.n_blocks:
            watch.watched(
                lambda: decoder.decode_block(state),
                sched.jit_cache_size, "prewarm_block",
                tracer=self.tracer, pid=self.obs_pid)
            # untrained/chatty params may emit EOS on dummy prompts;
            # clearing done (a runtime array — same compiled fn) keeps
            # every later block-index variant getting compiled too
            state.done[:] = False
        if state.cache is not None:
            self.pool.release(B, P + gen_len, state.cache)
            state.cache = None

    # ------------------------------------------------------ stealing

    def steal_waiting(self) -> Optional[ServeRequest]:
        """Give up the newest waiting request to an idle sibling (see
        ``BlockScheduler.steal_waiting``); closes this engine's
        "request" span — the thief's re-submission opens a fresh one on
        its own track with the same trace id."""
        req = self.scheduler.steal_waiting()
        if req is not None:
            self._close_stolen_span(req)
            self.metrics.steals_out += 1
        return req

    def steal_paused(self):
        """Give up one host-portable parked row as ``(req, state)`` (or
        None); same span discipline as ``steal_waiting``."""
        out = self.scheduler.steal_paused()
        if out is not None:
            self._close_stolen_span(out[0])
            self.metrics.steals_out += 1
        return out

    def _close_stolen_span(self, req: ServeRequest) -> None:
        if self.tracer is not None and req.trace_id:
            self.tracer.async_end(req.trace_id, "request",
                                  pid=self.obs_pid, uid=req.uid,
                                  stolen=True)

    def adopt_paused(self, req: ServeRequest, state: DecodeState) -> int:
        """Adopt a stolen mid-decode row: reopens the request's span
        pair on this engine's track and parks it for the normal resume
        path. Returns the fresh uid."""
        self.metrics.steals_in += 1
        t_ns = time.perf_counter_ns()
        uid = self.scheduler.adopt_paused(req, state)
        if self.tracer is not None and req.trace_id:
            # "request" reopens just before the scheduler's "queue"
            # span (explicit earlier timestamp keeps nesting sound)
            self.tracer.async_begin(req.trace_id, "request",
                                    pid=self.obs_pid, t_ns=t_ns,
                                    uid=uid, stolen=True)
        return uid

    # ------------------------------------------------------ handoff

    def take_handoffs(self) -> List[ServeRequest]:
        """Drain the rows the last prefill-only step primed (chunk KV
        already published to the shared store). Closes each request's
        "request" span on this engine's track tagged ``handoff=True``
        — the decode-pool adopter reopens it, exactly like the steal
        span contract."""
        out = self.scheduler.take_handoffs()
        for req in out:
            self.metrics.handoffs_out += 1
            if self.tracer is not None and req.trace_id:
                self.tracer.async_end(req.trace_id, "request",
                                      pid=self.obs_pid, uid=req.uid,
                                      handoff=True)
        return out

    def adopt_handoff(self, req: ServeRequest,
                      wait_s: Optional[float] = None) -> int:
        """Adopt a prefill-pool-primed request onto this engine's
        waiting queue (its prompt KV comes out of the shared store at
        admission). ``wait_s`` is the extraction→adoption gap the
        owning loop measured. Returns the fresh uid."""
        self.metrics.handoffs_in += 1
        if wait_s is not None:
            self.metrics.handoff_wait_s += wait_s
            self.metrics.hist_handoff.observe(wait_s)
        t_ns = time.perf_counter_ns()
        uid = self.scheduler.adopt_handoff(req)
        if self.tracer is not None and req.trace_id:
            self.tracer.async_begin(req.trace_id, "request",
                                    pid=self.obs_pid, t_ns=t_ns,
                                    uid=uid, handoff=True)
        return uid

    def preempt(self, uid: int) -> None:
        self.scheduler.preempt(uid)

    def cancel(self, uid: int) -> Optional[Completion]:
        """Terminate a request and free its slot (≠ ``preempt``, which
        parks the state for resumption). Waiting/paused requests finish
        here and now — the partial ``Completion`` is returned and a
        terminal chunk is published so any stream consumer shuts down.
        Active rows are released at the next block boundary and their
        ``Completion`` (``cancelled=True``) comes out of that ``step``;
        this returns ``None`` for them."""
        comp = self.scheduler.cancel(uid)
        if comp is not None:
            self._record(comp)
            self.router.publish([BlockChunk(
                uid, 0, np.zeros(0, np.int32), "", True, False)])
        return comp

    def on_chunk(self, uid: Optional[int], fn) -> None:
        """Register a per-block callback (``uid=None`` = all requests)."""
        self.router.subscribe(uid, fn)

    def open_stream(self, uid: int) -> RequestStream:
        return RequestStream(self.router, uid)

    # ------------------------------------------------------ stepping

    def step(self) -> List[Completion]:
        """One scheduler tick: every live gang advances one block."""
        t0 = time.perf_counter()
        chunks, completions = self.scheduler.tick()
        dt = time.perf_counter() - t0
        # occupancy uses the row count whose decode this tick paid for
        # (sampled pre-harvest), not the post-compaction remainder
        self.metrics.sample_tick(self.scheduler.last_decoded_rows, dt)
        self.router.publish(chunks)
        for comp in completions:
            self._record(comp)
        if chunks or completions:
            self.stats["batches"] += 1
        self.stats["time_s"] += dt
        self.metrics.queue_depth = len(self.scheduler.waiting)
        self.metrics.gang_merges = self.scheduler.merges
        # phase-split busy seconds (single decode-thread writer)
        self.metrics.prefill_busy_s = self.scheduler.prefill_wall_s
        self.metrics.decode_busy_s = self.scheduler.decode_wall_s
        # mirror the compile ledger (single decode-thread writer)
        watch = self.scheduler.compile_watch
        self.metrics.compile_misses = watch.misses
        self.metrics.compile_hits = watch.hits
        self.metrics.compile_seconds = watch.seconds
        self.metrics.post_warm_compiles = watch.post_warm
        if self.prefix_cache is not None:
            st = self.prefix_cache.stats()
            self.metrics.prefix_cache_bytes = st["bytes"]
            self.metrics.prefix_cache_evictions = st["evictions"]
            self.metrics.prefix_cache_nodes = st["nodes"]
        if self.profiler is not None:
            blocks = self.telemetry.blocks
            self.profiler.tick(blocks - self._prof_blocks_seen)
            self._prof_blocks_seen = blocks
        self._mirror_audit()
        return completions

    def _record(self, comp: Completion) -> None:
        self.metrics.add_request(RequestMetrics(
            uid=comp.uid, queue_s=comp.queue_s, ttfb_s=comp.ttfb_s,
            latency_s=comp.latency_s, n_tokens=comp.n_tokens,
            nfe=comp.nfe, n_blocks=comp.n_blocks,
            host_syncs=comp.host_syncs, logit_syncs=comp.logit_syncs,
            cache_hit_tokens=comp.cache_hit_tokens))
        if comp.cache_hit_tokens > 0:
            self.metrics.prefix_cache_hits += 1
            self.metrics.prefix_cache_hit_tokens += comp.cache_hit_tokens
        if comp.cancelled:
            self.metrics.cancelled += 1
        if self.tracer is not None and comp.trace_id:
            self.tracer.async_end(comp.trace_id, "request",
                                  pid=self.obs_pid, uid=comp.uid,
                                  cancelled=comp.cancelled)
        self.stats["requests"] += 1
        self.stats["tokens"] += comp.n_tokens
        if not comp.cancelled:
            # goodput: tokens from completions a client actually kept
            # (the repro.obs.series rate decomposition tok_s vs
            # goodput_tok_s reads these two counters)
            self.stats["good_tokens"] += comp.n_tokens
        if self.auditor is not None:
            self.auditor.on_completion(comp)

    def attach_auditor(self, auditor) -> None:
        """Attach a :class:`repro.obs.audit.ShadowAuditor`. Decode
        thread only from then on — the auditor's counters share the
        metrics mirror's single-writer contract."""
        self.auditor = auditor

    def audit_tick(self) -> bool:
        """Advance the audit lane by at most one decoder call (no-op
        without an auditor or when paying traffic is active — the
        auditor itself defers to the scheduler's admission signals).
        Returns True when audit work ran."""
        if self.auditor is None:
            return False
        ran = self.auditor.tick()
        if ran:
            # audits finish between scheduler steps — mirror here too,
            # or counters go stale once the engine idles
            self._mirror_audit()
        return ran

    def _mirror_audit(self) -> None:
        if self.auditor is None:
            return
        a = self.auditor
        self.metrics.audits_sampled = a.sampled
        self.metrics.audits_completed = a.completed
        self.metrics.audit_dropped = a.dropped
        self.metrics.audit_divergences = a.divergences_total()
        self.metrics.audit_backlog = a.backlog
        self.metrics.audit_regret = a.regret

    @property
    def audit_pending(self) -> bool:
        return self.auditor is not None and self.auditor.pending

    def drain_audits(self) -> None:
        """Run the audit backlog to empty (offline/test convenience;
        the serving loop instead interleaves single ``audit_tick``
        calls between scheduler ticks)."""
        while self.audit_pending:
            if not self.audit_tick():
                break

    def run_to_completion(self) -> List[Completion]:
        out: List[Completion] = []
        while not self.scheduler.idle:
            out.extend(self.step())
        return out

    def stream(self) -> Iterator[BlockChunk]:
        """Tick until every submitted request finishes, yielding chunks
        as blocks commit. Chunks per request arrive in block order."""
        pending: List[BlockChunk] = []
        self.router.subscribe(None, pending.append)
        try:
            while not self.scheduler.idle:
                self.step()
                while pending:
                    yield pending.pop(0)
        finally:
            self.router.unsubscribe(None, pending.append)

    def generate_stream(self, prompt, max_tokens: int = 64) \
            -> Iterator[BlockChunk]:
        """Submit one request and yield only its chunks."""
        uid = self.submit(prompt, max_tokens)
        for chunk in self.stream():
            if chunk.uid == uid:
                yield chunk
                if chunk.finished:
                    return

    # ------------------------------------------------------ reporting

    @property
    def throughput(self) -> float:
        return self.stats["tokens"] / max(self.stats["time_s"], 1e-9)

    def jit_cache_size(self) -> int:
        return self.scheduler.jit_cache_size()
