"""Request/response records shared across the serving subsystem."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.decoder import round_up_blocks  # re-export; single def

__all__ = ["ServeRequest", "BlockChunk", "Completion", "round_up_blocks"]


@dataclasses.dataclass
class ServeRequest:
    """One queued generation request plus its lifecycle timestamps."""
    uid: int
    prompt_tokens: np.ndarray          # (P,) int32
    gen_len: int                       # rounded up to a block multiple
    max_tokens: int
    submit_time: float
    admit_time: float = -1.0
    first_block_time: float = -1.0     # TTFB anchor
    finish_time: float = -1.0
    nfe: int = 0                       # batch steps while this row was live
    blocks_decoded: int = 0
    preempted: int = 0                 # times kicked back to the queue
    eos_seen: bool = False             # a streamed chunk contained EOS
    host_syncs: int = 0                # device->host sync points attributed
    logit_syncs: int = 0               # ... of which full-logit copies
    expected_hit_tokens: int = 0       # prefix-cache match at submit time
    cache_hit_tokens: int = 0          # prompt tokens whose prefill KV was
                                       # assembled from the cross-request
                                       # prefix cache (0 = cold)
    trace_id: str = ""                 # repro.obs correlation id ("" = off)
    stolen: int = 0                    # times adopted mid-decode by another
                                       # engine (adopt_paused)
    handoffs: int = 0                  # times migrated prefill→decode pool
                                       # (disaggregated serving; KV travels
                                       # through the shared radix store)
    commit_conf: list = dataclasses.field(default_factory=list)
                                       # per harvested block: (K,) float32
                                       # commit-time confidences for this
                                       # row (repro.obs.audit calibration)

    @property
    def bucket(self):
        """Shape bucket: requests sharing it can decode in one batch."""
        return (int(self.prompt_tokens.shape[0]), self.gen_len)


@dataclasses.dataclass
class BlockChunk:
    """One streamed block of committed tokens for a request. ``tokens``
    are the raw block tokens (may extend past EOS); ``text`` is the
    EOS-truncated decoded piece. ``finished`` marks the request's last
    chunk."""
    uid: int
    block_idx: int
    tokens: np.ndarray
    text: str
    finished: bool
    eos: bool                          # this block decoded an EOS


@dataclasses.dataclass
class Completion:
    """Terminal record for a request (superset of the legacy
    ``repro.core.engine.Completion`` field names). ``tokens``/``text``
    are trimmed to the *requested* ``max_tokens``, not the block-rounded
    ``gen_len`` — network front ends must never over-return. Cancelled
    requests (explicit cancel, client disconnect, deadline expiry)
    carry whatever was committed before the cancel took effect."""
    uid: int
    text: str
    tokens: np.ndarray                 # (<= max_tokens,) EOS-truncated
    latency_s: float                   # submit -> finish
    nfe: int
    ttfb_s: float = 0.0                # submit -> first block committed
    queue_s: float = 0.0               # submit -> admitted to a slot
    n_tokens: int = 0                  # non-EOS tokens generated
    n_blocks: int = 0
    max_tokens: int = 0                # requested budget (pre-rounding)
    cancelled: bool = False            # partial result: freed early
    host_syncs: int = 0                # host sync points while live
    logit_syncs: int = 0               # (B, K, V) logit copies while live
    cache_hit_tokens: int = 0          # prefix-cache tokens reused at
                                       # prefill (repro.cache)
    expected_hit_tokens: int = 0       # router/admission-time estimate
    trace_id: str = ""                 # repro.obs correlation id ("" = off)
    prompt_tokens: Optional[np.ndarray] = None
                                       # (P,) int32 — kept so the shadow
                                       # auditor can re-decode the request
    commit_conf: Optional[np.ndarray] = None
                                       # (n_blocks*K,) float32 commit-time
                                       # confidences (untrimmed gen axis)
    stolen: bool = False               # decoded partly on an adopting engine
    handed_off: bool = False           # primed on a prefill-pool engine,
                                       # decoded on a decode-pool engine
    early_exited: bool = False         # an EOS block skipped later blocks

    @property
    def tokens_per_s(self) -> float:
        return self.n_tokens / max(self.latency_s, 1e-9)
