"""Shape- and placement-bucketed KV-cache buffer pool.

Every decode method except dKV rewrites the prefix (and, with
``frozen_suffix``, the pruned-suffix) KV at each block refresh and masks
staleness with ``kv_valid``, so a buffer handed to a new request needs
no zeroing: reuse is free. The pool therefore only has to bound
*allocation* churn — ``init_cache`` builds a whole per-layer pytree of
(B, T, H, D) zeros, which at production shapes is the dominant
per-request host cost and a fresh device allocation each time.

Buffers are keyed by ``(batch, total_len, placement)`` — the shape
bucketing the scheduler uses for gangs plus the ``DecodeExecutor``
placement key. The placement component exists for the multi-engine
world: a pool is *bound to one executor* (one mesh), allocation routes
through it so buffers are born sharded, and a buffer placed on one
mesh can never be handed to a decoder driving another — that would be
a silent cross-device copy at best and a reuse of donated (dead)
memory at worst. Engines must therefore hold one pool per executor;
``BlockScheduler`` enforces the binding at construction.

Buffers are retained on a bounded free list with oldest-first
eviction.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.models.config import ModelConfig
from repro.models.model import init_cache

HOST_PLACEMENT = ("host",)    # the executor-less single-device world


class PrefixKVPool:
    def __init__(self, cfg: ModelConfig, max_free: int = 8, executor=None):
        self.cfg = cfg
        self.max_free = max_free
        self.executor = executor
        self.placement: Tuple = (executor.placement if executor is not None
                                 else HOST_PLACEMENT)
        self._free: List[Tuple[int, tuple, Any]] = []
        self._seq = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _key(self, batch: int, total_len: int) -> tuple:
        return (batch, total_len, self.placement)

    def acquire(self, batch: int, total_len: int):
        """Return a cache pytree for the bucket, reusing the most
        recently released matching buffer when one exists."""
        key = self._key(batch, total_len)
        for i in range(len(self._free) - 1, -1, -1):
            if self._free[i][1] == key:
                _, _, cache = self._free.pop(i)
                self.hits += 1
                return cache
        self.misses += 1
        if self.executor is not None:
            return self.executor.init_cache(batch, total_len)
        return init_cache(self.cfg, batch, total_len)

    def release(self, batch: int, total_len: int, cache) -> None:
        if cache is None:
            return
        self._seq += 1
        self._free.append((self._seq, self._key(batch, total_len), cache))
        while len(self._free) > self.max_free:
            self._free.pop(0)
            self.evictions += 1

    @property
    def free_buffers(self) -> int:
        return len(self._free)

    def free_bytes(self) -> int:
        total = 0
        for _, _, cache in self._free:
            total += sum(getattr(leaf, "nbytes", 0)
                         for leaf in jax.tree.leaves(cache))
        return total

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "free_buffers": len(self._free),
                "free_bytes": self.free_bytes()}
