"""Continuous batching at diffusion-block granularity.

The unit of work is one *block* of one *gang* — a batch of requests
sharing a shape bucket ``(prompt_len, gen_len)`` that advance in
lockstep through ``DiffusionDecoder.decode_block``. Every scheduler
tick advances each live gang by one block, then harvests: finished rows
(EOS early exit or last block) emit their final chunk immediately, and
the gang is *compacted* — live rows are gathered into the next
power-of-two batch bucket, freed slots are backfilled from the waiting
queue at the same tick, and the old KV buffer returns to the
``PrefixKVPool``. Compiled step shapes are therefore fixed per
(bucket, batch-pow2, block-index) triple: after warmup no request
causes a recompile.

Exactness: compaction relies on ``DiffusionDecoder.batch_invariant`` —
per-row results are bit-identical under batch reshaping for every
method except dkv, whose step-level KV freezing drifts at ulp level
when the batch changes. dkv gangs therefore keep their admitted batch
until every row finishes (matching the synchronous engine), while the
other methods shrink and backfill freely.

Preemption is block-level: ``preempt(uid)`` extracts the row's
``DecodeState`` at the next block boundary, parks it without a KV
buffer, and re-admits it ahead of the waiting queue when a slot frees —
resuming at the exact block it left off.

Cancellation is distinct from preemption: ``cancel(uid)`` gives the
slot up for good and terminates the request with a *partial*
``Completion`` (whatever was committed so far, EOS/max_tokens
trimmed). A waiting or paused request is cancelled immediately; an
active row is released at the next block boundary — before the next
tick's decode, so a cancelled request never pays for another block.
The async front end (``repro.server``) drives it on client disconnect
and deadline expiry.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.cache import PrefixKVCache
from repro.cache.store import HOST_PLACEMENT
from repro.core.decoder import (DecodeConfig, DecodeState, DiffusionDecoder,
                                eos_truncate)
from repro.models.config import ModelConfig
from repro.obs.compile import CompileWatch
from repro.obs.trace import span
from repro.serving.pool import PrefixKVPool
from repro.serving.types import BlockChunk, Completion, ServeRequest


def _pow2_ge(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _pow2_le(n: int) -> int:
    assert n >= 1
    return 1 << (n.bit_length() - 1)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


class Gang:
    """A batch of requests decoding in lockstep, one block per tick.
    ``requests[i] is None`` marks a padding or vacated lane."""

    def __init__(self, decoder: DiffusionDecoder, state: DecodeState,
                 requests: List[Optional[ServeRequest]]):
        self.decoder = decoder
        self.state = state
        self.requests = requests
        # rows whose final chunk has been emitted (padding lanes never emit)
        self.emitted = [r is None for r in requests]
        # state.nfe high-water mark already attributed to requests. A
        # fresh gang starts at 0 so the dkv prefill pass (counted into
        # state.nfe by prefill()) reaches the first harvest's delta;
        # compacted/resumed states restart their counters at 0 too.
        self.nfe_seen = 0
        self.syncs_seen = 0          # state.host_syncs high-water mark
        self.logit_syncs_seen = 0    # state.logit_syncs high-water mark
        # (B, K) commit-time confidences of the block drained this tick
        # (set by _drain_block_stats, consumed by _harvest same-tick)
        self.last_commit_conf = None

    @property
    def batch(self) -> int:
        return self.state.batch

    def live_rows(self) -> List[int]:
        """Rows still producing output."""
        return [i for i, r in enumerate(self.requests)
                if r is not None and not self.emitted[i]]

    def open_rows(self) -> List[int]:
        """Rows that still need future blocks (drive compaction)."""
        return [i for i, r in enumerate(self.requests)
                if r is not None and not self.state.row_finished(i)]


class BlockScheduler:
    def __init__(self, cfg: ModelConfig, params, dcfg: DecodeConfig, *,
                 max_slots: int = 8, max_gang: Optional[int] = None,
                 pool: Optional[PrefixKVPool] = None,
                 max_waiting: Optional[int] = None,
                 tokenizer=None, mesh=None, pad_pow2: bool = False,
                 executor=None, batch_multiple: Optional[int] = None,
                 merge_gangs: bool = True,
                 prefix_cache: Optional[PrefixKVCache] = None,
                 prefill_only: bool = False,
                 tracer=None, telemetry=None, block_hist=None):
        self.cfg = cfg
        self.params = params
        self.dcfg = dcfg
        self.executor = executor
        # Disaggregated serving: a prefill-only scheduler admits and
        # prefills gangs exactly like a co-located one (hit-homogeneous
        # grouping included) but never decodes a block — each primed
        # gang is dismantled into ``handoff_ready`` the same tick, its
        # chunk KV already published to the (shared) radix store, and
        # the owning EngineLoop migrates the requests to a decode-pool
        # engine (see ``take_handoffs`` / ``adopt_handoff``).
        self.prefill_only = prefill_only
        self.handoff_ready: List[ServeRequest] = []
        # busy-seconds split by phase (prefill = prefill/re-prime
        # passes, decode = decode_block walls) — pool imbalance in a
        # disaggregated fleet is visible here before it costs tok/s
        self.prefill_wall_s = 0.0
        self.decode_wall_s = 0.0
        # Gang batches sized as a multiple of the mesh's data-axis
        # extent shard evenly; any other size falls back to replicated
        # placement (never silent padding — see DecodeExecutor). The
        # scheduler therefore *rounds gang sizes up* to this multiple
        # (pad rows replicate row 0, exactly like pad_pow2 padding).
        self.batch_multiple = (batch_multiple if batch_multiple is not None
                               else (executor.data_extent
                                     if executor is not None else 1))
        self.max_slots = max(max_slots, self.batch_multiple)
        self.max_gang = min(max_gang or self.max_slots, self.max_slots)
        # pad_pow2 snaps gang sizes to a power-of-two ladder: fewest
        # compiled batch shapes (log2(max_slots) sizes), at the price of
        # pad rows that burn compute — worth it when compiles are the
        # scarce resource (large accelerator graphs). The default uses
        # exact sizes: at most max_slots distinct batch shapes, and
        # every freed row immediately stops costing FLOPs.
        self.pad_pow2 = pad_pow2
        if pool is None:
            pool = PrefixKVPool(cfg, executor=executor)
        elif pool.executor is not executor:
            # a shared pool across meshes would hand buffers placed on
            # one mesh to decoders driving another — refuse up front
            raise ValueError(
                "PrefixKVPool must be bound to the scheduler's executor "
                f"(pool.executor={pool.executor!r}, "
                f"scheduler executor={executor!r})")
        self.pool = pool
        # cross-request prefix KV store (repro.cache): like the pool,
        # one store per executor placement — chunk KV shapes/numerics
        # are mesh-specific, so a store warmed on one mesh must never
        # feed a decoder driving another
        placement = (executor.placement if executor is not None
                     else HOST_PLACEMENT)
        # vanilla has no KV cache at all — a store could never be
        # filled or read, so it is not silently carried: the scheduler
        # runs storeless (no probes, no hit-keyed admission groups)
        use_store = dcfg.prefix_cache and dcfg.method != "vanilla"
        if prefix_cache is not None and not use_store:
            raise ValueError(
                "a PrefixKVCache store needs DecodeConfig.prefix_cache "
                "and a non-vanilla method "
                f"(prefix_cache={dcfg.prefix_cache}, "
                f"method={dcfg.method!r})")
        if use_store and prefix_cache is None:
            prefix_cache = PrefixKVCache(chunk_tokens=dcfg.cache_chunk,
                                         placement=placement)
        if prefix_cache is not None:
            # a *shared* store (disaggregated pools) is keyed by mesh
            # shape, not device ids: chunk KV is host-staged numpy and
            # its numerics depend only on the mesh shape, so any
            # same-shape executor may publish and consume it
            shape_key = (executor.shape_key if executor is not None
                         else HOST_PLACEMENT)
            ok = (tuple(prefix_cache.placement) == tuple(placement)
                  or (prefix_cache.shared
                      and tuple(prefix_cache.placement) == tuple(shape_key)))
            if not ok:
                raise ValueError(
                    "PrefixKVCache must be bound to the scheduler's "
                    f"executor placement (store={prefix_cache.placement}, "
                    f"scheduler={placement}, shared needs {shape_key})")
            if prefix_cache.chunk_tokens != dcfg.cache_chunk:
                raise ValueError(
                    f"PrefixKVCache chunk {prefix_cache.chunk_tokens} != "
                    f"DecodeConfig.cache_chunk {dcfg.cache_chunk}")
        self.prefix_cache = prefix_cache if use_store else None
        self.max_waiting = max_waiting
        self.tok = tokenizer
        self.mesh = mesh if executor is None else executor.mesh
        self.merge_gangs = merge_gangs
        self.waiting: Deque[ServeRequest] = deque()
        self.paused: Deque[Tuple[ServeRequest, DecodeState,
                                 DiffusionDecoder]] = deque()
        self.gangs: List[Gang] = []
        self._decoders: Dict[int, DiffusionDecoder] = {}
        self._preempt: set = set()
        self._cancel: set = set()
        self._uid = 0
        self.last_decoded_rows = 0
        self.merges = 0            # cross-gang straggler merges performed
        # observability (repro.obs) — all optional. ``tracer`` records
        # queue/decode/block spans on the request's async track plus
        # prefill/decode_block spans on this engine's thread track
        # (``pid`` names the track; the owning EngineLoop sets it);
        # ``telemetry`` accumulates the per-block BlockStats the decoder
        # harvests; ``block_hist`` observes per-block wall time.
        self.tracer = tracer
        self.telemetry = telemetry
        self.block_hist = block_hist
        self.pid = 0
        # innermost open async span per traced uid ("queue" | "decode"
        # | "paused") — the bookkeeping that keeps span trees balanced
        # through cancel/preempt/deadline paths
        self._span_state: Dict[int, str] = {}
        # compile ledger: every jit-dispatching call site below runs
        # through it so new compiled variants are attributed to the
        # call that built them (and flagged if they appear after the
        # startup pre-warm declared the engine warm)
        self.compile_watch = CompileWatch()

    # ------------------------------------------------------ bookkeeping

    def _decoder(self, gen_len: int) -> DiffusionDecoder:
        if gen_len not in self._decoders:
            d = dataclasses.replace(self.dcfg, gen_len=gen_len)
            self._decoders[gen_len] = DiffusionDecoder(
                self.cfg, self.params, d, mesh=self.mesh,
                executor=self.executor, prompt_cache=self.prefix_cache)
        return self._decoders[gen_len]

    def decoder_for(self, gen_len: int) -> DiffusionDecoder:
        """Public accessor for the per-``gen_len`` decoder (the engine's
        pre-warm drives it directly, outside the admission path)."""
        return self._decoder(gen_len)

    def _pad_batch(self, n: int) -> int:
        """Gang-size policy: optional pow2 ladder, then round up to the
        data-shard multiple so sharding never falls back silently."""
        padded = _pow2_ge(n) if self.pad_pow2 else n
        return _round_up(padded, self.batch_multiple)

    @property
    def slots_used(self) -> int:
        return sum(g.batch for g in self.gangs)

    @property
    def live_rows(self) -> int:
        return sum(len(g.live_rows()) for g in self.gangs)

    @property
    def idle(self) -> bool:
        return not (self.waiting or self.paused or self.gangs
                    or self.handoff_ready)

    def debug_state(self) -> dict:
        """JSON-safe snapshot of scheduler occupancy for operator
        inspection (``/debug/vars``) and flight-recorder dumps. Reads
        may come from the asyncio thread while the decode thread
        mutates — ``list()`` snapshots keep iteration safe; individual
        fields can be one tick stale, which is fine for debugging."""
        gangs = list(self.gangs)
        return {
            "waiting": len(self.waiting),
            "paused": len(self.paused),
            "prefill_only": self.prefill_only,
            "handoff_ready": len(self.handoff_ready),
            "prefill_wall_s": round(self.prefill_wall_s, 6),
            "decode_wall_s": round(self.decode_wall_s, 6),
            "slots_used": self.slots_used,
            "max_slots": self.max_slots,
            "live_rows": self.live_rows,
            "merges": self.merges,
            "pending_preempts": len(self._preempt),
            "pending_cancels": len(self._cancel),
            "jit_cache_size": self.jit_cache_size(),
            "compile": self.compile_watch.counters(),
            "gangs": [{
                "batch": g.batch,
                "live_rows": len(g.live_rows()),
                "block_idx": g.state.block_idx,
                "n_blocks": g.state.n_blocks,
                "prompt_len": g.state.prompt_len,
                "method": g.decoder.dcfg.method,
                "uids": [r.uid for r in list(g.requests)
                         if r is not None],
            } for g in gangs],
        }

    def jit_cache_size(self) -> int:
        """Compiled variants across every decoder *and* the executor's
        cache-creation fns — the quantity whose growth the CompileWatch
        ledger attributes to call sites."""
        n = sum(d.jit_cache_size() for d in self._decoders.values())
        if self.executor is not None:
            n += self.executor.jit_cache_size()
        return n

    # ------------------------------------------------------ submission

    def submit(self, prompt_tokens: np.ndarray, gen_len: int,
               max_tokens: int, trace_id: str = "") -> ServeRequest:
        """Admission control: reject (raise) beyond ``max_waiting``."""
        if self.max_waiting is not None \
                and len(self.waiting) >= self.max_waiting:
            raise RuntimeError(
                f"admission rejected: waiting queue at max_waiting="
                f"{self.max_waiting}")
        self._uid += 1
        req = ServeRequest(self._uid, np.asarray(prompt_tokens, np.int32),
                           gen_len, max_tokens, time.perf_counter(),
                           trace_id=trace_id)
        if self.tracer is not None and trace_id:
            self.tracer.async_begin(trace_id, "queue", pid=self.pid,
                                    uid=req.uid)
            self._span_state[req.uid] = "queue"
        if self.prefix_cache is not None:
            # expected hit length: reported up the stack (router
            # affinity, Completion) and the basis of hit-aware
            # admission grouping — see _group_key
            req.expected_hit_tokens = self.prefix_cache.match_len(
                req.prompt_tokens)
        self.waiting.append(req)
        return req

    def preempt(self, uid: int) -> None:
        """Vacate the request's slot at the next block boundary; the
        request resumes from the same block once a slot frees. (For the
        non-batch-invariant dkv baseline the remaining rows keep their
        lanes, so only the preempted request itself is perturbed.)
        Unknown/finished uids are ignored — a stale flag must never
        outlive its request, or it would fire on a future uid."""
        active = any(r is not None and r.uid == uid
                     for g in self.gangs for r in g.requests)
        if active:
            self._preempt.add(uid)

    def cancel(self, uid: int) -> Optional[Completion]:
        """Terminate a request wherever it lives, freeing its resources
        for good (contrast ``preempt``, which parks the state to
        resume). Waiting/paused requests are cancelled *now* and their
        partial ``Completion`` is returned. Active rows are flagged and
        released at the next block boundary — the partial ``Completion``
        comes out of the next ``tick()`` (return value ``None`` here).
        Unknown or already-finished uids return ``None`` and set no
        flag, so a stale cancel can never fire on a future uid."""
        now = time.perf_counter()
        for r in self.waiting:
            if r.uid == uid:
                self.waiting.remove(r)
                return self._make_completion(
                    r, np.zeros(0, np.int32), now, cancelled=True)
        for item in self.paused:
            req, state, decoder = item
            if req.uid == uid:
                self.paused.remove(item)
                K = decoder.dcfg.block_size
                gen = state.x[0, state.prompt_len:
                              state.prompt_len + state.block_idx * K].copy()
                return self._make_completion(req, gen, now, cancelled=True)
        for r in self.handoff_ready:
            # primed but not yet migrated to the decode pool: conclude
            # here, immediately — the EngineLoop's dispatch skips done
            # tickets, so the cancel fires exactly once
            if r.uid == uid:
                self.handoff_ready.remove(r)
                return self._make_completion(
                    r, np.zeros(0, np.int32), now, cancelled=True)
        active = any(r is not None and r.uid == uid and not g.emitted[i]
                     for g in self.gangs
                     for i, r in enumerate(g.requests))
        if active:
            self._preempt.discard(uid)   # cancel wins over preempt
            self._cancel.add(uid)
        return None

    def _apply_cancels(self):
        """Release cancel-flagged rows at the block boundary: vacate the
        lane before this tick's decode (a cancelled request never pays
        for another block), emit the partial ``Completion`` plus a
        terminal ``BlockChunk`` so streams shut down, then compact so
        freed slots are backfillable this same tick. dkv gangs keep
        their lanes (non-batch-invariant) with ``done`` masking the dead
        row, exactly like preemption."""
        chunks: List[BlockChunk] = []
        completions: List[Completion] = []
        if not self._cancel:
            return chunks, completions
        now = time.perf_counter()
        for gang in self.gangs:
            st = gang.state
            K = gang.decoder.dcfg.block_size
            P = st.prompt_len
            for i in gang.live_rows():
                req = gang.requests[i]
                if req.uid not in self._cancel:
                    continue
                self._cancel.discard(req.uid)
                gen = st.x[i, P:P + st.block_idx * K].copy()
                completions.append(
                    self._make_completion(req, gen, now, cancelled=True))
                chunks.append(BlockChunk(req.uid, st.block_idx,
                                         np.zeros(0, np.int32), "",
                                         True, False))
                gang.requests[i] = None
                gang.emitted[i] = True
                st.done[i] = True
        self._cancel.clear()   # flags never outlive their sweep
        self._compact()
        return chunks, completions

    # ------------------------------------------------------ span hooks

    def _trace_admit(self, req: ServeRequest) -> None:
        """Request entered a gang: close "queue" (first admission only
        — a resumed request's queue span closed long ago) and open
        "decode"."""
        if self.tracer is None or not req.trace_id:
            return
        if self._span_state.get(req.uid) == "queue":
            self.tracer.async_end(req.trace_id, "queue", pid=self.pid)
        self.tracer.async_begin(req.trace_id, "decode", pid=self.pid,
                                uid=req.uid)
        self._span_state[req.uid] = "decode"

    def _trace_finish(self, req: ServeRequest) -> None:
        """Request reached its terminal Completion: close whichever
        span is still open (decode for active/preempt-cancelled rows,
        queue for cancelled-while-waiting; a paused request has
        nothing open — its decode span closed at extraction)."""
        if self.tracer is None or not req.trace_id:
            return
        open_span = self._span_state.pop(req.uid, None)
        if open_span in ("queue", "decode"):
            self.tracer.async_end(req.trace_id, open_span, pid=self.pid)

    # ------------------------------------------------------ stealing

    def steal_waiting(self) -> Optional[ServeRequest]:
        """Give up the *newest* waiting request to an idle sibling
        engine (EngineRouter block-boundary work stealing). Newest
        first: the head of the queue is next in line for this engine's
        own backfill, while the tail would wait longest here. Closes
        the request's "queue" span on this engine's track — the thief
        opens a fresh one when it re-admits."""
        if not self.waiting:
            return None
        req = self.waiting.pop()
        if self.tracer is not None and req.trace_id \
                and self._span_state.pop(req.uid, None) == "queue":
            self.tracer.async_end(req.trace_id, "queue", pid=self.pid,
                                  stolen=True)
        return req

    def steal_paused(self) -> Optional[Tuple[ServeRequest, DecodeState]]:
        """Give up one parked (preempted) row, newest first. Only
        host-portable states leave: a dkv state pins a gathered device
        cache on this engine's mesh (and dkv is not batch-invariant
        anyway), so dkv rows always resume where they paused."""
        for item in reversed(self.paused):
            req, state, decoder = item
            if decoder.dcfg.method == "dkv" or state.cache is not None:
                continue
            self.paused.remove(item)
            self._span_state.pop(req.uid, None)
            if self.tracer is not None and req.trace_id:
                self.tracer.instant("steal_out", pid=self.pid, uid=req.uid)
            return req, state

    def adopt_paused(self, req: ServeRequest, state: DecodeState) -> int:
        """Adopt a mid-decode row stolen from a sibling engine: the
        request gets a fresh uid in this scheduler's namespace (the
        victim's uid could collide with a live one here) and parks on
        the paused deque at the exact block it left off. The normal
        resume path — pool buffer acquire plus radix-store re-prime
        when the prefix cache is on — picks it up at the next
        ``_admit``, so a stolen row decodes exactly like a row preempted
        and resumed on one engine."""
        self._uid += 1
        req.uid = self._uid
        req.stolen += 1
        if self.tracer is not None and req.trace_id:
            self.tracer.async_begin(req.trace_id, "queue", pid=self.pid,
                                    uid=req.uid, stolen=True)
            self._span_state[req.uid] = "queue"
        self.paused.append((req, state, self._decoder(req.gen_len)))
        return req.uid

    # ------------------------------------------------------ handoff

    def take_handoffs(self) -> List[ServeRequest]:
        """Drain the requests a prefill-only tick primed; the owning
        EngineLoop migrates each to a decode-pool engine."""
        out, self.handoff_ready = self.handoff_ready, []
        return out

    def adopt_handoff(self, req: ServeRequest) -> int:
        """Adopt a request primed on a prefill-pool engine: fresh uid
        in this scheduler's namespace (the prefill engine's uid could
        collide with a live one here), back onto the waiting queue with
        every lifecycle counter intact — ``submit_time`` and the
        prefill-pass nfe/syncs carry over, so the Completion reports
        true end-to-end latency. The normal admission path prefills it
        again, but the prefill engine already published every aligned
        chunk to the *shared* radix store, so this pass assembles the
        prompt KV from the store and computes only the unaligned
        remainder (O(cache_chunk), not O(prompt)) — which is exactly
        why handed-off output is bit-identical to the single-engine
        path: cached-vs-cold prefill identity holds by construction
        (see repro.cache). Bypasses ``max_waiting`` like
        ``adopt_paused``: the row was admitted once already."""
        self._uid += 1
        req.uid = self._uid
        req.handoffs += 1
        if self.tracer is not None and req.trace_id:
            self.tracer.async_begin(req.trace_id, "queue", pid=self.pid,
                                    uid=req.uid, handoff=True)
            self._span_state[req.uid] = "queue"
        if self.prefix_cache is not None:
            req.expected_hit_tokens = self.prefix_cache.match_len(
                req.prompt_tokens)
        self.waiting.append(req)
        return req.uid

    def _extract_handoffs(self) -> None:
        """Dismantle every primed gang into ``handoff_ready``: the
        chunk KV lives in the shared store now (``prefill`` published
        it), so the gang buffer goes straight back to the pool and only
        the *requests* travel — no DecodeState crosses engines. The
        prefill pass's nfe/sync deltas are attributed to each row first
        (same bookkeeping as ``_harvest``)."""
        for gang in self.gangs:
            st = gang.state
            dnfe = st.nfe - gang.nfe_seen
            dsync = st.host_syncs - gang.syncs_seen
            dlogit = st.logit_syncs - gang.logit_syncs_seen
            for req in gang.requests:
                if req is None:
                    continue
                req.nfe += dnfe
                req.host_syncs += dsync
                req.logit_syncs += dlogit
                self._trace_handoff(req)
                self.handoff_ready.append(req)
            if st.cache is not None:
                self.pool.release(st.batch, st.total_len, st.cache)
                st.cache = None
        self.gangs = []

    def _trace_handoff(self, req: ServeRequest) -> None:
        """Row leaves this engine for the decode pool: close whichever
        span is open on this track (decode, normally) tagged
        ``handoff=True``; the decode engine opens a fresh "queue" span
        at adoption — same span-continuity contract as stealing."""
        if self.tracer is None or not req.trace_id:
            return
        open_span = self._span_state.pop(req.uid, None)
        if open_span in ("queue", "decode"):
            self.tracer.async_end(req.trace_id, open_span, pid=self.pid,
                                  handoff=True)
        self.tracer.instant("handoff_out", pid=self.pid, uid=req.uid)

    # ------------------------------------------------------ merge

    def _merge_stragglers(self) -> None:
        """Cross-gang merge (ROADMAP open item): gangs that sit at the
        same (shape bucket, block index) — typically stragglers left
        ragged by early exits, cancels, or split admissions — are fused
        into one gang before the next ``decode_block``, so N part-full
        block calls become one. Safe only for batch-invariant methods
        (per-row tokens don't depend on batching); dkv gangs are never
        touched. Merged rows restart their gang-level counters exactly
        like compaction (``take_rows``) does."""
        if not self.merge_gangs or len(self.gangs) < 2:
            return
        groups: Dict[tuple, List[Gang]] = {}
        for g in self.gangs:
            st = g.state
            if not g.decoder.batch_invariant or st.finished:
                continue
            if any(r is not None and r.uid in self._preempt
                   for r in g.requests):
                continue    # let preemption extract its row first
            key = (st.prompt_len, st.total_len, st.block_idx)
            groups.setdefault(key, []).append(g)
        for gs in groups.values():
            if len(gs) < 2:
                continue
            gs.sort(key=lambda g: len(g.open_rows()))
            bin_gangs: List[Gang] = []
            bin_rows = bin_slots = 0
            for g in gs:
                r = len(g.open_rows())
                # a merge may never grow the slot footprint: the padded
                # merged batch must fit inside the slots the source
                # gangs release (admission's padded<=max_slots guard
                # doesn't apply here, and pow2 padding of e.g. three
                # 1-row gangs would otherwise mint a 4th slot out of
                # thin air), and stay within the gang-size cap
                fits = (bin_rows + r <= self.max_gang
                        and self._pad_batch(bin_rows + r)
                        <= bin_slots + g.batch)
                if bin_gangs and not fits:
                    if len(bin_gangs) >= 2:
                        self._merge_bin(bin_gangs)
                    bin_gangs, bin_rows, bin_slots = [], 0, 0
                bin_gangs.append(g)
                bin_rows += r
                bin_slots += g.batch
            if len(bin_gangs) >= 2:
                self._merge_bin(bin_gangs)

    def _merge_bin(self, gangs: List[Gang]) -> None:
        decoder = gangs[0].decoder
        T = gangs[0].state.total_len
        parts: List[Tuple[DecodeState, List[int]]] = []
        reqs: List[Optional[ServeRequest]] = []
        for g in gangs:
            rows = g.open_rows()
            parts.append((g.state, rows))
            reqs.extend(g.requests[i] for i in rows)
        new_b = self._pad_batch(len(reqs))
        if new_b > len(reqs):   # pad lanes replicate the first open row
            parts.append((parts[0][0],
                          [parts[0][1][0]] * (new_b - len(reqs))))
            reqs.extend([None] * (new_b - len(reqs)))
        if decoder.cache_carries_state:
            # prefix_cache: the sources' prompt KV must be read by the
            # merge gather — merge first, release after
            state = decoder.merge_rows(parts)
            for g in gangs:
                if g.state.cache is not None:
                    self.pool.release(g.state.batch, T, g.state.cache)
                    g.state.cache = None
                self.gangs.remove(g)
        else:
            # release source buffers BEFORE acquiring the merged one:
            # their contents are never read (merge_rows only needs a
            # right-shaped backing; the next refresh rewrites it), and a
            # matching-shape release turns the acquire into a
            # guaranteed pool hit
            for g in gangs:
                if g.state.cache is not None:
                    self.pool.release(g.state.batch, T, g.state.cache)
                    g.state.cache = None
                self.gangs.remove(g)
            cache = None
            if decoder.dcfg.method != "vanilla":
                cache = self.compile_watch.watched(
                    lambda: self.pool.acquire(new_b, T),
                    self.jit_cache_size, "merge_acquire",
                    tracer=self.tracer, pid=self.pid)
            state = decoder.merge_rows(parts, cache=cache)
        self.gangs.append(Gang(decoder, state, reqs))
        self.merges += 1

    # ------------------------------------------------------ tick

    def tick(self) -> Tuple[List[BlockChunk], List[Completion]]:
        """One scheduler round: release cancelled rows → admit →
        advance every gang one block → harvest chunks/completions →
        compact + backfill."""
        chunks, completions = self._apply_cancels()
        if self.prefill_only:
            # prefill pool: admit (prefill publishes chunk KV to the
            # shared store), dismantle into handoff_ready, then admit
            # again so slots freed by the extraction fill this tick
            self._admit()
            self._extract_handoffs()
            self._admit()
            self._extract_handoffs()
            self.last_decoded_rows = 0
            return chunks, completions
        self._merge_stragglers()
        self._admit()
        # rows whose decode this tick actually pays for — sampled before
        # the decode loop so occupancy isn't attributed post-compaction
        self.last_decoded_rows = self.live_rows
        for gang in self.gangs:
            size0 = self.jit_cache_size()
            t0_ns = time.perf_counter_ns()
            gang.decoder.decode_block(gang.state)
            t1_ns = time.perf_counter_ns()
            self.decode_wall_s += (t1_ns - t0_ns) / 1e9
            self.compile_watch.observe(
                self.jit_cache_size() - size0, (t1_ns - t0_ns) / 1e9,
                "decode_block", tracer=self.tracer, pid=self.pid,
                t0_ns=t0_ns, t1_ns=t1_ns)
            self._drain_block_stats(gang, t0_ns, t1_ns)
            c, comp = self._harvest(gang, gang.state.nfe - gang.nfe_seen,
                                    gang.state.host_syncs - gang.syncs_seen,
                                    gang.state.logit_syncs
                                    - gang.logit_syncs_seen,
                                    t0_ns=t0_ns, t1_ns=t1_ns)
            gang.nfe_seen = gang.state.nfe
            gang.syncs_seen = gang.state.host_syncs
            gang.logit_syncs_seen = gang.state.logit_syncs
            chunks.extend(c)
            completions.extend(comp)
        self._compact()
        # backfill freed slots within the same tick so the next tick
        # decodes at full occupancy
        self._admit()
        return chunks, completions

    def _drain_block_stats(self, gang: Gang, t0_ns: int,
                           t1_ns: int) -> None:
        """Route the BlockStats the decoder just appended: into the
        telemetry aggregator, the block-wall histogram, and a
        thread-track trace span for this engine's timeline. Drained
        every tick so compaction (which builds fresh states) never
        loses or double-counts a block."""
        stats = gang.state.block_stats
        gang.last_commit_conf = None
        if not stats:
            return
        gang.state.block_stats = []
        gang.last_commit_conf = stats[-1].commit_conf
        if self.telemetry is not None:
            self.telemetry.extend(stats)
        if self.block_hist is not None:
            for bs in stats:
                self.block_hist.observe(bs.wall_s)
        if self.tracer is not None:
            last = stats[-1]
            self.tracer.complete(
                "decode_block", t0_ns, t1_ns, pid=self.pid,
                method=last.method, block=last.block_idx,
                batch=last.batch, steps=last.steps,
                committed=last.tokens_committed)

    # ------------------------------------------------------ admission

    def _admit(self) -> None:
        free = self.max_slots - self.slots_used
        # resumed (preempted) states go first, at their original block
        while self.paused and free > 0:
            req, state, decoder = self.paused.popleft()
            if state.cache is None and decoder.dcfg.method != "vanilla":
                def _resume(state=state, decoder=decoder):
                    state.cache = self.pool.acquire(state.batch,
                                                    state.total_len)
                    if decoder.dcfg.prefix_cache:
                        # a parked state dropped its prompt KV; re-prime
                        # it (its own chunks are usually still in the
                        # store, so this is O(tail), not O(prompt))
                        decoder.prime_prompt_kv(state)
                t0 = time.perf_counter()
                self.compile_watch.watched(
                    _resume, self.jit_cache_size, "resume",
                    tracer=self.tracer, pid=self.pid)
                self.prefill_wall_s += time.perf_counter() - t0
            if req.admit_time < 0:   # resume keeps the first admission
                req.admit_time = time.perf_counter()
            self._trace_admit(req)
            self.gangs.append(Gang(decoder, state, [req]))
            free -= state.batch
        if free <= 0 or not self.waiting:
            return
        # bucket the queue once per _admit (not per admitted gang — a
        # large backlog is exactly the continuous-batching regime)
        groups: Dict[tuple, List[ServeRequest]] = {}
        for r in self.waiting:
            groups.setdefault(self._group_key(r), []).append(r)
        admitted_ids = set()
        while free > 0:
            # Largest shape group first (mirrors the synchronous
            # engine), but never fragment a group across gangs just to
            # fill freed slots: each block call has a large fixed cost
            # (weight traffic), so splitting one would-be batch into two
            # gangs costs more than briefly idling the slots. A group is
            # admitted when its full target batch fits. (pad_pow2 mode
            # instead caps the gang at the pow2 ladder below max_slots —
            # a padded target larger than max_slots could never fit and
            # would livelock the queue.)
            admitted = False
            for bucket, group in sorted(groups.items(),
                                        key=lambda kv: -len(kv[1])):
                if not group:
                    continue
                decoder = self._decoder(bucket[1])
                n, padded = self._gang_target(len(group), free, decoder)
                if n == 0 or padded > free:
                    continue
                batch_reqs = group[:n]
                del group[:n]
                admitted_ids.update(id(r) for r in batch_reqs)
                self.gangs.append(
                    self._form_gang(decoder, bucket, batch_reqs, padded))
                admitted = True
                free = self.max_slots - self.slots_used
                break
            if not admitted:
                break
        if admitted_ids:
            self.waiting = deque(r for r in self.waiting
                                 if id(r) not in admitted_ids)

    def _group_key(self, r: ServeRequest) -> tuple:
        """Admission group: shape bucket, plus — with the prefix cache
        on — the *current* cached-hit depth in chunks, so gangs form
        hit-homogeneous (a gang's prefill computes from the minimum hit
        across its rows; mixing a cold row into a warm gang would make
        every row pay the cold row's prompt). Re-queried here rather
        than frozen at submit: the cache warms while requests queue."""
        if self.prefix_cache is None:
            return r.bucket
        hit = self.prefix_cache.match_len(r.prompt_tokens)
        return r.bucket + (hit // self.dcfg.cache_chunk,)

    def _gang_target(self, group_len: int, free: int,
                     decoder: DiffusionDecoder):
        """Pick (rows to admit, padded gang batch) for one shape group.
        pow2 snapping only applies to compactable (batch-invariant)
        methods — dkv pad rows would decode until the whole gang
        finishes — while data-shard rounding applies to every method
        (sharded placement needs it regardless). The shrink loop keeps
        the padded target inside ``max_slots`` so a rounding multiple
        that doesn't divide ``max_slots`` can never livelock the
        queue."""
        pow2 = self.pad_pow2 and decoder.batch_invariant
        n = min(group_len,
                _pow2_le(min(free, self.max_gang)) if pow2
                else self.max_gang)
        while n > 0:
            padded = _round_up(_pow2_ge(n) if pow2 else n,
                               self.batch_multiple)
            if padded <= self.max_slots:
                return n, padded
            n -= 1
        return 0, 0

    def _form_gang(self, decoder: DiffusionDecoder, bucket, batch_reqs,
                   padded: int) -> Gang:
        P, gen_len = bucket[:2]   # group key may carry a hit suffix
        n = len(batch_reqs)
        prompts = np.stack(
            [r.prompt_tokens for r in batch_reqs]
            + [batch_reqs[0].prompt_tokens] * (padded - n)).astype(np.int32)
        def _build():
            cache = None
            if decoder.dcfg.method != "vanilla":
                cache = self.pool.acquire(padded, P + gen_len)
            with span(self.tracer, "prefill", pid=self.pid, batch=padded,
                      prompt_len=P):
                return decoder.prefill(prompts, cache=cache)

        t0 = time.perf_counter()
        state = self.compile_watch.watched(
            _build, self.jit_cache_size, "prefill",
            tracer=self.tracer, pid=self.pid)
        now = time.perf_counter()
        self.prefill_wall_s += now - t0
        for i, r in enumerate(batch_reqs):
            if r.admit_time < 0:
                # a handed-off row keeps its first (prefill-pool)
                # admission stamp, like a resumed row does — queue_s
                # measures time to first admission, not handoff wait
                r.admit_time = now
            if state.prefix_hit_tokens is not None and r.handoffs == 0:
                # a handed-off row's decode-pool prefill hits the store
                # by construction (the prefill pool just published its
                # chunks); keep the prefill engine's number — it is the
                # one that measures genuine cross-request reuse
                r.cache_hit_tokens = int(state.prefix_hit_tokens[i])
            self._trace_admit(r)
        rows: List[Optional[ServeRequest]] = \
            list(batch_reqs) + [None] * (padded - n)
        return Gang(decoder, state, rows)

    # ------------------------------------------------------ harvest

    def _decode_text(self, tokens: np.ndarray) -> str:
        return self.tok.decode(tokens) if self.tok is not None else ""

    def _make_completion(self, req: ServeRequest, gen: np.ndarray,
                         now: float, cancelled: bool = False) -> Completion:
        """Terminal record from a raw generated region. EOS-truncates
        (``eos_truncate``, the same policy as ``row_output``), then
        trims to the *requested* ``max_tokens`` — ``gen_len`` is
        block-rounded, and the surplus must never leave the engine."""
        gen, n_tok = eos_truncate(np.asarray(gen, np.int32),
                                  self.cfg.eos_token_id)
        gen = gen[:req.max_tokens]
        n_tok = min(n_tok, req.max_tokens)
        req.finish_time = now
        admit = req.admit_time if req.admit_time >= 0 else now
        first = req.first_block_time if req.first_block_time >= 0 else now
        self._trace_finish(req)
        conf = (np.concatenate(req.commit_conf).astype(np.float32)
                if req.commit_conf else None)
        K = self.dcfg.block_size
        return Completion(
            uid=req.uid, text=self._decode_text(gen), tokens=gen,
            latency_s=now - req.submit_time, nfe=req.nfe,
            ttfb_s=first - req.submit_time,
            queue_s=admit - req.submit_time,
            n_tokens=n_tok, n_blocks=req.blocks_decoded,
            max_tokens=req.max_tokens, cancelled=cancelled,
            host_syncs=req.host_syncs, logit_syncs=req.logit_syncs,
            cache_hit_tokens=req.cache_hit_tokens,
            expected_hit_tokens=req.expected_hit_tokens,
            trace_id=req.trace_id,
            prompt_tokens=req.prompt_tokens,
            commit_conf=conf,
            stolen=req.stolen > 0,
            handed_off=req.handoffs > 0,
            early_exited=req.blocks_decoded * K < req.gen_len)

    def _harvest(self, gang: Gang, dnfe: int, dsync: int = 0,
                 dlogit: int = 0, t0_ns: Optional[int] = None,
                 t1_ns: Optional[int] = None):
        st = gang.state
        K = gang.decoder.dcfg.block_size
        P = st.prompt_len
        eos = self.cfg.eos_token_id
        bidx = st.block_idx - 1
        bstart = P + bidx * K
        now = time.perf_counter()
        chunks: List[BlockChunk] = []
        completions: List[Completion] = []
        for i, req in enumerate(gang.requests):
            if req is None or gang.emitted[i]:
                continue
            req.nfe += dnfe
            req.host_syncs += dsync
            req.logit_syncs += dlogit
            if req.first_block_time < 0:
                req.first_block_time = now
            finished = st.row_finished(i)
            if bidx >= 0:   # a zero-block request decodes nothing
                req.blocks_decoded += 1
                toks = st.x[i, bstart:bstart + K].copy()
                if gang.last_commit_conf is not None:
                    req.commit_conf.append(np.asarray(
                        gang.last_commit_conf[i], np.float32))
                # chunk *text* is what network consumers concatenate:
                # clamp it to the requested max_tokens (gen_len is
                # block-rounded) and mute blocks after an EOS block so
                # joined stream text always equals Completion.text
                allowed = max(0, min(K, req.max_tokens - bidx * K))
                if req.eos_seen:
                    allowed = 0
                text = self._decode_text(toks[:allowed])
                if bool((toks[:allowed] == eos).any()):
                    req.eos_seen = True
                chunks.append(BlockChunk(req.uid, bidx, toks, text,
                                         finished,
                                         bool((toks == eos).any())))
                if self.tracer is not None and req.trace_id \
                        and t0_ns is not None:
                    # the decoded block, attributed to each live
                    # request's async track with the gang's bounds
                    self.tracer.async_span(
                        req.trace_id, f"block {bidx}", t0_ns, t1_ns,
                        pid=self.pid, nfe_delta=dnfe)
            if finished:
                gang.emitted[i] = True
                self._preempt.discard(req.uid)  # flags die with request
                self._cancel.discard(req.uid)
                completions.append(self._make_completion(
                    req, st.x[i, P:].copy(), now))
        return chunks, completions

    # ------------------------------------------------------ compaction

    def _compact(self) -> None:
        kept: List[Gang] = []
        for gang in self.gangs:
            st = gang.state
            T = st.total_len
            # block-level preemption: extract flagged rows first
            for i in list(gang.open_rows()):
                req = gang.requests[i]
                if req.uid in self._preempt:
                    self._preempt.discard(req.uid)
                    sub = gang.decoder.take_rows(st, [i], alloc_cache=False)
                    req.preempted += 1
                    if self.tracer is not None and req.trace_id:
                        self.tracer.async_end(req.trace_id, "decode",
                                              pid=self.pid)
                        self.tracer.instant("preempt", pid=self.pid,
                                            uid=req.uid)
                        self._span_state[req.uid] = "paused"
                    self.paused.append((req, sub, gang.decoder))
                    gang.requests[i] = None
                    gang.emitted[i] = True
                    # if the gang can't compact (dkv), stop the vacated
                    # lane from driving further denoise steps — done
                    # rows no longer extend the block loop, and no
                    # other row reads this lane's state
                    st.done[i] = True
            open_rows = gang.open_rows()
            if not open_rows:
                if st.cache is not None:
                    self.pool.release(st.batch, T, st.cache)
                continue
            if gang.decoder.batch_invariant:
                new_b = _round_up(_pow2_ge(len(open_rows)) if self.pad_pow2
                                  else len(open_rows), self.batch_multiple)
                if new_b < st.batch:
                    rows = open_rows + [open_rows[0]] * \
                        (new_b - len(open_rows))
                    cache = None
                    if gang.decoder.dcfg.method != "vanilla" \
                            and not gang.decoder.cache_carries_state:
                        # a state-carrying cache (prefix_cache prompt
                        # region) is gathered by take_rows itself; a
                        # pooled buffer would be dead weight
                        cache = self.compile_watch.watched(
                            lambda new_b=new_b: self.pool.acquire(new_b, T),
                            self.jit_cache_size, "compact_acquire",
                            tracer=self.tracer, pid=self.pid)
                    new_state = gang.decoder.take_rows(st, rows, cache=cache)
                    if st.cache is not None:
                        self.pool.release(st.batch, T, st.cache)
                    reqs = [gang.requests[i] for i in open_rows] \
                        + [None] * (new_b - len(open_rows))
                    ng = Gang(gang.decoder, new_state, reqs)
                    kept.append(ng)
                    continue
            kept.append(gang)
        self.gangs = kept
