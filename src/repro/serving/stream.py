"""Streaming output path: per-block callbacks and pull iterators.

The scheduler emits ``BlockChunk``s at every block boundary; this
module routes them. Two consumption styles:

* callbacks — ``router.subscribe(uid, fn)`` (or ``uid=None`` for a
  wildcard) fires ``fn(chunk)`` synchronously as chunks are published;
* iterators — ``RequestStream`` buffers one request's chunks and is
  drained by iterating while the engine ticks.

Chunks for a given request always arrive in block order (the scheduler
advances a request's gang one block per tick), so consumers can
concatenate ``chunk.text`` pieces directly.
"""
from __future__ import annotations

import logging
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from repro.serving.types import BlockChunk

log = logging.getLogger(__name__)


class StreamRouter:
    """Chunk fan-out. A subscriber that raises is logged and dropped —
    one broken consumer must not abort delivery to the rest of the
    batch — and emptied subscriber lists (per-uid *and* wildcard) are
    garbage-collected so a long-lived engine doesn't accumulate dead
    keys from every request it ever served."""

    def __init__(self):
        self._subs: Dict[Optional[int], List[Callable[[BlockChunk], None]]] \
            = {}

    def subscribe(self, uid: Optional[int],
                  fn: Callable[[BlockChunk], None]) -> None:
        """``uid=None`` subscribes to every request's chunks."""
        self._subs.setdefault(uid, []).append(fn)

    def unsubscribe(self, uid: Optional[int],
                    fn: Callable[[BlockChunk], None]) -> None:
        subs = self._subs.get(uid)
        if subs and fn in subs:
            subs.remove(fn)
        if subs is not None and not subs:
            del self._subs[uid]

    def _deliver(self, key: Optional[int], chunk: BlockChunk) -> None:
        subs = self._subs.get(key)
        if not subs:
            return
        for fn in list(subs):
            try:
                fn(chunk)
            except Exception:
                log.exception("stream subscriber for uid=%s raised; "
                              "unsubscribing it", key)
                try:
                    subs.remove(fn)
                except ValueError:
                    pass
        if not subs:
            self._subs.pop(key, None)

    def publish(self, chunks: List[BlockChunk]) -> None:
        for chunk in chunks:
            self._deliver(chunk.uid, chunk)
            self._deliver(None, chunk)
            # drop per-uid subscribers once their request finished
            if chunk.finished:
                self._subs.pop(chunk.uid, None)


class RequestStream:
    """Buffered per-request chunk stream. Fed by a router subscription;
    drained with ``next()`` / iteration while the engine is stepped (the
    engine's ``stream()`` drives ticking for you)."""

    def __init__(self, router: StreamRouter, uid: int):
        self.uid = uid
        self._buf: Deque[BlockChunk] = deque()
        self._finished = False
        router.subscribe(uid, self._on_chunk)

    def _on_chunk(self, chunk: BlockChunk) -> None:
        self._buf.append(chunk)
        self._finished |= chunk.finished

    @property
    def exhausted(self) -> bool:
        return self._finished and not self._buf

    def pop(self) -> Optional[BlockChunk]:
        return self._buf.popleft() if self._buf else None

    def drain(self) -> List[BlockChunk]:
        out = list(self._buf)
        self._buf.clear()
        return out

    @property
    def text(self) -> str:
        raise AttributeError("RequestStream buffers chunks; join "
                             "chunk.text pieces as you drain them")
