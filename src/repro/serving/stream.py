"""Streaming output path: per-block callbacks and pull iterators.

The scheduler emits ``BlockChunk``s at every block boundary; this
module routes them. Two consumption styles:

* callbacks — ``router.subscribe(uid, fn)`` (or ``uid=None`` for a
  wildcard) fires ``fn(chunk)`` synchronously as chunks are published;
* iterators — ``RequestStream`` buffers one request's chunks and is
  drained by iterating while the engine ticks.

Chunks for a given request always arrive in block order (the scheduler
advances a request's gang one block per tick), so consumers can
concatenate ``chunk.text`` pieces directly.
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import Callable, Deque, Dict, List, Optional

from repro.serving.types import BlockChunk


class StreamRouter:
    def __init__(self):
        self._subs: Dict[Optional[int], List[Callable[[BlockChunk], None]]] \
            = defaultdict(list)

    def subscribe(self, uid: Optional[int],
                  fn: Callable[[BlockChunk], None]) -> None:
        """``uid=None`` subscribes to every request's chunks."""
        self._subs[uid].append(fn)

    def unsubscribe(self, uid: Optional[int],
                    fn: Callable[[BlockChunk], None]) -> None:
        if fn in self._subs.get(uid, ()):
            self._subs[uid].remove(fn)

    def publish(self, chunks: List[BlockChunk]) -> None:
        for chunk in chunks:
            for fn in self._subs.get(chunk.uid, ()):
                fn(chunk)
            for fn in self._subs.get(None, ()):
                fn(chunk)
        # drop per-uid subscribers once their request finished
        for chunk in chunks:
            if chunk.finished and chunk.uid in self._subs:
                del self._subs[chunk.uid]


class RequestStream:
    """Buffered per-request chunk stream. Fed by a router subscription;
    drained with ``next()`` / iteration while the engine is stepped (the
    engine's ``stream()`` drives ticking for you)."""

    def __init__(self, router: StreamRouter, uid: int):
        self.uid = uid
        self._buf: Deque[BlockChunk] = deque()
        self._finished = False
        router.subscribe(uid, self._on_chunk)

    def _on_chunk(self, chunk: BlockChunk) -> None:
        self._buf.append(chunk)
        self._finished |= chunk.finished

    @property
    def exhausted(self) -> bool:
        return self._finished and not self._buf

    def pop(self) -> Optional[BlockChunk]:
        return self._buf.popleft() if self._buf else None

    def drain(self) -> List[BlockChunk]:
        out = list(self._buf)
        self._buf.clear()
        return out

    @property
    def text(self) -> str:
        raise AttributeError("RequestStream buffers chunks; join "
                             "chunk.text pieces as you drain them")
