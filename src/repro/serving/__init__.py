"""Continuous-batching serving subsystem (vLLM-style, at diffusion-block
granularity).

Layering:
    ContinuousEngine  — user API: submit / step / stream / metrics
    BlockScheduler    — gangs, admission control, compaction, preemption,
                        cross-gang straggler merge
    DecodeExecutor    — placement layer: one mesh; sharded params/caches,
                        gang submit/harvest, donation policy
    PrefixKVPool      — shape- and placement-bucketed KV buffer reuse
    PrefixKVCache     — cross-request content-addressed prompt KV reuse
                        (repro.cache; enabled by DecodeConfig.prefix_cache)
    StreamRouter      — per-block chunk callbacks / iterators
    ServeMetrics      — TTFB, latency percentiles, occupancy, NFE

Built on the resumable ``DiffusionDecoder.prefill`` / ``decode_block``
API in ``repro.core.decoder``. The legacy synchronous path survives as
``repro.core.engine.ServingEngine(mode="batch")``.
"""
from repro.cache import PrefixKVCache
from repro.serving.engine import ContinuousEngine
from repro.serving.executor import DecodeExecutor
from repro.serving.metrics import RequestMetrics, ServeMetrics, percentile
from repro.serving.pool import PrefixKVPool
from repro.serving.scheduler import BlockScheduler, Gang
from repro.serving.stream import RequestStream, StreamRouter
from repro.serving.types import (BlockChunk, Completion, ServeRequest,
                                 round_up_blocks)

__all__ = [
    "ContinuousEngine", "DecodeExecutor", "BlockScheduler", "Gang",
    "PrefixKVPool", "PrefixKVCache", "StreamRouter", "RequestStream",
    "ServeMetrics",
    "RequestMetrics", "percentile", "BlockChunk", "Completion",
    "ServeRequest", "round_up_blocks",
]
