"""DecodeExecutor — the placement layer between the decode path and a
device mesh.

Everything above this module (``DiffusionDecoder``, ``BlockScheduler``,
``PrefixKVPool``, the engines) manipulates *row indices and host
arrays*; everything below it (the jitted per-block fused decode
functions, the Pallas kernels) sees *placed device arrays*. The
executor owns the boundary:

* **param placement** — one-time ``jax.device_put`` of the weight
  pytree under ``NamedSharding`` built from the existing
  ``launch/sharding.SpecBuilder`` serve-mode specs (model axis = tensor
  parallel; attention heads / d_ff / experts / vocab shard there).
* **cache placement** — KV buffers are created *on device, already
  sharded* via a jitted ``init_cache`` with ``out_shardings`` from
  ``SpecBuilder.cache`` (batch over the data axis, heads over model).
  A host-side ``init_cache`` + transfer would materialize the whole
  buffer twice.
* **gang submit** — per-block host arrays (tokens, commit masks,
  query positions) are uploaded batch-sharded over the data axis when
  the gang batch divides its extent, and *replicated* when it does
  not (the documented fallback — sharding must never silently pad a
  batch; the scheduler's gang-size rounding makes the fallback rare).
  Harvest needs no executor involvement: every shard is addressable
  in this process, so the decoder's one-per-block ``np.array`` fetch
  already gathers sharded outputs.
* **donation** — the fused per-block fn rewrites the whole KV cache
  (every method but vanilla), so its input cache buffer is dead the
  moment the call is issued. When the backend supports buffer
  donation (TPU/GPU; XLA:CPU only warns and copies) the executor
  tells the decoder to donate it, halving peak KV memory per gang.

``executor=None`` everywhere above this layer means exactly the
pre-executor single-device behavior: ``jnp.asarray`` uploads and a
host-side ``init_cache`` on the default device.

The placement *key* (sorted device ids) tags pool buffers so a
``PrefixKVPool`` can never hand a buffer placed on one mesh to a
decoder driving another — see ``PrefixKVPool``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import data_axes_of
from repro.launch.sharding import SpecBuilder
from repro.models.config import ModelConfig
from repro.models.model import init_cache


class DecodeExecutor:
    """Owns one mesh: placed params, sharded cache creation, and the
    host<->device transfer policy for gang-shaped arrays."""

    def __init__(self, cfg: ModelConfig, params, mesh, *,
                 donate_cache: Optional[bool] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.data_axes: Tuple[str, ...] = data_axes_of(mesh)
        self.data_extent = 1
        for a in self.data_axes:
            self.data_extent *= mesh.shape[a]
        # XLA:CPU accepts donation annotations but ignores them with a
        # warning per call — default it off there, on everywhere else
        self.donate_cache = (jax.default_backend() != "cpu"
                             if donate_cache is None else donate_cache)
        self._sb = SpecBuilder(cfg, mesh, mode="serve")
        self._dp = (self.data_axes if len(self.data_axes) > 1
                    else (self.data_axes[0] if self.data_axes else None))
        self.params = jax.device_put(params, self._shardings(
            self._sb.params()))
        self._cache_fns: Dict[Tuple[int, int], Any] = {}

    # ------------------------------------------------------ identity

    @property
    def placement(self) -> tuple:
        """Hashable placement key: which devices this mesh spans. Pool
        buffers are bucketed by it so meshes never share buffers."""
        return tuple(sorted(d.id for d in self.mesh.devices.flat))

    @property
    def shape_key(self) -> tuple:
        """Hashable mesh-*shape* key. KV numerics depend on the mesh
        shape (sharded-matmul reduction order, head padding), not on
        which device ids back it — so a *shared* ``PrefixKVCache``
        (disaggregated pools, host-staged numpy chunks) is keyed by
        this: any executor with the same axis extents produces and
        consumes byte-identical chunk KV."""
        return ("shape",) + tuple(sorted(self.mesh.shape.items()))

    def __repr__(self):
        return (f"DecodeExecutor(mesh={dict(self.mesh.shape)}, "
                f"devices={self.placement})")

    # ------------------------------------------------------ placement

    def _shardings(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            spec_tree, is_leaf=lambda x: isinstance(x, P))

    def batch_sharding(self, ndim: int, batch: int) -> NamedSharding:
        """Data-axis sharding over dim 0 when the batch divides the
        data extent; replicated otherwise (never silent padding)."""
        if self.data_extent > 1 and batch % self.data_extent == 0:
            spec = P(self._dp, *([None] * (ndim - 1)))
        else:
            spec = P(*([None] * ndim))
        return NamedSharding(self.mesh, spec)

    def put_batch(self, arr) -> jnp.ndarray:
        """Upload one gang-shaped host array (dim 0 = batch)."""
        arr = np.asarray(arr)
        return jax.device_put(arr, self.batch_sharding(arr.ndim,
                                                       arr.shape[0]))

    def init_cache(self, batch: int, total_len: int):
        """Device-resident sharded cache creation: jitted zeros with
        ``out_shardings`` from the SpecBuilder cache specs, compiled
        once per (batch, total_len) bucket."""
        key = (batch, total_len)
        fn = self._cache_fns.get(key)
        if fn is None:
            shardings = self._shardings(self._sb.cache(batch, total_len))
            fn = jax.jit(lambda: init_cache(self.cfg, batch, total_len),
                         out_shardings=shardings)
            self._cache_fns[key] = fn
        return fn()

    def constrain_cache(self, cache, batch: int, total_len: int):
        """Pin a cache pytree to the canonical SpecBuilder sharding from
        inside a jitted computation. The decode fns apply this to their
        cache *outputs* so a recycled pool buffer carries exactly the
        sharding a fresh ``init_cache`` buffer does — otherwise the jit
        cache sees two sharding-distinct variants of every (batch,
        block) shape and the second one compiles at serve time, after
        pre-warm declared the engine warm."""
        if cache is None or not jax.tree.leaves(cache):
            return cache
        shardings = self._shardings(self._sb.cache(batch, total_len))
        return jax.tree.map(jax.lax.with_sharding_constraint,
                            cache, shardings)

    def jit_cache_size(self) -> int:
        """Compiled cache-creation variants — counted alongside the
        decoder's jit caches by the CompileWatch ledger, so a pool
        acquire at a never-seen (batch, total_len) shows up as the
        compile it is."""
        return sum(fn._cache_size() for fn in self._cache_fns.values())
