"""Synthetic arithmetic corpus — the GSM8K stand-in for CPU-scale e2e
validation of the paper's accuracy/throughput tables.

Each sample is ``Q:<a>+<b>=? A:<a+b>`` (addition/subtraction/multiply,
few-shot prefixable). Deterministic per seed. The evaluation metric is
exact-match on the answer span — our analogue of GSM8K accuracy, so the
methods table (vanilla / dkv / prefix / fast / streaming) reports both a
real quality metric and throughput, like paper Tables 1/2.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

from repro.data.tokenizer import ByteTokenizer


@dataclasses.dataclass
class Sample:
    prompt: str
    answer: str


def make_sample(rng: np.random.Generator, max_operand: int = 99) -> Sample:
    """Fixed-width prompts (zero-padded operands) so every prompt in a
    batch has identical length — the serving engine then needs no
    padding-aware attention for the evaluation harness."""
    width = len(str(max_operand))
    op = rng.choice(["+", "-"])
    a = int(rng.integers(0, max_operand + 1))
    b = int(rng.integers(0, max_operand + 1))
    val = {"+": a + b, "-": a - b}[op]
    return Sample(f"Q:{a:0{width}d}{op}{b:0{width}d}=? A:", str(val))


def few_shot_prompt(rng: np.random.Generator, shots: int,
                    max_operand: int = 99) -> str:
    parts = []
    for _ in range(shots):
        s = make_sample(rng, max_operand)
        parts.append(s.prompt + s.answer)
    return "\n".join(parts) + ("\n" if parts else "")


@dataclasses.dataclass
class Batch:
    tokens: np.ndarray      # (B, S) int32
    loss_mask: np.ndarray   # (B, S) bool — answer region (SFT-style)


class ArithmeticDataset:
    """Packed, padded training batches; deterministic per (seed, step)."""

    def __init__(self, tokenizer: ByteTokenizer, seq_len: int = 128,
                 shots: int = 0, max_operand: int = 99, seed: int = 0):
        self.tok = tokenizer
        self.seq_len = seq_len
        self.shots = shots
        self.max_operand = max_operand
        self.seed = seed

    def sample_ids(self, rng) -> Tuple[np.ndarray, np.ndarray]:
        # LLaDA SFT recipe: the response region is padded to full length
        # with EOS so the model learns to emit EOS-fill after the answer
        # (this is what makes early exit well-defined at decode time).
        s = make_sample(rng, self.max_operand)
        prefix = few_shot_prompt(rng, self.shots, self.max_operand)
        p = self.tok.encode(prefix + s.prompt)
        a = self.tok.encode(s.answer, add_eos=True)
        ids = np.full(self.seq_len, self.tok.eos_id, np.int32)
        body = np.concatenate([p, a])[: self.seq_len]
        ids[:len(body)] = body
        mask = np.ones(self.seq_len, bool)
        mask[:len(p)] = False
        return ids, mask

    def batch(self, step: int, batch_size: int) -> Batch:
        rng = np.random.default_rng((self.seed, step))
        toks = np.full((batch_size, self.seq_len), self.tok.pad_id, np.int32)
        lm = np.zeros((batch_size, self.seq_len), bool)
        for i in range(batch_size):
            ids, mask = self.sample_ids(rng)
            toks[i, :len(ids)] = ids
            lm[i, :len(mask)] = mask
        return Batch(toks, lm)

    def eval_set(self, n: int, seed: int = 10_000) -> List[Sample]:
        rng = np.random.default_rng((self.seed, seed))
        out = []
        for _ in range(n):
            out.append(make_sample(rng, self.max_operand))
        return out


def exact_match(tok: ByteTokenizer, generated: np.ndarray,
                samples: List[Sample]) -> float:
    hits = 0
    for row, s in zip(generated, samples):
        text = tok.decode(row)
        pred = text.split("\n")[0].strip()
        hits += int(pred == s.answer)
    return hits / max(len(samples), 1)
