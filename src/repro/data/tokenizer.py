"""Byte-level tokenizer with trailing special tokens.

Vocabulary layout matches ModelConfig's convention: the last two ids are
[EOS] (vocab-2) and [MASK] (vocab-1); [PAD] sits at vocab-3. Plain bytes
occupy [0, 256).
"""
from __future__ import annotations

from typing import List

import numpy as np


class ByteTokenizer:
    def __init__(self, vocab_size: int = 320):
        assert vocab_size >= 259
        self.vocab_size = vocab_size
        self.pad_id = vocab_size - 3
        self.eos_id = vocab_size - 2
        self.mask_id = vocab_size - 1

    def encode(self, text: str, add_eos: bool = False) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if add_eos:
            ids.append(self.eos_id)
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        out: List[int] = []
        for i in np.asarray(ids).tolist():
            if i == self.eos_id:
                break
            if i < 256:
                out.append(i)
        return bytes(out).decode("utf-8", errors="replace")
