"""Paper Figure 5 analogue: sliding window size sweep (accuracy and
throughput vs w)."""
from __future__ import annotations

from benchmarks.common import GEN_LEN, bench_model, emit, eval_prompts, \
    run_method


def main(n_eval: int = 24):
    cfg, params = bench_model()
    tok, samples, prompts = eval_prompts(cfg, n=n_eval)
    for w in (0, 4, 8, 16, 32, -1):
        r = run_method(cfg, params, prompts, samples, tok,
                       method="streaming", gen_len=GEN_LEN, window=w,
                       early_exit=False)
        emit(f"fig_window/w{w if w >= 0 else 'full'}",
             1e6 * r["wall"] / max(r["result"].tokens_generated, 1),
             f"acc={r['acc']:.3f};tps={r['tps']:.1f};qtok={r['qtok']}")


if __name__ == "__main__":
    main()
