"""Shared benchmark harness: a trained tiny diffusion LM (cached on
disk) + timing helpers. Every benchmark prints ``name,us_per_call,derived``
CSV rows (benchmarks/run.py aggregates)."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core.decoder import DecodeConfig, DiffusionDecoder
from repro.data.synthetic import ArithmeticDataset, exact_match
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.training import checkpoint
from repro.training.train import TrainConfig, train

CKPT = os.environ.get("REPRO_BENCH_CKPT", "results/bench_model")
TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_TRAIN_STEPS", "1200"))
GEN_LEN = 32
BLOCK = 8
SEQ = 12 + GEN_LEN  # fixed-width prompt (12) + generation


def bench_model(seed: int = 0):
    """Train (or load) the benchmark model: tiny diffusion LM on
    arithmetic, the stand-in for LLaDA/GSM8K (DESIGN.md §7)."""
    cfg = get_config("tiny", block_size=BLOCK)
    params0 = init_params(cfg, jax.random.PRNGKey(seed))
    if os.path.exists(CKPT + ".npz"):
        return cfg, checkpoint.restore(CKPT, params0)
    params, _ = train(cfg, TrainConfig(
        steps=TRAIN_STEPS, batch_size=48, seq_len=SEQ,
        log_every=max(TRAIN_STEPS // 4, 1), checkpoint_path=CKPT),
        verbose=True)
    return cfg, params


def eval_prompts(cfg, n: int = 32, shots: int = 0, seed: int = 10_000):
    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=SEQ, shots=shots)
    samples = ds.eval_set(n, seed=seed)
    prompts = np.stack([tok.encode(s.prompt) for s in samples]).astype(np.int32)
    return tok, samples, prompts


def run_method(cfg, params, prompts, samples, tok, *, method,
               gen_len=GEN_LEN, warmup=True, **dkw):
    d = DecodeConfig(method=method, gen_len=gen_len, block_size=BLOCK, **dkw)
    dec = DiffusionDecoder(cfg, params, d)
    if warmup:  # compile outside the timed region
        dec.generate(prompts[:1].copy())
    r = dec.generate(prompts.copy())
    acc = exact_match(tok, r.tokens, samples)
    tps = r.tokens_generated / r.wall_time if r.wall_time else 0.0
    return dict(method=method, acc=acc, nfe=r.nfe, tps=tps,
                wall=r.wall_time, qtok=r.query_tokens_processed,
                kvtok=r.kv_tokens_attended, result=r)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
