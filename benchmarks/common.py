"""Shared benchmark harness: a trained tiny diffusion LM (cached on
disk) + timing helpers. Every benchmark prints ``name,us_per_call,derived``
CSV rows (benchmarks/run.py aggregates), and every JSON-emitting bench
appends one record per run to the append-only cross-PR perf history
(``results/history/<bench>.jsonl`` — see ``append_history``)."""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.core.decoder import DecodeConfig, DiffusionDecoder
from repro.data.synthetic import ArithmeticDataset, exact_match
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.training import checkpoint
from repro.training.train import TrainConfig, train

CKPT = os.environ.get("REPRO_BENCH_CKPT", "results/bench_model")
TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_TRAIN_STEPS", "1200"))
GEN_LEN = 32
BLOCK = 8
SEQ = 12 + GEN_LEN  # fixed-width prompt (12) + generation


def bench_model(seed: int = 0):
    """Train (or load) the benchmark model: tiny diffusion LM on
    arithmetic, the stand-in for LLaDA/GSM8K (DESIGN.md §7)."""
    cfg = get_config("tiny", block_size=BLOCK)
    params0 = init_params(cfg, jax.random.PRNGKey(seed))
    if os.path.exists(CKPT + ".npz"):
        return cfg, checkpoint.restore(CKPT, params0)
    params, _ = train(cfg, TrainConfig(
        steps=TRAIN_STEPS, batch_size=48, seq_len=SEQ,
        log_every=max(TRAIN_STEPS // 4, 1), checkpoint_path=CKPT),
        verbose=True)
    return cfg, params


def eval_prompts(cfg, n: int = 32, shots: int = 0, seed: int = 10_000):
    tok = ByteTokenizer(cfg.vocab_size)
    ds = ArithmeticDataset(tok, seq_len=SEQ, shots=shots)
    samples = ds.eval_set(n, seed=seed)
    prompts = np.stack([tok.encode(s.prompt) for s in samples]).astype(np.int32)
    return tok, samples, prompts


def run_method(cfg, params, prompts, samples, tok, *, method,
               gen_len=GEN_LEN, warmup=True, **dkw):
    d = DecodeConfig(method=method, gen_len=gen_len, block_size=BLOCK, **dkw)
    dec = DiffusionDecoder(cfg, params, d)
    if warmup:  # compile outside the timed region
        dec.generate(prompts[:1].copy())
    r = dec.generate(prompts.copy())
    acc = exact_match(tok, r.tokens, samples)
    tps = r.tokens_generated / r.wall_time if r.wall_time else 0.0
    return dict(method=method, acc=acc, nfe=r.nfe, tps=tps,
                wall=r.wall_time, qtok=r.query_tokens_processed,
                kvtok=r.kv_tokens_attended, result=r)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


# --------------------------------------------------------------------
# cross-PR perf history: results/BENCH_*.json files are overwritten in
# place every run, so the trajectory across PRs is invisible and
# bench_gate.py can only compare against git:HEAD. Each bench run also
# appends one compact record here; scripts/perf_report.py renders the
# trajectory and bench_gate.py runs EWMA drift rules over it.

HISTORY_DIR = os.environ.get("REPRO_HISTORY_DIR", "results/history")
HISTORY_MAX_METRICS = 500      # runaway-nesting backstop per record


def _git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip()
    except Exception:
        return ""


def _numeric_leaves(doc, prefix=""):
    """Flatten nested dicts to dotted-path numeric leaves — the same
    addressing scheme scripts/bench_gate.py matches its rules against,
    so a history record and a fresh BENCH doc name a metric
    identically."""
    for key in sorted(doc):
        val = doc[key]
        path = f"{prefix}.{key}" if prefix else str(key)
        if isinstance(val, dict):
            yield from _numeric_leaves(val, path)
        elif isinstance(val, (bool, int, float)):
            yield path, float(val)


def append_history(out_path: str, doc: dict, history_dir=None) -> str:
    """Append one perf-history record for this bench run. The history
    file is named after the output file's stem (``BENCH_obs`` vs
    ``BENCH_obs_quick`` stay separate series — quick and full waves are
    not comparable), the config hash is over the exact CLI invocation
    (same flags = same series), and metrics are every numeric leaf of
    the result doc under dotted paths. Append-only JSONL: a crashed run
    corrupts at most its own last line, never history."""
    bench = os.path.splitext(os.path.basename(out_path))[0]
    hdir = history_dir or HISTORY_DIR
    os.makedirs(hdir, exist_ok=True)
    argv = " ".join(sys.argv[1:])
    metrics = {}
    for path, val in _numeric_leaves(doc):
        if len(metrics) >= HISTORY_MAX_METRICS:
            break
        metrics[path] = val
    record = {
        "bench": bench,
        "commit": _git_commit(),
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "config_hash": hashlib.sha1(argv.encode()).hexdigest()[:12],
        "argv": argv,
        "metrics": metrics,
    }
    path = os.path.join(hdir, f"{bench}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(record) + "\n")
    print(f"history: appended {bench} -> {path}")
    return path


def shared_prefix_workload(n: int, *, templates: int = 4,
                           template_len: int = 96, tail_len: int = 8,
                           zipf_a: float = 1.2, seed: int = 0,
                           as_text: bool = False):
    """Production-shaped prompt mix for the prefix-cache benchmarks:
    ``templates`` long shared headers (chat template / few-shot header /
    system prompt stand-ins) x per-request novel tails, with template
    popularity following a bounded zipf law (rank^-a, normalized) — a
    few templates dominate, exactly the regime where cross-request
    prefix reuse pays. Returns ``(prompts, template_ids, reuse_frac)``
    where ``reuse_frac`` is the fraction of requests whose template was
    already issued (a cache-warmth upper bound). ``as_text`` emits
    printable-ASCII strings (byte-tokenizer safe) for the HTTP path;
    default emits int32 token arrays."""
    rng = np.random.default_rng(seed)

    def piece(length):
        if as_text:
            return "".join(chr(c) for c in rng.integers(48, 123, length))
        return rng.integers(1, 200, length).astype(np.int32)

    heads = [piece(template_len) for _ in range(templates)]
    p = 1.0 / np.arange(1, templates + 1) ** zipf_a
    ids = rng.choice(templates, size=n, p=p / p.sum())
    prompts = [heads[i] + piece(tail_len) if as_text
               else np.concatenate([heads[i], piece(tail_len)])
               for i in ids]
    seen = set()
    reused = 0
    for i in ids:
        reused += i in seen
        seen.add(int(i))
    return prompts, ids.tolist(), reused / max(n, 1)
