"""Paper Tables 1/2/8 analogue: accuracy + throughput + speedup of all
five methods (vanilla / dKV-Cache / Prefix-Cache / Fast-dLLM / ours) on
the trained arithmetic model, at two generation lengths.

Also reports NFE and query-token reductions — the hardware-independent
speedup mechanisms (wall-clock on 1 CPU core understates the paper's
GPU/TPU gains; NFE and attended-token ratios are the transferable part).
"""
from __future__ import annotations

from benchmarks.common import (bench_model, emit, eval_prompts, run_method)

METHODS = ["vanilla", "dkv", "prefix", "fast", "streaming"]


def main(n_eval: int = 32):
    cfg, params = bench_model()
    tok, samples, prompts = eval_prompts(cfg, n=n_eval)
    for gen_len in (16, 32):
        base_tps = None
        for m in METHODS:
            r = run_method(cfg, params, prompts, samples, tok, method=m,
                           gen_len=gen_len, window=16, tau0=0.9, alpha=0.3)
            if base_tps is None:
                base_tps = r["tps"] or 1e-9
            emit(f"table_methods/gen{gen_len}/{m}",
                 1e6 * r["wall"] / max(r["result"].tokens_generated, 1),
                 f"acc={r['acc']:.3f};tps={r['tps']:.1f};"
                 f"speedup={r['tps']/base_tps:.2f}x;nfe={r['nfe']};"
                 f"qtok={r['qtok']}")


if __name__ == "__main__":
    main()
