"""Cross-request prefix cache benchmark (repro.cache).

Serves the shared-prefix workload (N zipf-popular templates x novel
tails — see ``common.shared_prefix_workload``) through the continuous
engine twice per method: prefix cache OFF (every request pays a full
[prompt || query] refresh per block) and ON (prompt KV assembled from
the radix store; only the novel tail + query are computed). Requests
run one at a time so TTFB isolates the prefill + first-block cost the
cache targets; hit/eviction counters come from ``ServeMetrics``.

    PYTHONPATH=src python benchmarks/bench_cache.py \
        [--n 32] [--templates 4] [--template-len 96] [--quick] \
        [--out results/BENCH_cache.json]

Acceptance gate (ISSUE 5): >= 2x TTFB p50 improvement at >= 50%
template reuse, hit/eviction counters visible.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np

from common import BLOCK, append_history, bench_model, shared_prefix_workload
from repro.core.decoder import DecodeConfig
from repro.data.tokenizer import ByteTokenizer
from repro.serving import ContinuousEngine

GEN_LEN = 16


def serve_workload(cfg, params, prompts, *, method, prefix_cache,
                   cache_chunk=16, max_tokens=GEN_LEN):
    d = DecodeConfig(method=method, gen_len=GEN_LEN, block_size=BLOCK,
                     window=8, prefix_cache=prefix_cache,
                     cache_chunk=cache_chunk)
    eng = ContinuousEngine(cfg, params, d, max_slots=4,
                           tokenizer=ByteTokenizer(cfg.vocab_size))
    # warmup: compile the shape lattice outside the timed region (a
    # throwaway prompt that shares no template with the workload)
    rng = np.random.default_rng(999)
    eng.submit(rng.integers(1, 200, len(prompts[0])).astype(np.int32),
               max_tokens=max_tokens)
    eng.run_to_completion()
    if eng.prefix_cache is not None:
        # drop the warmup's chunks so the workload starts cold
        eng.prefix_cache.tree = type(eng.prefix_cache.tree)(cache_chunk)
        eng.prefix_cache.bytes = 0
    eng.metrics.requests.clear()
    # closed loop at concurrency 1: TTFB == prefill + first block
    for p in prompts:
        eng.submit(p, max_tokens=max_tokens)
        eng.run_to_completion()
    snap = eng.metrics.snapshot()
    return {
        "ttfb_p50_ms": snap["ttfb_p50_s"] * 1e3,
        "ttfb_p99_ms": snap["ttfb_p99_s"] * 1e3,
        "latency_p50_ms": snap["latency_p50_s"] * 1e3,
        "throughput_tok_s": snap["throughput_tok_s"],
        "prefix_cache_hits": snap["prefix_cache_hits"],
        "prefix_cache_hit_tokens": snap["prefix_cache_hit_tokens"],
        "prefix_cache_evictions": snap["prefix_cache_evictions"],
        "prefix_cache_bytes": snap["prefix_cache_bytes"],
    }


def main():
    ap = argparse.ArgumentParser()
    # Default workload sits where prefix caching pays on this model: a
    # long shared header (few-shot/system-prompt regime) and a short
    # novel tail. At tiny-model scale the refresh is attention-bound
    # only for P >~ 500 (below that XLA:CPU dispatch overhead levels
    # both modes — see EXPERIMENTS.md); production prompts live there.
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--templates", type=int, default=4)
    ap.add_argument("--template-len", type=int, default=760)
    ap.add_argument("--tail-len", type=int, default=16)
    ap.add_argument("--cache-chunk", type=int, default=32)
    ap.add_argument("--methods", default="streaming,fast")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/BENCH_cache.json")
    args = ap.parse_args()
    if args.quick:
        args.n, args.methods = 8, "streaming"

    cfg, params = bench_model()
    prompts, ids, reuse = shared_prefix_workload(
        args.n, templates=args.templates, template_len=args.template_len,
        tail_len=args.tail_len)
    print(f"workload: n={args.n} templates={args.templates} "
          f"P={args.template_len + args.tail_len} reuse={reuse:.2f}")

    result = {"config": {
        "n": args.n, "templates": args.templates,
        "template_len": args.template_len, "tail_len": args.tail_len,
        "prompt_len": args.template_len + args.tail_len,
        "gen_len": GEN_LEN, "block": BLOCK,
        "cache_chunk": args.cache_chunk, "template_reuse_frac": reuse,
    }, "methods": {}}
    for method in args.methods.split(","):
        off = serve_workload(cfg, params, prompts, method=method,
                             prefix_cache=False)
        on = serve_workload(cfg, params, prompts, method=method,
                            prefix_cache=True,
                            cache_chunk=args.cache_chunk)
        speedup = off["ttfb_p50_ms"] / max(on["ttfb_p50_ms"], 1e-9)
        result["methods"][method] = {
            "cache_off": off, "cache_on": on,
            "ttfb_p50_speedup": speedup,
        }
        print(f"{method}: ttfb_p50 {off['ttfb_p50_ms']:.1f}ms -> "
              f"{on['ttfb_p50_ms']:.1f}ms ({speedup:.2f}x)  "
              f"hits={on['prefix_cache_hits']} "
              f"hit_toks={on['prefix_cache_hit_tokens']} "
              f"evictions={on['prefix_cache_evictions']}")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    append_history(args.out, result)


if __name__ == "__main__":
    main()
