"""Analytic FLOPs accounting at the paper's exact configuration —
LLaDA-8B, generation length 512, block 32, window 96 (Table 12) — one
row per method. This is the scale-faithful complement to the CPU bench:
it shows where the paper's 10-68x speedups come from structurally.

Per-NFE cost model (decoder-only transformer):
    proj/ffn flops = 2 * N_layer_params * Sq
    attn flops     = 4 * L * H * Sq * Skv * hd
summed over the block-refresh pass (prefix+query) and the per-step
passes, with steps/block taken from (a) one-per-step baselines and
(b) the paper's parallel-decoding regime (~3 commits/step, Fig. 3).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.suffix import suffix_query_region
from repro.models import get_config

PROMPT = 128          # ~GSM8K 5-shot prompt
GEN = 512
BLOCK = 32
WINDOW = 96
PARALLEL_STEPS = 11   # ~32/3 commits per step (paper Fig. 3 regime)


def flops_forward(cfg, sq, skv):
    body = cfg.param_count() - 2 * cfg.vocab_size * cfg.d_model
    proj = 2.0 * body * sq
    attn = 4.0 * cfg.n_layers * cfg.n_heads * sq * skv * cfg.head_dim
    head = 2.0 * cfg.vocab_size * cfg.d_model * sq
    return proj + attn + head


def method_flops(cfg, method):
    """Total generation FLOPs for one sample."""
    total = 0.0
    n_blocks = GEN // BLOCK
    steps = BLOCK if method in ("vanilla", "dkv", "prefix") else PARALLEL_STEPS
    for c in range(n_blocks):
        prefix = PROMPT + c * BLOCK
        if method == "vanilla":
            sq = skv = PROMPT + GEN
            total += steps * flops_forward(cfg, sq, skv)
            continue
        w = -1 if method in ("dkv", "prefix", "fast") else WINDOW
        r = suffix_query_region(gen_start=PROMPT, gen_len=GEN,
                                block_size=BLOCK, block_idx=c, window=w)
        sq = r.query_len
        # block-refresh pass + (steps-1) cached steps
        total += flops_forward(cfg, prefix + sq, prefix + sq)
        if method == "frozen":
            total += (steps - 1) * flops_forward(cfg, BLOCK, prefix + sq)
        else:
            total += (steps - 1) * flops_forward(cfg, sq, prefix + sq)
    return total


def main():
    cfg = get_config("llada-8b")
    base = None
    for m in ("vanilla", "prefix", "fast", "streaming", "frozen"):
        f = method_flops(cfg, m)
        if base is None:
            base = f
        emit(f"paper_config/llada8b_gen512/{m}", 0.0,
             f"tflops_per_sample={f/1e12:.1f};speedup={base/f:.1f}x")


if __name__ == "__main__":
    main()
