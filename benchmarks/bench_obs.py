"""Observability overhead + trace-export validation benchmark.

    PYTHONPATH=src python benchmarks/bench_obs.py \
        [--quick] [--n 16] [--max-slots 8] \
        [--out results/BENCH_obs.json]

Two sections, one JSON document (the PR's acceptance evidence):

* **decode overhead** — the bench_decode ragged workload run twice on
  identical engines, tracer off then tracer on (span pipeline + block
  telemetry + histograms all live). Asserts tracer-on throughput is
  within 5% of tracer-off, and that ``host_syncs_per_block`` is
  *unchanged* — per-block telemetry must ride the fused loop's single
  existing sync, never add one.
* **HTTP trace export** — a multi-request ``bench_server``-style run
  (concurrent SSE + JSON clients) with tracing on; the Chrome-trace
  JSON is exported and validated: loads as trace-event JSON, has the
  per-engine track metadata, and every request's async span tree is
  well-formed and covers accept (http) -> admission (queue) -> blocks
  -> finalize.
* **audit overhead** — the same closed-loop HTTP wave run with the
  shadow auditor off and on at its *default* sampling rate
  (``AuditConfig().sample_rate``). Asserts audit-on throughput and
  TTFB p50 are each within 5% of audit-off, ``host_syncs_per_block``
  stays exactly 1.0, at least one completion was actually re-decoded
  and compared, and zero divergences were reported.
* **recorder overhead** — the closed-loop wave again, with the
  time-series ``MetricsRecorder`` off then on at a fast sampling
  interval *and* a live console client hammering ``/debug/timeline``
  + ``/console`` for the duration (the dashboard's polling load is
  part of what is being priced). Asserts recorder-on throughput is
  within 5% of recorder-off, ``host_syncs_per_block`` stays exactly
  1.0, samples were actually taken, and every timeline poll returned
  parseable JSON.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from bench_decode import run_engine
from bench_serving import GEN_LEN, ragged_model, ragged_workload
from bench_server import build_frontend, closed_loop
from common import BLOCK, append_history
from repro.core.decoder import DecodeConfig
from repro.obs.trace import Tracer, request_tree
from repro.server import client as C

OVERHEAD_TOLERANCE = 0.05          # tracer-on within 5% of tracer-off
QUICK_TOLERANCE = 0.15             # --quick runs a workload too small to
                                   # resolve a 5% effect above CPU jitter;
                                   # the acceptance number is the full run


def bench_overhead(args):
    cfg, params = ragged_model(args.arch)
    work = ragged_workload(args.n)
    dcfg = DecodeConfig(method="streaming", gen_len=GEN_LEN,
                        block_size=BLOCK, window=8)
    # Alternate off/on reps (order flipped each rep) and keep each
    # mode's best run: single-shot CPU runs carry scheduler + process
    # warmup jitter larger than the effect measured.
    tracer = Tracer()
    off = on = None
    for rep in range(args.reps):
        modes = (False, True) if rep % 2 == 0 else (True, False)
        for traced in modes:
            r = run_engine(cfg, params, dcfg, work, args.max_slots,
                           tracer=tracer if traced else None)
            if traced:
                if on is None or (r["throughput_tok_s"]
                                  > on["throughput_tok_s"]):
                    on = r
            elif off is None or (r["throughput_tok_s"]
                                 > off["throughput_tok_s"]):
                off = r
    overhead = 1.0 - on["throughput_tok_s"] / max(
        off["throughput_tok_s"], 1e-9)
    rec = {
        "tracer_off": {k: off[k] for k in
                       ("tokens", "wall_s", "throughput_tok_s",
                        "host_syncs_per_block")},
        "tracer_on": {k: on[k] for k in
                      ("tokens", "wall_s", "throughput_tok_s",
                       "host_syncs_per_block")},
        "throughput_overhead_frac": round(overhead, 4),
        "tolerance_frac": args.tolerance,
        "reps": args.reps,
        "within_tolerance": overhead <= args.tolerance,
        "host_syncs_per_block_unchanged":
            on["host_syncs_per_block"] == off["host_syncs_per_block"],
        "trace_events_recorded": len(tracer.events()),
    }
    print(f"decode overhead: off={off['throughput_tok_s']:.1f} tok/s "
          f"on={on['throughput_tok_s']:.1f} tok/s "
          f"({overhead * 100:+.2f}%; tolerance "
          f"{args.tolerance * 100:.0f}%)  syncs/blk "
          f"{off['host_syncs_per_block']:.2f} -> "
          f"{on['host_syncs_per_block']:.2f}")
    return rec


def validate_chrome_trace(path, expect_ids):
    """Schema + span-tree checks over an exported Chrome trace."""
    doc = json.loads(open(path).read())
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs, "traceEvents missing/empty"
    for e in evs:
        assert e["ph"] in ("M", "X", "b", "e", "i"), e
        assert isinstance(e["name"], str) and isinstance(e["pid"], int)
    track_names = {e["args"]["name"] for e in evs if e["ph"] == "M"
                   and e["name"] == "process_name"}
    assert "engine-0" in track_names, track_names
    trees = {}
    for tid in expect_ids:
        tree = request_tree([e for e in evs if e.get("id") == tid])
        names = [n for n, _, _, _ in tree]
        # full lifecycle coverage on every request
        assert names[0] == "http", names
        assert "request" in names and "queue" in names, names
        assert "decode" in names, names
        assert any(n.startswith("block ") for n in names), names
        trees[tid] = names
    return {
        "path": path,
        "events": len(evs),
        "tracks": sorted(track_names),
        "requests_validated": len(trees),
        "spans_per_request_min": min(len(v) for v in trees.values()),
    }


async def _audit_wave(args, rate):
    """One warmup + one timed closed-loop wave; ``rate > 0`` attaches a
    ShadowAuditor at that sampling rate. Audits run in the decode
    thread's idle gaps during the wave and drain during shutdown."""
    frontend, eng = build_frontend(args.max_slots, max_pending=32)
    auditor = None
    if rate > 0:
        from repro.obs.audit import AuditConfig, ShadowAuditor
        auditor = ShadowAuditor(eng, AuditConfig(sample_rate=rate))
        eng.attach_auditor(auditor)
    await frontend.start()
    work = ragged_workload(max(8, args.n))
    await closed_loop(frontend.host, frontend.port, args.clients, 2, work)
    closed = await closed_loop(frontend.host, frontend.port,
                               args.clients, args.per_client, work)
    await frontend.shutdown(drain=True)
    eng.drain_audits()
    closed["host_syncs_per_block"] = \
        eng.metrics.snapshot()["host_syncs_per_block"]
    if auditor is not None:
        closed["audit"] = auditor.stats()
    return closed


def bench_audit(args):
    from repro.obs.audit import AuditConfig
    rate = AuditConfig().sample_rate       # the documented default
    recs = {0.0: [], rate: []}
    for rep in range(args.reps):
        modes = (0.0, rate) if rep % 2 == 0 else (rate, 0.0)
        for r in modes:
            recs[r].append(asyncio.run(_audit_wave(args, r)))
    # best-of per metric per mode: single-shot CPU waves carry warmup/
    # scheduler jitter larger than the effect measured
    best = {m: {"throughput_tok_s":
                max(r["throughput_tok_s"] for r in rows),
                "ttfb_p50_s": min(r["ttfb_p50_s"] for r in rows),
                "host_syncs_per_block":
                max(r["host_syncs_per_block"] for r in rows)}
            for m, rows in recs.items()}
    tok_over = 1.0 - (best[rate]["throughput_tok_s"]
                      / max(best[0.0]["throughput_tok_s"], 1e-9))
    ttfb_over = (best[rate]["ttfb_p50_s"]
                 / max(best[0.0]["ttfb_p50_s"], 1e-9)) - 1.0
    audit = recs[rate][-1]["audit"]
    rec = {
        "sample_rate": rate,
        "audit_off": best[0.0],
        "audit_on": best[rate],
        "throughput_overhead_frac": round(tok_over, 4),
        "ttfb_p50_overhead_frac": round(ttfb_over, 4),
        "tolerance_frac": args.tolerance,
        "reps": args.reps,
        "within_tolerance": (tok_over <= args.tolerance
                             and ttfb_over <= args.tolerance),
        "host_syncs_per_block":
            best[rate]["host_syncs_per_block"],
        "audits_completed": audit["completed"],
        "audit_divergences": audit["divergences"],
        "audit_errors": audit["errors"],
    }
    print(f"audit overhead @ rate={rate}: "
          f"tok/s {tok_over * 100:+.2f}% "
          f"ttfb_p50 {ttfb_over * 100:+.2f}% "
          f"(tolerance {args.tolerance * 100:.0f}%)  "
          f"audits={audit['completed']} "
          f"divergences={sum(audit['divergences'].values())}")
    return rec


async def _recorder_wave(args, enabled):
    """One warmup + one timed closed-loop wave; ``enabled`` attaches a
    ``MetricsRecorder`` at a fast sampling interval (20 Hz — an order
    of magnitude hotter than the 0.5 s serving default, so the bench
    bounds a worst case) and runs a console-poller task issuing
    ``/debug/timeline`` + ``/console`` reads throughout the wave."""
    frontend, eng = build_frontend(args.max_slots, max_pending=32)
    if enabled:
        from repro.obs.series import MetricsRecorder
        frontend.loop.recorder = MetricsRecorder(
            eng, interval_s=0.05, loop=frontend.loop)
    await frontend.start()
    host, port = frontend.host, frontend.port
    work = ragged_workload(max(8, args.n))
    await closed_loop(host, port, args.clients, 2, work)
    stop = asyncio.Event()
    polls = {"n": 0}

    async def console_poller():
        while not stop.is_set():
            st, _, body = await C.request(
                host, port, "GET", "/debug/timeline?window=30&step=1")
            assert st == 200, st
            doc = json.loads(body)
            assert doc["engines_reporting"] >= 1, doc
            st, _, page = await C.request(host, port, "GET", "/console")
            assert st == 200 and b"<!doctype html>" in page.lower()
            polls["n"] += 1
            try:
                await asyncio.wait_for(stop.wait(), 0.1)
            except asyncio.TimeoutError:
                pass

    poller = asyncio.create_task(console_poller()) if enabled else None
    closed = await closed_loop(host, port, args.clients,
                               args.per_client, work)
    if poller is not None:
        stop.set()
        await poller
    closed["host_syncs_per_block"] = \
        eng.metrics.snapshot()["host_syncs_per_block"]
    if enabled:
        closed["recorder"] = frontend.loop.recorder.stats()
        closed["timeline_polls"] = polls["n"]
    await frontend.shutdown(drain=True)
    return closed


def bench_recorder(args):
    recs = {False: [], True: []}
    for rep in range(args.reps):
        modes = (False, True) if rep % 2 == 0 else (True, False)
        for m in modes:
            recs[m].append(asyncio.run(_recorder_wave(args, m)))
    # best-of per metric per mode, same rationale as bench_audit
    best = {m: {"throughput_tok_s":
                max(r["throughput_tok_s"] for r in rows),
                "ttfb_p50_s": min(r["ttfb_p50_s"] for r in rows),
                "host_syncs_per_block":
                max(r["host_syncs_per_block"] for r in rows)}
            for m, rows in recs.items()}
    tok_over = 1.0 - (best[True]["throughput_tok_s"]
                      / max(best[False]["throughput_tok_s"], 1e-9))
    rstats = recs[True][-1]["recorder"]
    rec = {
        "recorder_off": best[False],
        "recorder_on": best[True],
        "throughput_overhead_frac": round(tok_over, 4),
        "tolerance_frac": args.tolerance,
        "reps": args.reps,
        "within_tolerance": tok_over <= args.tolerance,
        "host_syncs_per_block":
            best[True]["host_syncs_per_block"],
        "host_syncs_per_block_unchanged":
            best[True]["host_syncs_per_block"]
            == best[False]["host_syncs_per_block"],
        "recorder_samples": rstats["samples"],
        "recorder_dropped": rstats["dropped"],
        "recorder_errors": rstats["errors"],
        "timeline_polls": recs[True][-1]["timeline_polls"],
    }
    print(f"recorder overhead: off="
          f"{best[False]['throughput_tok_s']:.1f} tok/s on="
          f"{best[True]['throughput_tok_s']:.1f} tok/s "
          f"({tok_over * 100:+.2f}%; tolerance "
          f"{args.tolerance * 100:.0f}%)  samples={rstats['samples']} "
          f"timeline_polls={rec['timeline_polls']}")
    return rec


async def bench_http_trace(args, trace_path):
    tracer = Tracer()
    frontend, eng = build_frontend(args.max_slots, max_pending=32,
                                   tracer=tracer)
    await frontend.start()
    host, port = frontend.host, frontend.port
    work = ragged_workload(max(8, args.n))
    # warmup wave compiles the shape lattice before the timed section
    await closed_loop(host, port, args.clients, 2, work)
    t0 = time.perf_counter()
    closed = await closed_loop(host, port, args.clients,
                               args.per_client, work)
    # a JSON (non-streaming) wave rides the same trace pipeline
    ids = []
    for prompt, budget in work[: args.clients]:
        status, headers, doc = await C.complete(
            host, port, {"prompt": prompt, "max_tokens": budget})
        assert status == 200
        ids.append(headers["x-repro-trace-id"])
    wall = time.perf_counter() - t0
    await frontend.shutdown(drain=True)
    tracer.export(trace_path)
    validation = validate_chrome_trace(trace_path, ids)
    print(f"http trace: {validation['events']} events, "
          f"{validation['requests_validated']} request trees validated, "
          f"tracks={validation['tracks']}")
    return {
        "closed_loop": closed,
        "json_requests": len(ids),
        "wall_s": wall,
        "tracer_dropped": tracer.dropped,
        "chrome_trace": validation,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller workload")
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--per-client", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3,
                    help="off/on pairs for the overhead section; "
                         "best-of per mode is reported")
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--out", default="results/BENCH_obs.json")
    args = ap.parse_args()
    args.tolerance = OVERHEAD_TOLERANCE
    if args.quick:
        args.n, args.clients, args.per_client = 8, 2, 2
        args.tolerance = QUICK_TOLERANCE

    overhead = bench_overhead(args)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    trace_path = os.path.join(os.path.dirname(args.out),
                              "trace_bench_obs.json")
    http = asyncio.run(bench_http_trace(args, trace_path))

    audit = bench_audit(args)
    recorder = bench_recorder(args)

    doc = {"config": {"n": args.n, "clients": args.clients,
                      "per_client": args.per_client,
                      "max_slots": args.max_slots, "arch": args.arch,
                      "gen_len": GEN_LEN, "block": BLOCK},
           "decode_overhead": overhead,
           "http_trace": http,
           "audit_overhead": audit,
           "recorder_overhead": recorder}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {args.out}")
    append_history(args.out, doc)
    if not overhead["within_tolerance"]:
        raise SystemExit(
            f"tracer overhead {overhead['throughput_overhead_frac']:.2%}"
            f" exceeds {args.tolerance:.0%}")
    if not overhead["host_syncs_per_block_unchanged"]:
        raise SystemExit("telemetry added host syncs per block")
    if not audit["within_tolerance"]:
        raise SystemExit(
            f"audit overhead tok/s "
            f"{audit['throughput_overhead_frac']:.2%} / ttfb "
            f"{audit['ttfb_p50_overhead_frac']:.2%} exceeds "
            f"{args.tolerance:.0%}")
    if audit["host_syncs_per_block"] != 1.0:
        raise SystemExit("auditing changed host_syncs_per_block from 1.0")
    if audit["audits_completed"] < 1:
        raise SystemExit("audit wave completed zero audits (vacuous)")
    if sum(audit["audit_divergences"].values()) or audit["audit_errors"]:
        raise SystemExit(f"clean audit wave reported divergences/errors: "
                         f"{audit['audit_divergences']} / "
                         f"{audit['audit_errors']}")
    if not recorder["within_tolerance"]:
        raise SystemExit(
            f"recorder overhead "
            f"{recorder['throughput_overhead_frac']:.2%} exceeds "
            f"{args.tolerance:.0%}")
    if recorder["host_syncs_per_block"] != 1.0:
        raise SystemExit("recorder changed host_syncs_per_block from 1.0")
    if recorder["recorder_samples"] < 1 or recorder["timeline_polls"] < 1:
        raise SystemExit("recorder wave took no samples or served no "
                         "timeline polls (vacuous)")
    if recorder["recorder_errors"]:
        raise SystemExit(
            f"recorder reported {recorder['recorder_errors']} "
            "internal sampling errors")


if __name__ == "__main__":
    main()
