"""Kernel micro-benchmarks: Pallas (interpret) wrappers vs the jnp
reference at dLLM-decode shapes. On this CPU container the interesting
derived quantity is the analytic VMEM working set / FLOP count per tile,
not wall-clock (interpret mode is a correctness harness, not a timer)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import append_history, emit
from repro.kernels import ref
from repro.kernels.ops import block_attention, confidence_argmax

SHAPES = [  # (B, Sq, Skv, H, Hkv, D) — steady-state decode / prefill tile
    (1, 129, 4096, 8, 2, 128),
    (4, 129, 32768 // 8, 8, 2, 128),
    (1, 512, 4096, 8, 2, 128),
]


def _time(f, n=3):
    jax.block_until_ready(f())  # compile + warm
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f())
    return (time.perf_counter() - t0) / n


def main():
    key = jax.random.PRNGKey(0)
    history = {}
    for (B, Sq, Skv, H, Hkv, D) in SHAPES:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Skv, Hkv, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Skv, Hkv, D), jnp.float32)
        qp = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
        kp = jnp.broadcast_to(jnp.arange(Skv)[None], (B, Skv))
        km = jnp.ones((B, Skv), bool)
        t_ref = _time(lambda: jax.jit(ref.block_attention_ref,
                                      static_argnames=("scale",))(
            q, k, v, qp, kp, km, scale=0.088))
        flops = 4 * B * H * Sq * Skv * D
        tile_vmem = (128 * D + 2 * 128 * D + 128 * D) * 4
        emit(f"bench_kernels/attn_B{B}_Sq{Sq}_Skv{Skv}", t_ref * 1e6,
             f"flops={flops:.3g};tile_vmem_bytes={tile_vmem};ref_path=jnp")
        history[f"attn_B{B}_Sq{Sq}_Skv{Skv}_us"] = t_ref * 1e6
    for (N, V) in [(129, 50304), (129, 256000), (1024, 151936)]:
        logits = jax.random.normal(key, (N, V), jnp.float32)
        t_ref = _time(lambda: jax.jit(ref.confidence_argmax_ref)(logits))
        emit(f"bench_kernels/conf_N{N}_V{V}", t_ref * 1e6,
             f"bytes_read={N*V*4};fused_writes={N*8}")
        history[f"conf_N{N}_V{V}_us"] = t_ref * 1e6
    # no JSON output file — the history record is the persistent trail
    append_history("BENCH_kernels.json", history)


if __name__ == "__main__":
    main()
