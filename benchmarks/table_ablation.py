"""Paper Table 3 analogue: module ablation — Suf. (suffix pruning),
Dyn. (dynamic threshold), Exit. (early exit) enabled incrementally on
top of the Fast-dLLM baseline."""
from __future__ import annotations

from benchmarks.common import bench_model, emit, eval_prompts, run_method

ROWS = [
    ("base(fast)", dict(method="fast", early_exit=False)),
    ("+Suf", dict(method="streaming", alpha=0.0, early_exit=False)),
    ("+Suf+Dyn", dict(method="streaming", alpha=0.3, early_exit=False)),
    ("+Suf+Dyn+Exit", dict(method="streaming", alpha=0.3, early_exit=True)),
]


def main(n_eval: int = 32):
    cfg, params = bench_model()
    tok, samples, prompts = eval_prompts(cfg, n=n_eval)
    for name, kw in ROWS:
        r = run_method(cfg, params, prompts, samples, tok, window=16,
                       tau0=0.9, gen_len=32, **kw)
        emit(f"table_ablation/{name}",
             1e6 * r["wall"] / max(r["result"].tokens_generated, 1),
             f"acc={r['acc']:.3f};tps={r['tps']:.1f};nfe={r['nfe']};"
             f"qtok={r['qtok']}")


if __name__ == "__main__":
    main()
