"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts. Run after `dryrun --all` (+ the unrolled roofline sweep):

    PYTHONPATH=src python -m benchmarks.render_experiments > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

GiB = 2**30


def load(d):
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        recs.append(json.load(open(p)))
    return recs


def dryrun_table():
    recs = load("results/dryrun")
    print("### §Dry-run — compile proof + per-device memory\n")
    print("All combos `.lower().compile()` on both production meshes. "
          "Memory is per device; `tpu est` subtracts XLA:CPU bf16→f32 "
          "promotion buffers (DESIGN.md §6.5).\n")
    print("| arch | shape | mesh | mem/dev (raw GiB) | mem/dev (TPU est) |"
          " args GiB | dominant |")
    print("|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        pd = r["per_device"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {pd['total_bytes']/GiB:.2f} "
              f"| {pd.get('total_bytes_tpu_estimate', pd['total_bytes'])/GiB:.2f} "
              f"| {pd['argument_bytes']/GiB:.2f} | {r['dominant_term'][:-2]} |")
    print()


def roofline_table():
    recs = [r for r in load("results/roofline") if r.get("unrolled")]
    print("### §Roofline — three terms per (arch × shape), 16x16 mesh\n")
    print("Exact HLO flops (fully-unrolled scans, chunking disabled). "
          "Terms in seconds/step on TPU v5e constants; `useful` = "
          "MODEL_FLOPS(6ND or 2ND_active)/HLO_FLOPs per chip.\n")
    print("| arch | shape | variant | compute s | memory s | collective s |"
          " dominant | useful | coll GiB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         r.get("variant", ""))):
        t = r["roofline"]
        u = r.get("useful_flops_ratio")
        print(f"| {r['arch']} | {r['shape']} | {r.get('variant') or '-'} "
              f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
              f"| {t['collective_s']:.2e} | {r['dominant_term'][:-2]} "
              f"| {u:.2f} | {t['collective_bytes']/GiB:.2f} |"
              if u is not None else
              f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - |")
    print()


def streaming_vs_baseline():
    recs = [r for r in load("results/roofline")
            if r.get("unrolled") and r["shape"] == "decode_32k"]
    by = defaultdict(dict)
    for r in recs:
        by[r["arch"]][r.get("variant") or "streaming"] = r
    print("### Suffix pruning at production scale — streaming vs "
          "full-suffix baseline (decode_32k)\n")
    print("| arch | term | baseline (full suffix, Sq=512) | streaming "
          "(Sq=129) | reduction |")
    print("|---|---|---|---|---|")
    for arch, d in sorted(by.items()):
        if "baseline" not in d or "streaming" not in d:
            continue
        for term in ("compute_s", "memory_s", "collective_s"):
            b = d["baseline"]["roofline"][term]
            s = d["streaming"]["roofline"][term]
            print(f"| {arch} | {term[:-2]} | {b:.2e} | {s:.2e} "
                  f"| {b/max(s,1e-12):.2f}x |")
    print()


if __name__ == "__main__":
    dryrun_table()
    roofline_table()
    streaming_vs_baseline()
