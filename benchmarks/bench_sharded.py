"""Mesh-parallel serving benchmark: decode throughput vs data-shard
count, and 1/2/4 engine loops behind one HTTP front end.

    PYTHONPATH=src python benchmarks/bench_sharded.py \
        [--quick] [--out results/BENCH_sharded.json]

Forces 8 host devices (override via REPRO_XLA_FLAGS) so the whole
matrix runs on CPU CI. Numbers on a host mesh measure *placement
overhead*, not speedup — 8 fake devices share one physical CPU, so
sharded decode is expected to be at best flat here; the benchmark's
job is (a) proving the full executor/router path end to end at every
shard count and (b) giving real accelerators a ready-made harness
where the same JSON turns into a scaling curve.

Two sections, both written to one JSON document:

* ``decode_scaling`` — one DiffusionDecoder, batch 8, data shards
  1/2/4 (executor=None is the 1-shard baseline): decode tok/s and
  wall per block.
* ``engine_scaling`` — 1/2/4 ``EngineLoop``s on disjoint single-device
  submeshes behind one ``HttpFrontend``; closed-loop loopback clients;
  client-observed p50/p99 latency, fleet tok/s, and the per-engine
  request split from /metrics.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " " + os.environ.get(
        "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=8"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import asyncio
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def bench_decode_scaling(cfg, params, dcfg, shards, batch, reps):
    import jax
    from repro.core.decoder import DiffusionDecoder
    from repro.launch.mesh import make_host_mesh
    from repro.serving import DecodeExecutor

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 200, (batch, 10)).astype(np.int32)
    out = []
    for d in shards:
        ex = (None if d == 1 else
              DecodeExecutor(cfg, params, make_host_mesh(d, 1)))
        dec = DiffusionDecoder(cfg, params if ex is None else None, dcfg,
                               executor=ex)
        dec.generate(prompts.copy())              # warmup + compile
        t0 = time.perf_counter()
        toks = blocks = 0
        for _ in range(reps):
            r = dec.generate(prompts.copy())
            toks += r.tokens_generated
            blocks += len(r.steps_per_block)
        wall = time.perf_counter() - t0
        rec = {"data_shards": d, "batch": batch,
               "tok_per_s": round(toks / wall, 2),
               "ms_per_block": round(1e3 * wall / max(blocks, 1), 2),
               "devices": 1 if ex is None else len(ex.placement)}
        print(f"  decode data={d}: {rec['tok_per_s']} tok/s "
              f"({rec['ms_per_block']} ms/block)")
        out.append(rec)
    return out


async def _closed_loop(host, port, clients, per_client, max_tokens):
    from repro.server import client as C

    lat = []

    async def one_client(i):
        for j in range(per_client):
            t0 = time.perf_counter()
            status, _, doc = await C.complete(
                host, port, {"prompt": f"Q:{i}{j}+{j}{i}=? A:",
                             "max_tokens": max_tokens})
            assert status == 200, status
            lat.append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    await asyncio.gather(*[one_client(i) for i in range(clients)])
    wall = time.perf_counter() - t0
    return lat, wall


def _trace_imbalance(tracer, n_engines):
    """Attribute per-engine time from the recorded trace: decode-busy
    seconds (``decode_block`` X spans on each engine's track) vs
    request queue-wait seconds (async ``queue`` spans, attributed to
    the engine that admitted the request). Engine pids are 1..N in
    EngineLoop construction order."""
    evs = tracer.events()
    busy = [0.0] * n_engines
    queued = [0.0] * n_engines
    for e in evs:
        if e.get("ph") == "X" and e.get("name") == "decode_block" \
                and 1 <= e["pid"] <= n_engines:
            busy[e["pid"] - 1] += e["dur"] / 1e6
    opens = {}
    for e in sorted((e for e in evs if e.get("cat") == "request"
                     and e.get("name") == "queue"),
                    key=lambda e: e["ts"]):
        if e["ph"] == "b":
            opens[e["id"]] = e
        elif e["ph"] == "e" and e["id"] in opens:
            b = opens.pop(e["id"])
            if 1 <= b["pid"] <= n_engines:
                queued[b["pid"] - 1] += (e["ts"] - b["ts"]) / 1e6
    return {"decode_busy_s": [round(v, 3) for v in busy],
            "queue_wait_s": [round(v, 3) for v in queued]}


def bench_engine_scaling(cfg, params, dcfg, engine_counts, clients,
                         per_client, max_tokens, trace_dir=None):
    from repro.data.tokenizer import ByteTokenizer
    from repro.launch.mesh import make_submeshes
    from repro.serving import ContinuousEngine, DecodeExecutor, percentile
    from repro.server import EngineLoop, EngineRouter, HttpFrontend

    tok = ByteTokenizer(cfg.vocab_size)
    out = []
    for n in engine_counts:
        tracer = None
        if trace_dir:
            from repro.obs.trace import Tracer
            tracer = Tracer()
        meshes = make_submeshes(n, 1, 1)
        engines = [ContinuousEngine(
            cfg, params, dcfg, max_slots=4, tokenizer=tok,
            executor=DecodeExecutor(cfg, params, m)) for m in meshes]
        loops = [EngineLoop(e, max_pending=64, idle_poll_s=0.002,
                            tracer=tracer, index=i)
                 for i, e in enumerate(engines)]
        front = loops[0] if n == 1 else EngineRouter(loops)

        async def run(front=front, engines=engines, n=n, tracer=tracer):
            fe = await HttpFrontend(front, port=0, tracer=tracer).start()
            try:
                lat, wall = await _closed_loop(
                    fe.host, fe.port, clients, per_client, max_tokens)
                served = [len(e.metrics.requests) for e in engines]
                toks = sum(e.metrics.total_tokens for e in engines)
                return {"engines": n, "clients": clients,
                        "requests": clients * per_client,
                        "tok_per_s": round(toks / wall, 2),
                        "latency_p50_ms": round(
                            1e3 * percentile(lat, 50), 1),
                        "latency_p99_ms": round(
                            1e3 * percentile(lat, 99), 1),
                        "per_engine_requests": served}
            finally:
                await fe.shutdown(drain=True, timeout_s=30)

        rec = asyncio.run(run())
        if tracer is not None:
            rec["per_engine_time"] = _trace_imbalance(tracer, n)
            path = os.path.join(trace_dir, f"trace_engines{n}.json")
            tracer.export(path)
            rec["trace_path"] = path
        print(f"  engines={n}: {rec['tok_per_s']} tok/s "
              f"p50={rec['latency_p50_ms']}ms "
              f"p99={rec['latency_p99_ms']}ms "
              f"split={rec['per_engine_requests']}"
              + (f" busy={rec['per_engine_time']['decode_busy_s']}"
                 f" queued={rec['per_engine_time']['queue_wait_s']}"
                 if tracer is not None else ""))
        out.append(rec)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer shard counts and requests")
    ap.add_argument("--out", default="results/BENCH_sharded.json")
    ap.add_argument("--trace-dir", default="",
                    help="record repro.obs traces per engine count and "
                         "report decode-busy vs queue-wait seconds per "
                         "engine (Chrome JSON written here)")
    args = ap.parse_args()

    import jax

    from repro.core.decoder import DecodeConfig
    from repro.models import get_config, init_params

    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(3))
    dcfg = DecodeConfig(method="streaming", gen_len=32, block_size=8,
                        window=16)

    shards = (1, 2) if args.quick else (1, 2, 4)
    engine_counts = (1, 2) if args.quick else (1, 2, 4)
    clients = 2 if args.quick else 4
    per_client = 2 if args.quick else 4

    print(f"devices={len(jax.devices())} backend={jax.default_backend()}")
    print("== decode throughput vs data shards ==")
    decode = bench_decode_scaling(cfg, params, dcfg, shards, batch=8,
                                  reps=1 if args.quick else 3)
    print("== engine loops behind one front end ==")
    engines = bench_engine_scaling(cfg, params, dcfg, engine_counts,
                                   clients, per_client, max_tokens=16,
                                   trace_dir=args.trace_dir or None)

    doc = {"arch": cfg.name, "method": dcfg.method,
           "n_devices": len(jax.devices()),
           "backend": jax.default_backend(),
           "note": ("host-mesh CPU run: measures placement overhead and "
                    "proves the sharded path; real scaling needs real "
                    "chips"),
           "decode_scaling": decode, "engine_scaling": engines}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
