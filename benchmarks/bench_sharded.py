"""Mesh-parallel serving benchmark: decode throughput vs data-shard
count, and 1/2/4 engine loops behind one HTTP front end.

    PYTHONPATH=src python benchmarks/bench_sharded.py \
        [--quick] [--out results/BENCH_sharded.json]

Process model: the parent never imports jax. Every measured
configuration runs in its OWN subprocess whose environment comes from
``repro.launch.host.budget_env`` — per-engine XLA intra-op thread
budget (``cores // engines``), 8 forced host devices, CPU platform.
XLA env is process-global and read once at backend init, so this is
the only honest way to compare engine counts: N engines measured under
the thread budget N engines would actually serve with.

Compile discipline: children enable the persistent compilation cache
(shared across the engine-count sweep, so config 2 reuses config 1's
XLA work) and pre-warm every (shape bucket x method x batch) fused
variant through ``ContinuousEngine.prewarm`` BEFORE the request burst
starts. The measurement window therefore contains zero compiles —
``post_warm_compiles`` is asserted 0 per engine and recorded in the
JSON. The seed benchmark compiled inside the window, per engine, which
is exactly the 1 -> 2 -> 4 engine collapse this PR removes.

Workload: fixed seed (recorded in the JSON) generates the SAME
request mix for every engine count — fixed-length arithmetic prompts,
a synchronized loopback request burst. Per-engine decode-busy seconds,
queue-wait seconds, and steal counts come straight from
``ServeMetrics.snapshot`` (first-class since this PR; the old
trace-replay attribution is gone).

Numbers on a host mesh measure *placement + host-budget overhead*, not
chip speedup — 8 fake devices share one physical CPU. The benchmark's
job is (a) proving the budgeted multi-engine path end to end and (b)
giving real accelerators a ready-made harness.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from common import append_history

HOST_DEVICES = 8
WORKLOAD_SEED = 3          # also the params PRNG seed: one knob, recorded


def make_workload(seed, clients, per_client):
    """The request mix, identical for every engine count: fixed-length
    single-digit arithmetic prompts (length-12 byte prompts -> one
    shape bucket, so pre-warm covers the whole workload)."""
    rng = np.random.default_rng(seed)
    digits = rng.integers(0, 10, (clients, per_client, 4))
    return [[f"Q:{a}{b}+{c}{d}=? A:" for (a, b, c, d) in row]
            for row in digits]


# --------------------------------------------------------------- child

def child_decode(spec):
    """Decode throughput vs data shards, one process for the sweep
    (shard counts share a decoder compile cache; no serving threads)."""
    import jax
    from repro.core.decoder import DecodeConfig, DiffusionDecoder
    from repro.launch.mesh import make_host_mesh
    from repro.models import get_config, init_params
    from repro.serving import DecodeExecutor

    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(spec["seed"]))
    dcfg = DecodeConfig(method="streaming", gen_len=32, block_size=8,
                        window=16)
    rng = np.random.default_rng(spec["seed"])
    prompts = rng.integers(0, 200, (spec["batch"], 10)).astype(np.int32)
    out = []
    for d in spec["shards"]:
        ex = (None if d == 1 else
              DecodeExecutor(cfg, params, make_host_mesh(d, 1)))
        dec = DiffusionDecoder(cfg, params if ex is None else None, dcfg,
                               executor=ex)
        dec.generate(prompts.copy())              # warmup + compile
        t0 = time.perf_counter()
        toks = blocks = 0
        for _ in range(spec["reps"]):
            r = dec.generate(prompts.copy())
            toks += r.tokens_generated
            blocks += len(r.steps_per_block)
        wall = time.perf_counter() - t0
        out.append({"data_shards": d, "batch": spec["batch"],
                    "tok_per_s": round(toks / wall, 2),
                    "ms_per_block": round(1e3 * wall / max(blocks, 1), 2),
                    "devices": 1 if ex is None else len(ex.placement)})
    return {"decode_scaling": out, "n_devices": len(jax.devices()),
            "backend": jax.default_backend()}


async def _burst(host, port, workload, max_tokens):
    """Fire every request concurrently from t0. A closed loop would let
    an N-engine config admit each arrival instantly (queue-wait ~0) and
    decode batch-1 gangs while the 1-engine config batches its backlog
    at max_slots — the rows would measure gang amortization, not engine
    scaling. With the full mix in flight up front, every engine count
    forms the same max_slots-sized gangs over the same requests."""
    from repro.server import client as C

    lat = []

    async def one(p):
        t0 = time.perf_counter()
        status, _, doc = await C.complete(
            host, port, {"prompt": p, "max_tokens": max_tokens})
        assert status == 200, status
        lat.append(time.perf_counter() - t0)

    t0 = time.perf_counter()
    await asyncio.gather(*[one(p) for row in workload for p in row])
    return lat, time.perf_counter() - t0


def child_engines(spec):
    """One engine-count configuration: budgeted process (env set by the
    parent), persistent compile cache, pre-warm, then the request burst."""
    import jax
    from repro.core.decoder import DecodeConfig, round_up_blocks
    from repro.data.tokenizer import ByteTokenizer
    from repro.launch import host as host_budgeting
    from repro.launch.mesh import make_submeshes
    from repro.models import get_config, init_params
    from repro.obs.compile import persistent_cache_counters
    from repro.serving import ContinuousEngine, DecodeExecutor, percentile
    from repro.server import EngineLoop, EngineRouter, HttpFrontend

    n = spec["engines"]
    pc_on = host_budgeting.enable_compile_cache(spec["cache_dir"])
    budget = host_budgeting.compute_host_budget(n)

    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(spec["seed"]))
    dcfg = DecodeConfig(method="streaming", gen_len=32, block_size=8,
                        window=16)
    tok = ByteTokenizer(cfg.vocab_size)
    workload = make_workload(spec["seed"], spec["clients"],
                             spec["per_client"])
    gen_len = round_up_blocks(spec["max_tokens"], dcfg.block_size)
    bucket = (len(tok.encode(workload[0][0])), gen_len)

    meshes = make_submeshes(n, 1, 1)
    engines = [ContinuousEngine(
        cfg, params, dcfg, max_slots=4, tokenizer=tok,
        executor=DecodeExecutor(cfg, params, m), host_budget=budget)
        for m in meshes]
    t0 = time.perf_counter()
    prewarm = [e.prewarm([bucket]) for e in engines]
    prewarm_s = time.perf_counter() - t0
    loops = [EngineLoop(e, max_pending=64, idle_poll_s=0.002, index=i)
             for i, e in enumerate(engines)]
    front = loops[0] if n == 1 else EngineRouter(loops,
                                                steal=spec["steal"])

    async def run():
        fe = await HttpFrontend(front, port=0).start()
        try:
            lat, wall = await _burst(fe.host, fe.port, workload,
                                     spec["max_tokens"])
        finally:
            await fe.shutdown(drain=True, timeout_s=60)
        snaps = [e.metrics.snapshot() for e in engines]
        toks = sum(e.metrics.total_tokens for e in engines)
        return {
            "engines": n, "clients": spec["clients"],
            "requests": sum(len(row) for row in workload),
            "intra_op_threads": budget.intra_op,
            "tok_per_s": round(toks / wall, 2),
            "latency_p50_ms": round(1e3 * percentile(lat, 50), 1),
            "latency_p99_ms": round(1e3 * percentile(lat, 99), 1),
            "prewarm_s": round(prewarm_s, 2),
            "prewarm_variants": sum(r["variants"] for r in prewarm),
            "persistent_cache": dict(persistent_cache_counters()) if pc_on
            else None,
            "per_engine": [{
                "requests": s["requests"],
                "decode_busy_s": round(s["busy_time_s"], 3),
                "queue_wait_s": round(s["queue_wait_s"], 3),
                "steals_in": s["steals_in"],
                "steals_out": s["steals_out"],
                "compile_misses": s["compile_misses"],
                "post_warm_compiles": s["post_warm_compiles"],
            } for s in snaps],
        }

    rec = asyncio.run(run())
    post = sum(e["post_warm_compiles"] for e in rec["per_engine"])
    assert post == 0, (
        f"{post} compile(s) inside the measurement window — pre-warm "
        f"missed a variant (see repro_post_warm_compiles_total)")
    return rec


# -------------------------------------------------------------- parent

def _spawn(mode, spec, engines_for_budget):
    """Run one child config in a fresh budgeted process; its last
    stdout line is the JSON result."""
    from repro.launch import host as host_budgeting
    budget = host_budgeting.compute_host_budget(engines_for_budget)
    env = host_budgeting.budget_env(budget, host_devices=HOST_DEVICES,
                                   platform="cpu")
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", mode,
         "--spec", json.dumps(spec)],
        env=env, capture_output=True, text=True, timeout=3000)
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise RuntimeError(f"child {mode} {spec} failed")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer shard counts and requests")
    ap.add_argument("--out", default="results/BENCH_sharded.json")
    ap.add_argument("--cache-dir", default="results/compile_cache",
                    help="persistent XLA compile cache shared across "
                         "the engine-count sweep")
    ap.add_argument("--no-steal", action="store_true",
                    help="disable block-boundary work stealing")
    ap.add_argument("--child", default="", choices=["", "decode",
                                                    "engines"])
    ap.add_argument("--spec", default="{}", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.child:
        fn = child_decode if args.child == "decode" else child_engines
        print(json.dumps(fn(json.loads(args.spec))))
        return

    shards = (1, 2) if args.quick else (1, 2, 4)
    engine_counts = (1, 2) if args.quick else (1, 2, 4)
    # full mode: enough concurrent clients that EVERY engine count can
    # form max_slots-sized gangs (16 clients / 4 engines = 4 rows each)
    # — otherwise small fleets win on batch amortization alone and the
    # comparison measures workload shape, not the serving stack
    clients = 2 if args.quick else 16
    per_client = 2

    print("== decode throughput vs data shards ==")
    dec = _spawn("decode", {"seed": WORKLOAD_SEED, "shards": list(shards),
                            "batch": 8,
                            "reps": 1 if args.quick else 3},
                 engines_for_budget=1)
    for r in dec["decode_scaling"]:
        print(f"  decode data={r['data_shards']}: {r['tok_per_s']} tok/s "
              f"({r['ms_per_block']} ms/block)")

    print("== engine loops behind one front end (budgeted processes) ==")
    engines = []
    for n in engine_counts:
        rec = _spawn("engines", {
            "seed": WORKLOAD_SEED, "engines": n, "clients": clients,
            "per_client": per_client, "max_tokens": 16,
            "cache_dir": os.path.abspath(args.cache_dir),
            "steal": not args.no_steal}, engines_for_budget=n)
        print(f"  engines={n} ({rec['intra_op_threads']} thread(s) each): "
              f"{rec['tok_per_s']} tok/s "
              f"p50={rec['latency_p50_ms']}ms "
              f"p99={rec['latency_p99_ms']}ms "
              f"split={[e['requests'] for e in rec['per_engine']]} "
              f"busy={[e['decode_busy_s'] for e in rec['per_engine']]} "
              f"steals={sum(e['steals_in'] for e in rec['per_engine'])} "
              f"prewarm={rec['prewarm_s']}s")
        engines.append(rec)

    doc = {"arch": "tiny", "method": "streaming",
           "workload_seed": WORKLOAD_SEED,
           "n_devices": dec["n_devices"], "backend": dec["backend"],
           "host_cores": os.cpu_count(),
           "steal": not args.no_steal,
           "note": ("host-mesh CPU run: subprocess-per-config with "
                    "per-engine thread budgets (repro.launch.host), "
                    "persistent compile cache + pre-warm (zero compiles "
                    "inside the measurement window); real scaling needs "
                    "real chips"),
           "decode_scaling": dec["decode_scaling"],
           "engine_scaling": engines}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {args.out}")
    append_history(args.out, doc)


if __name__ == "__main__":
    main()
