"""Paper Table 6 analogue: trailing positional information ablation."""
from __future__ import annotations

from benchmarks.common import bench_model, emit, eval_prompts, run_method


def main(n_eval: int = 32):
    cfg, params = bench_model()
    tok, samples, prompts = eval_prompts(cfg, n=n_eval)
    for trailing in (False, True):
        r = run_method(cfg, params, prompts, samples, tok,
                       method="streaming", gen_len=32, window=8,
                       trailing_position=trailing)
        emit(f"table_trailing/{'with' if trailing else 'without'}",
             1e6 * r["wall"] / max(r["result"].tokens_generated, 1),
             f"acc={r['acc']:.3f};tps={r['tps']:.1f}")


if __name__ == "__main__":
    main()
