"""Paper Table 4 analogue: effect of prefill (few-shot) length on
throughput/speedup for Fast-dLLM vs streaming."""
from __future__ import annotations

from benchmarks.common import bench_model, emit, eval_prompts, run_method


def main(n_eval: int = 24):
    cfg, params = bench_model()
    for shots in (0, 2, 4):
        tok, samples, prompts = eval_prompts(cfg, n=n_eval, shots=shots)
        base = None
        for m in ("prefix", "fast", "streaming"):
            r = run_method(cfg, params, prompts, samples, tok, method=m,
                           gen_len=32, window=16)
            if base is None:
                base = r["tps"] or 1e-9
            emit(f"table_prefill/shots{shots}/{m}",
                 1e6 * r["wall"] / max(r["result"].tokens_generated, 1),
                 f"acc={r['acc']:.3f};tps={r['tps']:.1f};"
                 f"speedup={r['tps']/base:.2f}x;promptlen={prompts.shape[1]}")


if __name__ == "__main__":
    main()
