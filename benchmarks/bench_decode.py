"""Decode-loop benchmark: legacy per-step host loop vs the fused
device-resident denoise loop, across all five methods, on the ragged
serving workload from bench_serving.

    PYTHONPATH=src python benchmarks/bench_decode.py \
        [--n 16] [--max-slots 8] [--arch tiny] [--use-kernels] \
        [--out results/BENCH_decode.json]

What it measures, per (method, loop):
  * decode wall time / throughput on the continuous engine (warmup wave
    first, so compiles are excluded — same protocol as bench_serving)
  * host_syncs_per_block — blocking device->host sync points per decoded
    block: ~1 for the fused loop, ~steps (8 here) for the host loop
  * logit_host_copies — full (B, K, V) block-logit device->host copies:
    0 under the fused loop (and 0 for the parallel methods in either
    loop, whose confidence comes from the fused head path)
  * token identity between the two loops (direct decoder run on fixed
    prompts; dkv is reported as an agreement fraction — its step-level
    KV freezing amplifies XLA:CPU run-to-run noise, see test_serving)

The default arch is `tiny`: dispatch/transfer-bound, which is exactly
the regime the fused loop targets. Use --arch tiny-100m to see the
compute-bound regime where the two loops converge.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np

from bench_serving import GEN_LEN, ragged_model, ragged_workload
from common import BLOCK, append_history
from repro.core.decoder import METHODS, DecodeConfig, DiffusionDecoder
from repro.serving import ContinuousEngine, ServeMetrics


def run_engine(cfg, params, dcfg, work, max_slots, tracer=None):
    """Timed engine run (post-warmup). ``tracer`` attaches the full
    repro.obs span pipeline — bench_obs uses the tracer-on/off delta
    as the observability overhead measurement."""
    eng = ContinuousEngine(cfg, params, dcfg, max_slots=max_slots)
    if tracer is not None:
        eng.set_tracer(tracer, "engine-0")
    for p, mt in work:                  # warmup wave: compile everything
        eng.submit(p, max_tokens=mt)
    eng.run_to_completion()
    eng.metrics = ServeMetrics(max_slots=max_slots)
    jit_after_warmup = eng.jit_cache_size()
    t0 = time.perf_counter()
    for p, mt in work:
        eng.submit(p, max_tokens=mt,
                   trace_id=tracer.new_trace_id()
                   if tracer is not None else "")
    done = eng.run_to_completion()
    wall = time.perf_counter() - t0
    snap = eng.metrics.snapshot()
    return {
        "requests": len(done),
        "tokens": snap["tokens"],
        "wall_s": wall,
        "throughput_tok_s": snap["tokens"] / max(wall, 1e-9),
        "latency_p50_s": snap["latency_p50_s"],
        "latency_p99_s": snap["latency_p99_s"],
        "host_syncs_per_block": snap["host_syncs_per_block"],
        "device_steps_per_block": snap["device_steps_per_block"],
        "logit_host_copies": snap["logit_host_copies"],
        "jit_cache": jit_after_warmup,
        "recompiled_after_warmup": eng.jit_cache_size() > jit_after_warmup,
    }


def token_identity(cfg, params, dcfg, seed=5):
    """Direct decoder comparison on fixed prompts: fraction of positions
    where the two loops emit the same token (1.0 = bit-identical)."""
    prompts = np.random.default_rng(seed).integers(
        32, 127, (4, 12)).astype(np.int32)
    host = DiffusionDecoder(
        cfg, params, dataclasses.replace(dcfg, fused=False)).generate(
        prompts.copy())
    fused = DiffusionDecoder(
        cfg, params, dataclasses.replace(dcfg, fused=True)).generate(
        prompts.copy())
    return {
        "agreement": float((host.tokens == fused.tokens).mean()),
        "identical": bool((host.tokens == fused.tokens).all()),
        "nfe_equal": host.nfe == fused.nfe,
        "steps_equal": host.steps_per_block == fused.steps_per_block,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--arch", default="tiny",
                    help="tiny = dispatch-bound (the fused loop's win); "
                         "tiny-100m = compute-bound")
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas attention/confidence (interpret mode on "
                         "CPU is slow; meant for real TPU)")
    ap.add_argument("--out", default="results/BENCH_decode.json")
    args = ap.parse_args()

    cfg, params = ragged_model(args.arch)
    work = ragged_workload(args.n)

    per_method = {}
    for method in METHODS:
        dcfg = DecodeConfig(method=method, gen_len=GEN_LEN, block_size=BLOCK,
                            window=8, use_kernels=args.use_kernels)
        host = run_engine(cfg, params,
                          dataclasses.replace(dcfg, fused=False),
                          work, args.max_slots)
        fused = run_engine(cfg, params,
                           dataclasses.replace(dcfg, fused=True),
                           work, args.max_slots)
        ident = token_identity(cfg, params, dcfg)
        per_method[method] = {
            "host": host,
            "fused": fused,
            "identity": ident,
            "speedup_wall": host["wall_s"] / max(fused["wall_s"], 1e-9),
            "sync_reduction": (host["host_syncs_per_block"]
                               / max(fused["host_syncs_per_block"], 1e-9)),
        }
        print(f"{method:10s} wall {host['wall_s']:.2f}s -> "
              f"{fused['wall_s']:.2f}s "
              f"({per_method[method]['speedup_wall']:.2f}x)  "
              f"syncs/blk {host['host_syncs_per_block']:.1f} -> "
              f"{fused['host_syncs_per_block']:.1f}  "
              f"logit copies {host['logit_host_copies']} -> "
              f"{fused['logit_host_copies']}  "
              f"agree={ident['agreement']:.3f}")

    rec = {
        "workload": {"n": args.n, "gen_budgets": "16(2/3)|32(1/3)",
                     "arch": args.arch, "max_slots": args.max_slots,
                     "use_kernels": args.use_kernels,
                     "fake_eos_token": cfg.eos_token_id},
        "methods": per_method,
        # acceptance: the fused loop removes every in-block (B, K, V)
        # logit device->host copy, and decode wall time is no worse
        "fused_logit_copies_total": sum(
            m["fused"]["logit_host_copies"] for m in per_method.values()),
        "geomean_speedup": float(np.exp(np.mean(
            [np.log(m["speedup_wall"]) for m in per_method.values()]))),
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    append_history(args.out, rec)
    print(f"\ndecode,geomean_speedup={rec['geomean_speedup']:.2f}x,"
          f"fused_logit_copies={rec['fused_logit_copies_total']}")


if __name__ == "__main__":
    main()
