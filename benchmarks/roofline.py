"""§Roofline: render the per-(arch x shape) roofline table from the
dry-run artifacts (results/roofline/*__unrolled.json preferred; falls
back to results/dryrun). Emits one CSV row per combo with the three
terms, the dominant bottleneck, and the useful-FLOPs ratio."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def load_records(dirs=("results/roofline", "results/dryrun")):
    recs = {}
    for d in dirs:
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            r = json.load(open(path))
            key = (r["arch"], r["shape"], r["mesh"], r.get("variant", ""))
            # prefer unrolled (exact flops) records
            if key not in recs or r.get("unrolled"):
                recs[key] = r
    return recs


def main():
    recs = load_records()
    if not recs:
        emit("roofline/NO_RECORDS", 0.0, "run repro.launch.dryrun first")
        return
    for (arch, shape, mesh, variant), r in sorted(recs.items()):
        t = r["roofline"]
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        ratio = r.get("useful_flops_ratio")
        uf = f"{ratio:.2f}" if ratio is not None else "na"
        derived = (f"compute_s={t['compute_s']:.3e};"
                   f"memory_s={t['memory_s']:.3e};"
                   f"collective_s={t['collective_s']:.3e};"
                   f"dominant={r['dominant_term']};useful_flops={uf}")
        emit(f"roofline/{arch}/{shape}/{mesh}"
             + (f"/{variant}" if variant and variant != "streaming" else ""),
             total * 1e6, derived)


if __name__ == "__main__":
    main()
