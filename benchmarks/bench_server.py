"""HTTP serving load harness: closed-loop and open-loop (Poisson)
workloads against the asyncio front end (``repro.server``), loopback
only, zero external dependencies.

    PYTHONPATH=src python benchmarks/bench_server.py \
        [--clients 4] [--per-client 4] [--open-n 32] [--rate 8] \
        [--slo 5.0] [--out results/BENCH_server.json]

Two load shapes, both over real sockets:

* **closed loop** — ``--clients`` concurrent clients, each issuing its
  next streaming request only after the previous one finished. Measures
  the service capability: client-observed TTFB (first SSE chunk) and
  request latency at fixed concurrency.
* **open loop** — Poisson arrivals at ``--rate`` req/s regardless of
  completions (the serving-paper regime: arrival rate is set by the
  world, not by the server). Every request carries a ``timeout_s`` SLO;
  the server answers 429 when its bounded admission queue fills and
  cancels requests that blow the deadline. Reported **goodput** counts
  only requests that completed fully within the SLO.

The model is the ragged fake-EOS tiny model from ``bench_serving``
(mixed early-exit/straggler behavior — the regime where continuous
batching and admission control actually matter), so the whole harness
isolates scheduling + network behavior from model quality.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np

from bench_serving import GEN_LEN, ragged_model, ragged_workload
from common import append_history, shared_prefix_workload
from repro.core.decoder import DecodeConfig
from repro.data.tokenizer import ByteTokenizer
from repro.serving import ContinuousEngine, percentile
from repro.server import EngineLoop, HttpFrontend
from repro.server import client as C

BLOCK = 8


def build_frontend(max_slots: int, max_pending: int,
                   prefix_cache: bool = False, tracer=None):
    cfg, params = ragged_model()
    d = DecodeConfig(method="streaming", gen_len=GEN_LEN, block_size=BLOCK,
                     window=8, prefix_cache=prefix_cache, cache_chunk=16)
    eng = ContinuousEngine(cfg, params, d, max_slots=max_slots,
                           tokenizer=ByteTokenizer(cfg.vocab_size))
    return HttpFrontend(EngineLoop(eng, max_pending=max_pending,
                                   idle_poll_s=0.002, tracer=tracer),
                        port=0, tracer=tracer), eng


async def stream_once(host, port, prompt, max_tokens):
    """One streaming request; returns client-observed timings."""
    t0 = time.perf_counter()
    stream = await C.SSEStream.open(
        host, port, {"prompt": prompt, "max_tokens": max_tokens})
    if stream.status != 200:
        return {"status": stream.status, "latency_s": 0.0}
    ttfb = None
    final = None
    async for event in stream.events():
        if ttfb is None and "block" in event:
            ttfb = time.perf_counter() - t0
        if "finish_reason" in event:
            final = event
    await stream.close()
    latency = time.perf_counter() - t0
    return {"status": 200, "ttfb_s": ttfb if ttfb is not None else latency,
            "latency_s": latency,
            "n_tokens": final["n_tokens"] if final else 0,
            "finish_reason": final["finish_reason"] if final else "?"}


async def closed_loop(host, port, clients, per_client, work):
    """Fixed-concurrency load: every client runs its share of the
    workload back-to-back."""
    async def one_client(idx):
        out = []
        for j in range(per_client):
            prompt, budget = work[(idx * per_client + j) % len(work)]
            out.append(await stream_once(host, port, prompt, budget))
        return out

    t0 = time.perf_counter()
    per = await asyncio.gather(*[one_client(i) for i in range(clients)])
    wall = time.perf_counter() - t0
    recs = [r for rs in per for r in rs if r["status"] == 200]
    toks = sum(r["n_tokens"] for r in recs)
    return {
        "clients": clients,
        "requests": len(recs),
        "tokens": int(toks),
        "wall_s": wall,
        "throughput_tok_s": toks / max(wall, 1e-9),
        "ttfb_p50_s": percentile([r["ttfb_s"] for r in recs], 50),
        "ttfb_p99_s": percentile([r["ttfb_s"] for r in recs], 99),
        "latency_p50_s": percentile([r["latency_s"] for r in recs], 50),
        "latency_p99_s": percentile([r["latency_s"] for r in recs], 99),
    }


async def open_loop(host, port, n, rate_rps, slo_s, work, seed=11):
    """Poisson arrivals at ``rate_rps``; each request gets ``slo_s`` as
    its server-enforced deadline. Goodput counts only requests that
    finished completely (not cancelled, not rejected) within the SLO."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, n)
    arrivals = np.cumsum(gaps)

    async def one(i):
        await asyncio.sleep(float(arrivals[i]))
        prompt, budget = work[i % len(work)]
        t0 = time.perf_counter()
        status, _, doc = await C.complete(
            host, port, {"prompt": prompt, "max_tokens": budget,
                         "timeout_s": slo_s})
        latency = time.perf_counter() - t0
        if status != 200:
            return {"status": status, "latency_s": latency}
        return {"status": 200, "latency_s": latency,
                "ttfb_s": doc["ttfb_s"], "n_tokens": doc["n_tokens"],
                "cancelled": doc["cancelled"],
                "finish_reason": doc["finish_reason"]}

    t0 = time.perf_counter()
    recs = await asyncio.gather(*[one(i) for i in range(n)])
    wall = time.perf_counter() - t0
    ok = [r for r in recs if r["status"] == 200]
    rejected = sum(r["status"] == 429 for r in recs)
    deadline_missed = sum(r.get("finish_reason") == "deadline" for r in ok)
    good = [r for r in ok
            if not r["cancelled"] and r["latency_s"] <= slo_s]
    good_toks = sum(r["n_tokens"] for r in good)
    return {
        "offered_requests": n,
        "offered_rps": rate_rps,
        "slo_s": slo_s,
        "wall_s": wall,
        "admission_rejects": int(rejected),
        "deadline_misses": int(deadline_missed),
        "completed": len(ok),
        "good_requests": len(good),
        "goodput_rps": len(good) / max(wall, 1e-9),
        "goodput_tok_s": good_toks / max(wall, 1e-9),
        "ttfb_p50_s": percentile([r["ttfb_s"] for r in ok], 50),
        "ttfb_p99_s": percentile([r["ttfb_s"] for r in ok], 99),
        "latency_p50_s": percentile([r["latency_s"] for r in ok], 50),
        "latency_p99_s": percentile([r["latency_s"] for r in ok], 99),
    }


async def shared_prefix_loop(host, port, clients, per_client, work):
    """Closed-loop JSON completions over *persistent* connections
    (``ClientSession`` keep-alive): the shared-prefix regime is many
    short exchanges per client, where per-request TCP setup would
    otherwise dominate small-prompt TTFB. Returns client-observed
    latency plus connection-reuse and server cache counters."""
    async def one_client(idx):
        sess = C.ClientSession(host, port)
        out = []
        try:
            for j in range(per_client):
                prompt, budget = work[(idx * per_client + j) % len(work)]
                t0 = time.perf_counter()
                status, _, doc = await sess.complete(
                    {"prompt": prompt, "max_tokens": budget})
                lat = time.perf_counter() - t0
                if status == 200:
                    out.append({"latency_s": lat,
                                "ttfb_s": doc["ttfb_s"],
                                "n_tokens": doc["n_tokens"],
                                "cache_hit_tokens":
                                    doc["cache_hit_tokens"]})
        finally:
            await sess.close()
        return out, sess.connects, sess.requests

    t0 = time.perf_counter()
    per = await asyncio.gather(*[one_client(i) for i in range(clients)])
    wall = time.perf_counter() - t0
    recs = [r for rs, _, _ in per for r in rs]
    return {
        "clients": clients,
        "requests": len(recs),
        "wall_s": wall,
        "connections_opened": sum(c for _, c, _ in per),
        "requests_per_connection": (len(recs)
                                    / max(sum(c for _, c, _ in per), 1)),
        "warm_requests": sum(r["cache_hit_tokens"] > 0 for r in recs),
        "hit_tokens": sum(r["cache_hit_tokens"] for r in recs),
        "ttfb_p50_s": percentile([r["ttfb_s"] for r in recs], 50),
        "ttfb_p99_s": percentile([r["ttfb_s"] for r in recs], 99),
        "latency_p50_s": percentile([r["latency_s"] for r in recs], 50),
        "latency_p99_s": percentile([r["latency_s"] for r in recs], 99),
    }


async def run_shared_prefix(args):
    """Shared-prefix scenario: its own front end with the prefix cache
    on, zipf template traffic, keep-alive clients."""
    frontend, eng = build_frontend(args.max_slots, args.max_pending,
                                   prefix_cache=True)
    await frontend.start()
    host, port = frontend.host, frontend.port
    prompts, _, reuse = shared_prefix_workload(
        max(16, args.open_n), templates=4, template_len=64, tail_len=8,
        as_text=True)
    work = [(p, GEN_LEN) for p in prompts]
    # warmup wave compiles shapes AND warms the template chunks
    await shared_prefix_loop(host, port, args.clients,
                             max(1, 8 // args.clients), work[:8])
    out = await shared_prefix_loop(host, port, args.clients,
                                   args.per_client, work)
    snap = eng.metrics.snapshot()
    out["template_reuse_frac"] = reuse
    out["server_cache"] = {k: snap[k] for k in
                           ("prefix_cache_hits", "prefix_cache_hit_tokens",
                            "prefix_cache_evictions", "prefix_cache_bytes")}
    await frontend.shutdown(drain=True)
    return out


async def run(args):
    frontend, eng = build_frontend(args.max_slots, args.max_pending)
    await frontend.start()
    host, port = frontend.host, frontend.port
    work = ragged_workload(max(16, args.open_n))
    # warmup wave over HTTP: compiles the (bucket, batch, block) shape
    # lattice before anything is timed
    await closed_loop(host, port, args.clients,
                      max(1, 16 // args.clients), work)

    closed = await closed_loop(host, port, args.clients,
                               args.per_client, work)
    open_ = await open_loop(host, port, args.open_n, args.rate,
                            args.slo, work)
    snap = eng.metrics.snapshot()
    await frontend.shutdown(drain=True)
    shared = await run_shared_prefix(args)
    return {"config": {"max_slots": args.max_slots,
                       "max_pending": args.max_pending,
                       "gen_len": GEN_LEN, "block": BLOCK,
                       "method": "streaming"},
            "closed_loop": closed,
            "open_loop": open_,
            "shared_prefix": shared,
            "server_metrics": {k: snap[k] for k in
                               ("requests", "tokens", "mean_occupancy",
                                "admission_rejects", "cancelled",
                                "deadline_misses", "queue_depth")}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--per-client", type=int, default=4)
    ap.add_argument("--open-n", type=int, default=32)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="open-loop Poisson arrival rate, req/s")
    ap.add_argument("--slo", type=float, default=5.0,
                    help="per-request deadline (timeout_s), seconds")
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--max-pending", type=int, default=16)
    ap.add_argument("--out", default="results/BENCH_server.json")
    args = ap.parse_args()

    result = asyncio.run(run(args))
    c, o = result["closed_loop"], result["open_loop"]
    print(f"closed-loop: {c['requests']} req @ {args.clients} clients  "
          f"tok/s={c['throughput_tok_s']:.1f}  "
          f"ttfb_p50={c['ttfb_p50_s'] * 1e3:.0f}ms  "
          f"p50={c['latency_p50_s'] * 1e3:.0f}ms  "
          f"p99={c['latency_p99_s'] * 1e3:.0f}ms")
    print(f"open-loop: offered={o['offered_rps']:.1f}rps n={o['offered_requests']}  "
          f"goodput={o['goodput_rps']:.2f}rps ({o['good_requests']} in SLO "
          f"{o['slo_s']}s)  rejects={o['admission_rejects']}  "
          f"deadline_misses={o['deadline_misses']}  "
          f"p99={o['latency_p99_s'] * 1e3:.0f}ms")
    s = result["shared_prefix"]
    print(f"shared-prefix: {s['requests']} req over "
          f"{s['connections_opened']} conns "
          f"({s['requests_per_connection']:.1f} req/conn, keep-alive)  "
          f"warm={s['warm_requests']} hit_toks={s['hit_tokens']}  "
          f"ttfb_p50={s['ttfb_p50_s'] * 1e3:.0f}ms  "
          f"p50={s['latency_p50_s'] * 1e3:.0f}ms")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    append_history(args.out, result)


if __name__ == "__main__":
    main()
