"""Paper Figure 6 analogue: dynamic-threshold strength alpha sweep."""
from __future__ import annotations

from benchmarks.common import GEN_LEN, bench_model, emit, eval_prompts, \
    run_method


def main(n_eval: int = 24):
    cfg, params = bench_model()
    tok, samples, prompts = eval_prompts(cfg, n=n_eval)
    for a in (0.0, 0.1, 0.3, 0.6, 0.9):
        r = run_method(cfg, params, prompts, samples, tok,
                       method="streaming", gen_len=GEN_LEN, window=16,
                       alpha=a, early_exit=False)
        emit(f"fig_alpha/a{a}",
             1e6 * r["wall"] / max(r["result"].tokens_generated, 1),
             f"acc={r['acc']:.3f};tps={r['tps']:.1f};nfe={r['nfe']}")


if __name__ == "__main__":
    main()
