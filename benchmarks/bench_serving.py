"""Serving benchmark: synchronous run-to-completion batching vs the
continuous block-level batcher on a ragged workload — mixed generation
budgets plus early-exit-heavy prompts alongside full-length stragglers,
the regime where stragglers pin a synchronous batch.

    PYTHONPATH=src python benchmarks/bench_serving.py \
        [--n 64] [--max-slots 16] [--out results/BENCH_serving.json]

The workload isolates *scheduling* from model quality: a random-init
tiny model with the EOS id remapped to a token it actually emits, chosen
so exit blocks are genuinely ragged (a mix of block-0/1 early exits and
rows that run the full budget). The fully-trained arithmetic bench
model terminates every request in block 0, which makes every scheduling
policy equivalent — raggedness is the property under test here.

Reports throughput (tok/s), p50/p99 latency, TTFB, mean slot occupancy
and the compiled-variant count: after one full warmup wave of the
workload, a second identical wave must trigger zero new compiles
(jit cache bounded by shape buckets, no per-request recompilation).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import numpy as np

from common import BLOCK, append_history
from repro.core.decoder import DecodeConfig, DiffusionDecoder
from repro.core.engine import ServingEngine
from repro.data.tokenizer import ByteTokenizer
from repro.models import get_config, init_params
from repro.serving import ContinuousEngine, ServeMetrics

GEN_LEN = 32


def ragged_model(arch="tiny", seed=3, straggler_frac=1 / 3):
    """Random-init model + the fake-EOS id whose exit-block
    distribution is closest to ``straggler_frac`` rows never exiting."""
    cfg = get_config(arch, block_size=BLOCK)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    d = DecodeConfig(method="streaming", gen_len=GEN_LEN, block_size=BLOCK,
                     window=8, early_exit=False)
    rng = np.random.default_rng(1)
    probe = rng.integers(32, 127, (16, 12)).astype(np.int32)
    r = DiffusionDecoder(cfg, params, d).generate(probe.copy())
    vals, counts = np.unique(r.tokens, return_counts=True)
    best, best_gap = int(vals[counts.argmax()]), 1.0
    for k in np.argsort(counts)[::-1][:8]:
        tok_id = int(vals[k])
        never = np.mean([(row != tok_id).all() for row in r.tokens])
        if abs(never - straggler_frac) < best_gap:
            best, best_gap = tok_id, abs(never - straggler_frac)
    return dataclasses.replace(cfg, eos_token_id=best), params


def ragged_workload(n, seed=7):
    """Printable-ASCII prompts (reversibly re-encodable by both
    engines) with mixed generation budgets: 2/3 short (16) and 1/3
    long (32) in a deterministic interleave."""
    rng = np.random.default_rng(seed)
    tok = ByteTokenizer()
    prompts = [tok.decode(row) for row in
               rng.integers(32, 127, (n, 12)).astype(np.int32)]
    budgets = [16 if rng.random() < 2 / 3 else GEN_LEN for _ in range(n)]
    return list(zip(prompts, budgets))


def run_batch(cfg, params, dcfg, work, max_batch):
    eng = ServingEngine(cfg, params, dcfg, max_batch=max_batch, mode="batch")
    # warmup wave: identical workload once, so every group shape is
    # compiled before the timed region (same treatment as continuous)
    for p, mt in work:
        eng.submit(p, max_tokens=mt)
    eng.run_to_completion()
    eng.stats.clear()
    submit_t = {}
    t0 = time.perf_counter()
    for p, mt in work:
        uid = eng.submit(p, max_tokens=mt)
        submit_t[uid] = time.perf_counter()
    # drive step-by-step so each request's latency is stamped when its
    # batch finishes, not when the whole run drains
    done, lat = [], []
    while eng._queue:
        comps = eng.step()
        now = time.perf_counter()
        done.extend(comps)
        lat.extend(now - submit_t[c.uid] for c in comps)
    wall = time.perf_counter() - t0
    toks = eng.stats["tokens"]
    return {
        "mode": "batch",
        "requests": len(done),
        "tokens": int(toks),
        "wall_s": wall,
        "throughput_tok_s": toks / max(wall, 1e-9),
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "batches": int(eng.stats["batches"]),
    }


def run_continuous(cfg, params, dcfg, work, max_slots):
    eng = ContinuousEngine(cfg, params, dcfg, max_slots=max_slots)
    # warmup wave: the identical workload once through — fills the
    # whole (bucket, batch-pow2, block) shape lattice including the
    # small-batch shapes of the drain tail
    for p, mt in work:
        eng.submit(p, max_tokens=mt)
    eng.run_to_completion()
    eng.metrics = ServeMetrics(max_slots=max_slots)
    jit_after_warmup = eng.jit_cache_size()
    t0 = time.perf_counter()
    for p, mt in work:
        eng.submit(p, max_tokens=mt)
    done = eng.run_to_completion()
    wall = time.perf_counter() - t0
    snap = eng.metrics.snapshot()
    return {
        "mode": "continuous",
        "requests": len(done),
        "tokens": snap["tokens"],
        "wall_s": wall,
        "throughput_tok_s": snap["tokens"] / max(wall, 1e-9),
        "latency_p50_s": snap["latency_p50_s"],
        "latency_p99_s": snap["latency_p99_s"],
        "ttfb_p50_s": snap["ttfb_p50_s"],
        "mean_occupancy": snap["mean_occupancy"],
        "nfe_per_request": snap["nfe_per_request"],
        "jit_cache_after_warmup": jit_after_warmup,
        "jit_cache_final": eng.jit_cache_size(),
        "pool": eng.pool.stats(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--max-slots", type=int, default=16)
    ap.add_argument("--method", default="streaming")
    ap.add_argument("--arch", default="tiny-100m",
                    help="tiny-100m is compute-bound on CPU so batch "
                         "compaction shows up in wall time; plain tiny "
                         "is dispatch-overhead-bound")
    ap.add_argument("--out", default="results/BENCH_serving.json")
    args = ap.parse_args()

    cfg, params = ragged_model(args.arch)
    work = ragged_workload(args.n)

    dcfg = DecodeConfig(method=args.method, gen_len=GEN_LEN,
                        block_size=BLOCK, window=8)

    batch = run_batch(cfg, params, dcfg, work, args.max_slots)
    cont = run_continuous(cfg, params, dcfg, work, args.max_slots)
    rec = {
        "workload": {"n": args.n, "gen_budgets": "16(2/3)|32(1/3)",
                     "method": args.method, "arch": args.arch,
                     "max_slots": args.max_slots,
                     "fake_eos_token": cfg.eos_token_id},
        "batch": batch,
        "continuous": cont,
        "speedup_throughput": (cont["throughput_tok_s"]
                               / max(batch["throughput_tok_s"], 1e-9)),
        # after one full wave of the workload, a second identical wave
        # must hit only cached compilations (shape-bucket bounded)
        "recompiled_after_warmup": (cont["jit_cache_final"]
                                    > cont["jit_cache_after_warmup"]),
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    append_history(args.out, rec)
    print(json.dumps(rec, indent=1))
    print(f"\nserving,{1e6 * cont['wall_s'] / max(args.n, 1):.1f},"
          f"speedup={rec['speedup_throughput']:.2f}x "
          f"p99 {batch['latency_p99_s']:.2f}s->{cont['latency_p99_s']:.2f}s")


if __name__ == "__main__":
    main()
