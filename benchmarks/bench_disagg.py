"""Disaggregated prefill/decode pools under a mixed workload: a steady
short-prompt decode stream, then a Poisson storm of long cold prompts
layered on top.

    PYTHONPATH=src python benchmarks/bench_disagg.py \
        [--quick] [--out results/BENCH_disagg.json]

The question this bench answers: when a burst of long-prompt (cache
cold, prefill-heavy) requests arrives, does the latency of the
already-running decode stream survive? Co-located engines interleave
the storm's prefill passes with the stream's decode gangs on the same
loops, so stream p50 inflates; a ``--pool prefill:N,decode:M`` fleet
absorbs the prefill passes on the prefill pool, hands each primed
request off through the shared radix store, and the decode pool only
ever sees decode work. Both configurations run the SAME seeded
workload in their own budgeted subprocess (``repro.launch.host``) with
the persistent compile cache + full pre-warm, so the measurement
windows contain zero compiles (asserted per engine).

Per config the child measures two windows over the identical stream:

* quiet — stream clients alone (the baseline the storm is judged
  against),
* storm — the same stream plus unique long prompts arriving with
  exponential gaps.

``degradation_p50`` is storm-window stream p50 over quiet-window
stream p50. The parent emits ``decode_pool_insulated`` — disaggregated
degradation no worse than co-located (with slack for host-CPU noise)
— plus ``handoffs_ok`` and ``zero_post_warm_compiles`` for
``scripts/bench_gate.py``.

Numbers on host CPU measure *scheduling isolation*, not chip speedup;
the insulation ratio is the portable signal.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from common import append_history

WORKLOAD_SEED = 3            # params + workload PRNG: one knob, recorded
STREAM_TOKENS = 16           # stream decode length (two 8-token blocks)
STORM_TOKENS = 8             # storm rows decode one block: prefill-heavy
CHUNK = 8                    # radix-store chunk (tokens)


def stream_prompts(seed, n):
    """Short warm prompts, all one shape bucket (12 bytes = one aligned
    chunk + remainder), reused round-robin by every stream client."""
    rng = np.random.default_rng(seed)
    d = rng.integers(0, 10, (n, 4))
    return [f"Q:{a}{b}+{c}{d_}=? A:" for (a, b, c, d_) in d]


def storm_prompt(i, length):
    """Unique long prompt #``i``: always a radix-store miss on its
    aligned prefix, so the router sends it to the prefill pool. Fixed
    ``length`` keeps the storm in one shape bucket (no storm-time
    compiles)."""
    head = f"CTX{i:05d}:"
    body = "".join(str((i * 7 + j) % 10) for j in range(length - len(head)))
    return head + body


# --------------------------------------------------------------- child

async def _stream_client(sess, prompts, offset, stop, log):
    """Closed-loop client: one request in flight, round-robin prompts;
    every completion is logged (start time, latency) so the parent
    window split can bucket it."""
    i = offset
    while not stop.is_set():
        t0 = time.perf_counter()
        status, _, doc = await sess.complete(
            {"prompt": prompts[i % len(prompts)],
             "max_tokens": STREAM_TOKENS})
        assert status == 200, status
        log.append((t0, time.perf_counter() - t0))
        i += 1


async def _storm(host, port, spec, log):
    """Poisson arrivals of unique long prompts for ``storm_s``;
    open-loop (fire-and-forget tasks, gathered at the end) so storm
    backpressure cannot throttle the arrival process itself."""
    from repro.server import client as C

    rng = np.random.default_rng(spec["seed"] + 17)
    tasks = []

    async def one(p):
        t0 = time.perf_counter()
        status, _, doc = await C.complete(
            host, port, {"prompt": p, "max_tokens": STORM_TOKENS})
        assert status == 200, status
        log.append((t0, time.perf_counter() - t0))

    t_end = time.perf_counter() + spec["storm_s"]
    i = 0
    while time.perf_counter() < t_end:
        tasks.append(asyncio.ensure_future(
            one(storm_prompt(i, spec["storm_len"]))))
        i += 1
        await asyncio.sleep(rng.exponential(1.0 / spec["storm_rate"]))
    await asyncio.gather(*tasks)


def _window(log, t0, t1):
    return [lat for (t, lat) in log if t0 <= t < t1]


def child_serve(spec):
    """One pool configuration end to end: budgeted process (env set by
    the parent), shared radix store, pre-warm both shape buckets, warm
    the stream prompts, then measure quiet vs storm windows."""
    import jax
    from repro.cache import PrefixKVCache
    from repro.core.decoder import DecodeConfig, round_up_blocks
    from repro.data.tokenizer import ByteTokenizer
    from repro.launch import host as host_budgeting
    from repro.models import get_config, init_params
    from repro.obs.compile import persistent_cache_counters
    from repro.server import EngineLoop, EngineRouter, HttpFrontend
    from repro.server.client import ClientSession
    from repro.serving import ContinuousEngine, percentile

    n_pre, n_dec = spec["prefill"], spec["decode"]
    roles = ["prefill"] * n_pre + ["decode" if n_pre else "both"] * n_dec
    pc_on = host_budgeting.enable_compile_cache(spec["cache_dir"])
    budgets = host_budgeting.compute_pool_budgets(
        {"prefill": n_pre, "decode": n_dec}) if n_pre else \
        {"both": host_budgeting.compute_host_budget(n_dec)}

    cfg = get_config("tiny")
    params = init_params(cfg, jax.random.PRNGKey(spec["seed"]))
    dcfg = DecodeConfig(method="streaming", gen_len=STREAM_TOKENS,
                        block_size=8, window=4,
                        prefix_cache=True, cache_chunk=CHUNK)
    tok = ByteTokenizer(cfg.vocab_size)
    store = PrefixKVCache(chunk_tokens=CHUNK, shared=True)

    s_prompts = stream_prompts(spec["seed"], 4)
    buckets = [(len(tok.encode(s_prompts[0])),
                round_up_blocks(STREAM_TOKENS, dcfg.block_size)),
               (len(tok.encode(storm_prompt(0, spec["storm_len"]))),
                round_up_blocks(STORM_TOKENS, dcfg.block_size))]

    engines = [ContinuousEngine(
        cfg, params, dcfg, max_slots=4, tokenizer=tok, prefix_cache=store,
        prefill_only=(r == "prefill"), host_budget=budgets[r])
        for r in roles]
    t0 = time.perf_counter()
    prewarm = [e.prewarm(buckets) for e in engines]
    prewarm_s = time.perf_counter() - t0
    loops = [EngineLoop(e, max_pending=256, idle_poll_s=0.002, index=i,
                        role=None if r == "both" else r)
             for i, (e, r) in enumerate(zip(engines, roles))]
    front = loops[0] if len(loops) == 1 else EngineRouter(loops)

    async def run():
        fe = await HttpFrontend(front, port=0).start()
        stream_log, storm_log = [], []
        try:
            # warm pass: publish every stream prompt's aligned chunk
            # into the store (and, in pool mode, prove the handoff path
            # before the clock starts)
            from repro.server import client as C
            for p in s_prompts:
                status, _, _ = await C.complete(
                    fe.host, fe.port,
                    {"prompt": p, "max_tokens": STREAM_TOKENS})
                assert status == 200, status

            stop = asyncio.Event()
            sessions = [ClientSession(fe.host, fe.port)
                        for _ in range(spec["stream_clients"])]
            clients = [asyncio.ensure_future(
                _stream_client(s, s_prompts, k, stop, stream_log))
                for k, s in enumerate(sessions)]
            t_quiet = time.perf_counter()
            tok_base = sum(e.metrics.total_tokens for e in engines)
            await asyncio.sleep(spec["quiet_s"])
            tok_quiet = sum(e.metrics.total_tokens for e in engines)
            t_storm = time.perf_counter()
            await _storm(fe.host, fe.port, spec, storm_log)
            t_end = time.perf_counter()
            tok_storm = sum(e.metrics.total_tokens for e in engines)
            stop.set()
            await asyncio.gather(*clients)
            for s in sessions:
                await s.close()
        finally:
            await fe.shutdown(drain=True, timeout_s=60)

        quiet = _window(stream_log, t_quiet, t_storm)
        storm = _window(stream_log, t_storm, t_end)
        assert quiet and storm, (len(quiet), len(storm))
        snaps = [e.metrics.snapshot() for e in engines]
        handoffs = sum(s["handoffs_in"] for s in snaps)
        wait_s = sum(s["handoff_wait_s"] for s in snaps)
        p50_q, p50_s = percentile(quiet, 50), percentile(storm, 50)
        return {
            "pool": f"prefill:{n_pre},decode:{n_dec}" if n_pre
                    else f"colocated:{n_dec}",
            "engines": len(engines),
            "intra_op_threads": next(iter(budgets.values())).intra_op,
            "quiet": {
                "stream_requests": len(quiet),
                "stream_p50_ms": round(1e3 * p50_q, 1),
                "stream_p99_ms": round(1e3 * percentile(quiet, 99), 1),
                "tok_per_s": round(
                    (tok_quiet - tok_base) / (t_storm - t_quiet), 2),
            },
            "storm": {
                "stream_requests": len(storm),
                "stream_p50_ms": round(1e3 * p50_s, 1),
                "stream_p99_ms": round(1e3 * percentile(storm, 99), 1),
                "storm_requests": len(storm_log),
                "storm_p50_ms": round(
                    1e3 * percentile([l for _, l in storm_log] or [0.0],
                                     50), 1),
                "tok_per_s": round(
                    (tok_storm - tok_quiet) / (t_end - t_storm), 2),
            },
            "degradation_p50": round(p50_s / max(p50_q, 1e-9), 3),
            "handoffs": handoffs,
            "handoff_wait_ms_mean": round(
                1e3 * wait_s / handoffs, 2) if handoffs else 0.0,
            "prewarm_s": round(prewarm_s, 2),
            "prewarm_variants": sum(r["variants"] for r in prewarm),
            "persistent_cache": dict(persistent_cache_counters()) if pc_on
            else None,
            "per_engine": [{
                "role": roles[i],
                "requests": s["requests"],
                "prefill_busy_s": round(s["prefill_busy_s"], 3),
                "decode_busy_s": round(s["decode_busy_s"], 3),
                "handoffs_in": s["handoffs_in"],
                "handoffs_out": s["handoffs_out"],
                "steals_in": s["steals_in"],
                "steals_out": s["steals_out"],
                "post_warm_compiles": s["post_warm_compiles"],
            } for i, s in enumerate(snaps)],
        }

    rec = asyncio.run(run())
    post = sum(e["post_warm_compiles"] for e in rec["per_engine"])
    assert post == 0, (
        f"{post} compile(s) inside the measurement window — pre-warm "
        f"missed a shape bucket (see repro_post_warm_compiles_total)")
    rec["zero_post_warm_compiles"] = True
    return rec


# -------------------------------------------------------------- parent

def _spawn(spec, engines_for_budget):
    """Run one pool config in a fresh budgeted process; its last stdout
    line is the JSON result."""
    from repro.launch import host as host_budgeting
    budget = host_budgeting.compute_host_budget(engines_for_budget)
    env = host_budgeting.budget_env(budget, platform="cpu")
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", "serve",
         "--spec", json.dumps(spec)],
        env=env, capture_output=True, text=True, timeout=3000)
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise RuntimeError(f"child {spec} failed")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _show(rec):
    q, s = rec["quiet"], rec["storm"]
    print(f"  {rec['pool']}: quiet p50={q['stream_p50_ms']}ms "
          f"({q['tok_per_s']} tok/s) -> storm p50={s['stream_p50_ms']}ms "
          f"({s['tok_per_s']} tok/s)  degradation x{rec['degradation_p50']} "
          f"handoffs={rec['handoffs']} "
          f"(wait {rec['handoff_wait_ms_mean']}ms)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2-engine fleets, short windows")
    ap.add_argument("--out", default="results/BENCH_disagg.json")
    ap.add_argument("--cache-dir", default="results/compile_cache",
                    help="persistent XLA compile cache shared across "
                         "both pool configurations")
    ap.add_argument("--child", default="", choices=["", "serve"])
    ap.add_argument("--spec", default="{}", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.child:
        print(json.dumps(child_serve(json.loads(args.spec))))
        return

    # identical total engine count per config — the comparison isolates
    # role assignment, not fleet size
    base = {
        "seed": WORKLOAD_SEED,
        "cache_dir": os.path.abspath(args.cache_dir),
        # full mode: enough closed-loop stream clients to keep EVERY
        # engine's slots occupied — with spare slots the load-aware
        # router just routes the stream around the storm-busy engine
        # and co-located head-of-line blocking never shows
        "stream_clients": 2 if args.quick else 8,
        "quiet_s": 4.0 if args.quick else 10.0,
        "storm_s": 6.0 if args.quick else 15.0,
        "storm_rate": 1.0 if args.quick else 4.0,
        # 12 radix chunks per storm prompt: a cold prefill is 12 chunk
        # passes back-to-back inside one host tick (long enough to
        # block that engine's stream rows), while the adopted row's
        # decode stays one block
        "storm_len": 48 if args.quick else 96,
    }
    total = 2 if args.quick else 4

    print("== co-located fleet (every engine prefills AND decodes) ==")
    colocated = _spawn(dict(base, prefill=0, decode=total),
                       engines_for_budget=total)
    _show(colocated)

    print("== disaggregated fleet (prefill pool + decode pool) ==")
    disagg = _spawn(dict(base, prefill=1, decode=total - 1),
                    engines_for_budget=total)
    _show(disagg)

    deg_c, deg_d = colocated["degradation_p50"], disagg["degradation_p50"]
    # the verdict is the head-to-head STORM window at equal fleet size:
    # does the pooled fleet serve the stream at least as well as
    # co-located while the burst is in flight? (The quiet-normalized
    # degradation ratios are reported but deliberately not gated —
    # pooling also improves the quiet baseline, because fewer, busier
    # decode engines form larger better-amortized gangs, and a better
    # baseline inflates the ratio while every absolute storm-window
    # number improves.) Slack absorbs 1-core host jitter: the claim is
    # "no worse under the burst", not a fixed speedup.
    cs, ds = colocated["storm"], disagg["storm"]
    insulated = (ds["stream_p50_ms"] <= cs["stream_p50_ms"] * 1.25
                 and ds["tok_per_s"] >= cs["tok_per_s"] * 0.8)
    handoffs_ok = (disagg["handoffs"] > 0 and colocated["handoffs"] == 0
                   and all(e["decode_busy_s"] == 0.0
                           for e in disagg["per_engine"]
                           if e["role"] == "prefill"))
    print(f"== verdict: storm-window stream p50 {cs['stream_p50_ms']}ms "
          f"(colocated) vs {ds['stream_p50_ms']}ms (disagg), tok/s "
          f"{cs['tok_per_s']} vs {ds['tok_per_s']}; degradation "
          f"x{deg_c} vs x{deg_d} -> insulated={insulated} "
          f"handoffs_ok={handoffs_ok}")

    doc = {"arch": "tiny", "method": "streaming",
           "workload_seed": WORKLOAD_SEED,
           "host_cores": os.cpu_count(),
           "stream_tokens": STREAM_TOKENS, "storm_tokens": STORM_TOKENS,
           "storm_len": base["storm_len"],
           "note": ("host-CPU run: subprocess-per-config with shared "
                    "thread budgets, persistent compile cache + pre-warm "
                    "(zero compiles inside the measurement windows); the "
                    "portable signal is the degradation ratio, not "
                    "absolute latency"),
           "zero_post_warm_compiles": (
               colocated["zero_post_warm_compiles"]
               and disagg["zero_post_warm_compiles"]),
           "handoffs_ok": handoffs_ok,
           "decode_pool_insulated": insulated,
           "colocated": colocated,
           "disaggregated": disagg}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {args.out}")
    append_history(args.out, doc)


if __name__ == "__main__":
    main()
